//! E1/E9/E10 + design-choice ablations:
//!   * Fig. 4  — save-timeline comparison (snapshot frequency per persist);
//!   * Fig. 3  — modeled GPU/CPU utilization during 3D pretraining;
//!   * §6.2a   — CPU memory accounting (<= 3x payload claim, OPT-2.7B DP-6);
//!   * ablations: tiny-bucket size sweep, sharding on/off, RAIM5 on/off,
//!     clean-copy depth — each isolating one design choice from §4.

use reft::config::{zoo, FtConfig, FtMethod};
use reft::hwsim::{ClusterHw, HwSpec};
use reft::snapshot::{cost, SnapshotPlan};
use reft::topology::{ParallelPlan, Topology};
use reft::util::{human_bytes, human_secs};

fn reft_cost_with(
    topo: &Topology,
    plan: &SnapshotPlan,
    ft: &FtConfig,
    iter_secs: f64,
) -> cost::SaveCost {
    let mut hw = ClusterHw::new(HwSpec::scaled(topo.nodes, topo.gpus_per_node));
    let ctx = cost::SaveCtx { topo, plan, ft, iter_compute_secs: iter_secs };
    cost::method_save_cost(&mut hw, &ctx)
}

fn main() {
    fig4_timeline();
    fig3_utilization();
    memory_accounting();
    bucket_sweep();
    sharding_ablation();
    raim5_ablation();
}

/// Fig. 4: under one persist budget, how many snapshots does each method fit?
fn fig4_timeline() {
    println!("=== Fig. 4 — snapshots per persisting period ===\n");
    let spec = zoo::zoo_model("opt-350m").unwrap();
    let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let plan = SnapshotPlan::build(&topo, &[spec.save_bytes()]);
    let iter = 1.0;
    let costs = cost::compare_methods(&topo, &plan, iter, true);
    let persist_time = costs
        .iter()
        .find(|c| c.method == "torchsnapshot")
        .unwrap()
        .total;
    println!("persisting period (sharded ckpt I/O): {}", human_secs(persist_time));
    println!(
        "{:<14} {:>14} {:>22}",
        "method", "save makespan", "saves per persist"
    );
    for c in &costs {
        let per = (persist_time / c.total).floor().max(1.0);
        println!(
            "{:<14} {:>14} {:>22}",
            c.method,
            human_secs(c.total),
            if c.method.starts_with("reft") {
                format!("{per:.0}  (in-memory, I/O-free)")
            } else {
                "1  (bound to storage I/O)".to_string()
            }
        );
    }
    let sn = costs.iter().find(|c| c.method == "reft-sn").unwrap();
    assert!(persist_time / sn.total > 5.0, "REFT must fit many snapshots per persist");
    println!();
}

/// Fig. 3: GPU vs CPU utilization during 3D pretraining of OPT-2.7B
/// (2 DP x 4 TP x 3 PP on the testbed), with and without REFT.
fn fig3_utilization() {
    println!("=== Fig. 3 — modeled utilization, OPT-2.7B 2DPx4TPx3PP ===\n");
    let spec = zoo::zoo_model("opt-2.7b").unwrap();
    let topo = Topology::build(ParallelPlan::new(2, 4, 3), 6, 4).unwrap();
    let stage_bytes: Vec<u64> = (0..3).map(|s| spec.stage_params(s, 3) * 16).collect();
    let plan = SnapshotPlan::build(&topo, &stage_bytes);
    let iter = 2.0; // s/iter for 2.7B on V100s (order of magnitude)
    let n_micro = 8;
    let bubble = reft::pipeline::bubble_fraction(3, n_micro);

    let mut csv = String::from("config,gpu_util,cpu_util\n");
    for (name, method) in [("baseline (no FT)", FtMethod::None), ("with REFT-Sn", FtMethod::ReftSn)]
    {
        let ft = FtConfig { method, ..FtConfig::default() };
        let c = reft_cost_with(&topo, &plan, &ft, iter);
        let gpu = (1.0 - bubble) * iter / (iter + c.stall);
        let cpu = (0.05 + (c.shamem + c.ec_encode) / (iter + c.stall)).min(1.0);
        println!(
            "  {name:<18} GPU ~{:>5.1}%   CPU ~{:>5.1}%   (stall {} per save)",
            gpu * 100.0,
            cpu * 100.0,
            human_secs(c.stall)
        );
        csv.push_str(&format!("{name},{gpu:.4},{cpu:.4}\n"));
    }
    std::fs::create_dir_all("artifacts/bench_results").unwrap();
    std::fs::write("artifacts/bench_results/fig3_utilization.csv", csv).unwrap();
    println!("  (paper's point: CPU headroom is large; REFT's extra CPU use");
    println!("   costs almost no GPU time)\n");
}

/// §6.2a: peak CPU memory <= 3x payload; OPT-2.7B DP-6 example.
fn memory_accounting() {
    println!("=== §6.2a — CPU memory accounting (OPT-2.7B, DP-6) ===\n");
    let spec = zoo::zoo_model("opt-2.7b").unwrap();
    let payload = spec.save_bytes();
    // 6-way DP on 6 nodes: each node's SMP holds shard + parity + dirty
    let shard = payload / 6;
    let per_node = |clean: u64, with_parity: bool| {
        let parity = if with_parity { shard.div_ceil(5) } else { 0 };
        let dirty = shard; // one in-flight dirty buffer
        clean * shard + parity + dirty
    };
    println!(
        "full FT payload: {} ({} params x 16 B)",
        human_bytes(payload),
        spec.total_params()
    );
    for (label, clean, parity) in [
        ("1 clean copy, RAIM5 on", 1u64, true),
        ("2 clean copies, RAIM5 on", 2, true),
        ("1 clean copy, RAIM5 off", 1, false),
    ] {
        let b = per_node(clean, parity);
        println!(
            "  {label:<26} per-node SMP memory {:>10}  ({:.2}x of node shard)",
            human_bytes(b),
            b as f64 / shard as f64
        );
        assert!(
            b <= 3 * shard + shard,
            "exceeds the paper's <= 3x + buffer budget"
        );
    }
    println!(
        "  paper quote: peak 20.45 GB incl. loader cache on this workload\n   (our 1-clean+parity per-node figure: {})\n",
        human_bytes(per_node(1, true))
    );
}

/// Ablation: tiny-bucket size vs stall + makespan (the §4.1 trade).
fn bucket_sweep() {
    println!("=== Ablation — tiny-bucket size (OPT-350M, DP-24) ===\n");
    let spec = zoo::zoo_model("opt-350m").unwrap();
    let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let plan = SnapshotPlan::build(&topo, &[spec.save_bytes()]);
    println!(
        "{:>12} {:>14} {:>14}",
        "bucket", "save makespan", "ramp share"
    );
    let mut prev_total = f64::INFINITY;
    for bucket in [1 << 20, 4 << 20, 16 << 20, 64 << 20, 256 << 20] {
        let ft = FtConfig {
            method: FtMethod::ReftSn,
            bucket_bytes: bucket,
            ..FtConfig::default()
        };
        let c = reft_cost_with(&topo, &plan, &ft, 1.0);
        let ramp = 2.0 * bucket as f64 / HwSpec::paper_testbed().shamem_bw;
        println!(
            "{:>12} {:>14} {:>13.1}%",
            human_bytes(bucket as u64),
            human_secs(c.total),
            ramp / c.total * 100.0
        );
        // bigger buckets should never make the modeled makespan *better*
        // than the pipeline bottleneck floor by much — monotone-ish growth
        assert!(c.total < prev_total * 10.0);
        prev_total = c.total;
    }
    println!("  (small buckets: negligible ramp, bounded PCIe interference;");
    println!("   the interference coefficient is what Fig. 11 pays for bulk copies)\n");
}

/// Ablation: intra-SG sharding on/off (the m-fold d2h reduction of §4.1).
fn sharding_ablation() {
    println!("=== Ablation — SG sharding on/off (OPT-350M) ===\n");
    let spec = zoo::zoo_model("opt-350m").unwrap();
    // sharded: DP-24 across 6 nodes; unsharded: same cluster, 1 DP path
    let sharded_topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let unsharded_topo = Topology::build(ParallelPlan::dp_only(1), 6, 4).unwrap();
    let ft = FtConfig { method: FtMethod::ReftSn, ..FtConfig::default() };
    let c_sh = reft_cost_with(
        &sharded_topo,
        &SnapshotPlan::build(&sharded_topo, &[spec.save_bytes()]),
        &ft,
        1.0,
    );
    let c_un = reft_cost_with(
        &unsharded_topo,
        &SnapshotPlan::build(&unsharded_topo, &[spec.save_bytes()]),
        &ft,
        1.0,
    );
    println!(
        "  sharded over 6 nodes : makespan {}  d2h {}",
        human_secs(c_sh.total),
        human_secs(c_sh.d2h)
    );
    println!(
        "  single-node snapshot : makespan {}  d2h {}",
        human_secs(c_un.total),
        human_secs(c_un.d2h)
    );
    println!(
        "  sharding speedup: {:.1}x (paper: ~m-fold with m SG members)\n",
        c_un.total / c_sh.total
    );
    assert!(c_un.total / c_sh.total > 3.0);
}

/// Ablation: RAIM5 on/off — protection vs doubled snapshot volume (§4.3).
fn raim5_ablation() {
    println!("=== Ablation — RAIM5 on/off (OPT-350M, DP-24) ===\n");
    let spec = zoo::zoo_model("opt-350m").unwrap();
    let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let plan = SnapshotPlan::build(&topo, &[spec.save_bytes()]);
    for (label, raim5) in [("RAIM5 off", false), ("RAIM5 on ", true)] {
        let ft = FtConfig { method: FtMethod::ReftSn, raim5, ..FtConfig::default() };
        let c = reft_cost_with(&topo, &plan, &ft, 1.0);
        println!(
            "  {label}: makespan {}  d2h {}  xor {}  -> survives node loss: {}",
            human_secs(c.total),
            human_secs(c.d2h),
            human_secs(c.ec_encode),
            raim5
        );
    }
    println!("  (the 2x d2h volume buys single-node-loss recovery per SG —");
    println!("   Eq. 7 turns the restart rate quadratically smaller)");
}
