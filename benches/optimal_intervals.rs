//! E8 — Appendix A: optimal snapshot/checkpoint intervals (Eq. 4–11),
//! swept over failure rates and save costs, plus the induced total-overhead
//! comparison (Eq. 4) showing why high-frequency cheap snapshots beat
//! low-frequency expensive checkpoints.

use reft::config::{FtConfig, FtMethod};
use reft::persist::{IntervalScheduler, SnapshotScheduler};
use reft::reliability::intervals::{self, reft_fail_rate, save_overhead};
use reft::snapshot::{cost, SnapshotPlan};
use reft::topology::{ParallelPlan, Topology};
use reft::util::human_secs;

fn main() {
    println!("=== Appendix A — optimal fault-tolerance intervals ===\n");

    // measured-ish costs from the save-cost model (OPT-350M, DP-24 class):
    let t_comp = 1.0; // s per iteration
    let t_sn = 0.18; // REFT snapshot makespan
    let t_ck = 2.4; // sharded checkpoint makespan
    let n = 6;

    println!("inputs: T_comp={t_comp}s, T_sn={t_sn}s, T_ckpt={t_ck}s, SG n={n}\n");
    println!(
        "{:<12} {:>14} {:>14} {:>14} {:>10}",
        "λ_node (/s)", "T_re_sn", "T_ckpt", "T_re_ckpt", "stretch"
    );
    for lam in [1e-3, 1e-4, 1e-5, 1e-6] {
        let s = intervals::schedule(t_sn, t_ck, t_comp, lam, n);
        println!(
            "{:<12.0e} {:>14} {:>14} {:>14} {:>9.1}x",
            lam,
            human_secs(s.t_re_sn),
            human_secs(s.t_ckpt),
            human_secs(s.t_re_ckpt),
            s.t_re_ckpt / s.t_ckpt
        );
    }

    // Eq. 4 total overhead comparison over a 30-day run at λ = 1e-5 /s
    println!("\n--- Eq. 4 total FT overhead over a 30-day run (λ=1e-5/s) ---");
    let lam = 1e-5;
    let t_total = 30.0 * 86400.0;
    let resched = 30.0;

    // checkpoint-based: restart on every node failure
    let s = intervals::schedule(t_sn, t_ck, t_comp, lam, n);
    let o_ck = save_overhead(t_ck, t_comp).max(1e-6);
    let ck_restart = 20.0 + s.t_ckpt / 2.0 + resched; // load + avg recompute
    let ck_overhead = o_ck * t_total / s.t_ckpt + ck_restart * t_total * lam;

    // REFT: snapshots are ~free (overlapped); restarts from memory on the
    // node-failure rate, from checkpoint only on the exceedance rate
    let o_sn = save_overhead(t_sn, t_comp).max(1e-6);
    let reft_mem_restart = 60.0 + s.t_re_sn / 2.0 + resched; // decode + recompute
    let lam_re = reft_fail_rate(lam, n);
    let reft_ck_restart = 20.0 + s.t_re_ckpt / 2.0 + resched;
    let reft_overhead = o_sn * t_total / s.t_re_sn
        + reft_mem_restart * t_total * lam
        + reft_ck_restart * t_total * lam_re;

    println!(
        "  checkpoint-based: {:>12}  ({:.2}% of run)",
        human_secs(ck_overhead),
        ck_overhead / t_total * 100.0
    );
    println!(
        "  REFT            : {:>12}  ({:.3}% of run)",
        human_secs(reft_overhead),
        reft_overhead / t_total * 100.0
    );
    println!(
        "  REFT reduces cumulative FT overhead by {:.1}x",
        ck_overhead / reft_overhead
    );
    assert!(reft_overhead < ck_overhead);

    // sensitivity: REFT's advantage vs SG size
    println!("\n--- exceedance rate vs SG size (λ_node=1e-4) ---");
    println!("{:<6} {:>14} {:>12}", "n", "λ_re", "vs λ_node");
    for n in [2usize, 3, 4, 6, 8, 12] {
        let r = reft_fail_rate(1e-4, n);
        println!("{n:<6} {r:>14.3e} {:>11.0}x", 1e-4 / r);
    }

    // the live control plane: both cadence schedulers, seeded with the
    // cost MODEL (no measurements yet) and an observed failure storm —
    // what the trainers run per step, in one table
    println!("\n--- live schedulers (Eq. 9 + Eq. 11) under an observed failure storm ---");
    let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let plan = SnapshotPlan::build(&topo, &[6_000_000_000]);
    let ft = FtConfig { method: FtMethod::ReftCkpt, raim5: true, ..FtConfig::default() };
    let t_sn_model = cost::modeled_snapshot_secs(&topo, &plan, &ft, t_comp);
    println!("modeled snapshot cost (Eq. 9 input): {}", human_secs(t_sn_model));
    let mut sn = SnapshotScheduler::new(1e-4, 6, 5);
    let mut ck = IntervalScheduler::new(1e-4, 6, 6, 100);
    println!(
        "below the event floor: snapshot holds static {} steps, persist derives from the knob",
        sn.interval_steps()
    );
    for k in 0..12 {
        // one node failure every 5 minutes of run time: the observed MLE is
        // 11 / (3300 s x 6 nodes) ~ 5.6e-4 per node-second — several times
        // hotter than the 1e-4 knob, so the empirical takeover visibly
        // shortens both cadences
        sn.note_failure_event(300.0 * k as f64);
        ck.note_failure_event(300.0 * k as f64);
    }
    let sn_steps = sn.observe(t_sn_model, t_comp);
    let ck_steps = ck.observe(t_ck, t_comp);
    println!(
        "observed λ/node {:.3e}: snapshot every {sn_steps} steps, persist every {ck_steps} steps",
        sn.lambda_node()
    );
    assert!(sn.empirical_events() == 12 && sn_steps >= 1 && ck_steps >= 1);
    assert!(
        sn.lambda_node() > 1e-4,
        "the storm must read hotter than the knob: {:.3e}",
        sn.lambda_node()
    );
    // the derived snapshot cadence must be at least as eager as the
    // persist cadence — the whole point of the two-tier split
    assert!(sn_steps <= ck_steps, "snapshots must outpace persists: {sn_steps} vs {ck_steps}");
}
