//! E8 — Appendix A: optimal snapshot/checkpoint intervals (Eq. 4–11),
//! swept over failure rates and save costs, plus the induced total-overhead
//! comparison (Eq. 4) showing why high-frequency cheap snapshots beat
//! low-frequency expensive checkpoints.

use reft::reliability::intervals::{self, reft_fail_rate, save_overhead};
use reft::util::human_secs;

fn main() {
    println!("=== Appendix A — optimal fault-tolerance intervals ===\n");

    // measured-ish costs from the save-cost model (OPT-350M, DP-24 class):
    let t_comp = 1.0; // s per iteration
    let t_sn = 0.18; // REFT snapshot makespan
    let t_ck = 2.4; // sharded checkpoint makespan
    let n = 6;

    println!("inputs: T_comp={t_comp}s, T_sn={t_sn}s, T_ckpt={t_ck}s, SG n={n}\n");
    println!(
        "{:<12} {:>14} {:>14} {:>14} {:>10}",
        "λ_node (/s)", "T_re_sn", "T_ckpt", "T_re_ckpt", "stretch"
    );
    for lam in [1e-3, 1e-4, 1e-5, 1e-6] {
        let s = intervals::schedule(t_sn, t_ck, t_comp, lam, n);
        println!(
            "{:<12.0e} {:>14} {:>14} {:>14} {:>9.1}x",
            lam,
            human_secs(s.t_re_sn),
            human_secs(s.t_ckpt),
            human_secs(s.t_re_ckpt),
            s.t_re_ckpt / s.t_ckpt
        );
    }

    // Eq. 4 total overhead comparison over a 30-day run at λ = 1e-5 /s
    println!("\n--- Eq. 4 total FT overhead over a 30-day run (λ=1e-5/s) ---");
    let lam = 1e-5;
    let t_total = 30.0 * 86400.0;
    let resched = 30.0;

    // checkpoint-based: restart on every node failure
    let s = intervals::schedule(t_sn, t_ck, t_comp, lam, n);
    let o_ck = save_overhead(t_ck, t_comp).max(1e-6);
    let ck_restart = 20.0 + s.t_ckpt / 2.0 + resched; // load + avg recompute
    let ck_overhead = o_ck * t_total / s.t_ckpt + ck_restart * t_total * lam;

    // REFT: snapshots are ~free (overlapped); restarts from memory on the
    // node-failure rate, from checkpoint only on the exceedance rate
    let o_sn = save_overhead(t_sn, t_comp).max(1e-6);
    let reft_mem_restart = 60.0 + s.t_re_sn / 2.0 + resched; // decode + recompute
    let lam_re = reft_fail_rate(lam, n);
    let reft_ck_restart = 20.0 + s.t_re_ckpt / 2.0 + resched;
    let reft_overhead = o_sn * t_total / s.t_re_sn
        + reft_mem_restart * t_total * lam
        + reft_ck_restart * t_total * lam_re;

    println!(
        "  checkpoint-based: {:>12}  ({:.2}% of run)",
        human_secs(ck_overhead),
        ck_overhead / t_total * 100.0
    );
    println!(
        "  REFT            : {:>12}  ({:.3}% of run)",
        human_secs(reft_overhead),
        reft_overhead / t_total * 100.0
    );
    println!(
        "  REFT reduces cumulative FT overhead by {:.1}x",
        ck_overhead / reft_overhead
    );
    assert!(reft_overhead < ck_overhead);

    // sensitivity: REFT's advantage vs SG size
    println!("\n--- exceedance rate vs SG size (λ_node=1e-4) ---");
    println!("{:<6} {:>14} {:>12}", "n", "λ_re", "vs λ_node");
    for n in [2usize, 3, 4, 6, 8, 12] {
        let r = reft_fail_rate(1e-4, n);
        println!("{n:<6} {r:>14.3e} {:>11.0}x", 1e-4 / r);
    }
}
