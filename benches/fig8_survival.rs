//! E2 — Fig. 8: parameter survival probability over time, REFT vs
//! checkpoint-based fault tolerance, on a 3072-GPU system with SGs of 6,
//! λ_hw = λ_sw = 1e-4, Weibull shapes c ∈ {1.0, 1.3, 1.5, 2.0}.
//!
//! Emits the curves as CSV (artifacts/bench_results/fig8.csv) and prints the
//! survival-threshold crossing table the paper quotes (REFT holds 0.9
//! survival for ~16.22 days at c = 1.3; checkpointing for ~0.5 days).
//! Also validates against a Monte-Carlo simulation of the same failure model
//! (the analytic curves must match the sampled system).

use reft::hwsim::FailureModel;
use reft::reliability::survival::{ck_survival, crossing_time, re_survival};
use reft::util::rng::Rng;

const K: usize = 3072;
const N: usize = 6;
const LHW: f64 = 1e-4;
const LSW: f64 = 1e-4;

fn main() {
    println!("=== Fig. 8 — survival probability (k={K}, SG n={N}, λ=1e-4) ===\n");

    // curves
    let mut csv = String::from("c,t_days,p_checkpoint,p_reft\n");
    for &c in &[1.0, 1.3, 1.5, 2.0] {
        let mut t = 0.05;
        while t <= 40.0 {
            let ck = ck_survival(K, LHW, LSW, c, t);
            let re = re_survival(K, N, LHW, c, t, 1.0);
            csv.push_str(&format!("{c},{t:.3},{ck:.6},{re:.6}\n"));
            t *= 1.25;
        }
    }
    std::fs::create_dir_all("artifacts/bench_results").unwrap();
    std::fs::write("artifacts/bench_results/fig8.csv", &csv).unwrap();
    println!("curves -> artifacts/bench_results/fig8.csv\n");

    // crossing table
    println!("survival >= 0.9 holds for (days):");
    println!(
        "{:<8} {:>12} {:>12} {:>8}",
        "shape c", "checkpoint", "REFT", "ratio"
    );
    for &c in &[1.0, 1.3, 1.5, 2.0] {
        let t_ck = crossing_time(0.9, |t| ck_survival(K, LHW, LSW, c, t));
        let t_re = crossing_time(0.9, |t| re_survival(K, N, LHW, c, t, 1.0));
        println!(
            "{c:<8} {t_ck:>12.3} {t_re:>12.2} {:>7.1}x",
            t_re / t_ck
        );
    }
    println!("(paper, c=1.3: checkpoint ~0.5 d, REFT ~16.22 d)");

    // Monte-Carlo cross-check at c = 1.3, t = 5 days: sample Weibull TTFs for
    // 3072 nodes, count runs where (a) any node fails (ckpt loss) and
    // (b) some SG loses >= 2 nodes (REFT loss). Software failures don't kill
    // REFT (SMPs), hardware failures kill a node.
    println!("\nMonte-Carlo cross-check (c=1.3, t=5 days, 2000 trials):");
    let c = 1.3;
    let t_probe = 5.0;
    let model = FailureModel::new(LHW, LSW, c);
    let mut rng = Rng::seed_from(99);
    let trials = 2000;
    let mut ck_alive = 0usize;
    let mut re_alive = 0usize;
    for _ in 0..trials {
        let mut any_fail = false;
        let mut sg_overflow = false;
        for _sg in 0..K / N {
            let mut dead_in_sg = 0;
            for _node in 0..N {
                let hw = model.sample_ttf(&mut rng, LHW) <= t_probe;
                let sw = model.sample_ttf(&mut rng, LSW) <= t_probe;
                if hw || sw {
                    any_fail = true;
                }
                if hw {
                    dead_in_sg += 1;
                }
            }
            if dead_in_sg >= 2 {
                sg_overflow = true;
            }
        }
        if !any_fail {
            ck_alive += 1;
        }
        if !sg_overflow {
            re_alive += 1;
        }
    }
    let ck_mc = ck_alive as f64 / trials as f64;
    let re_mc = re_alive as f64 / trials as f64;
    let ck_an = ck_survival(K, LHW, LSW, c, t_probe);
    let re_an = re_survival(K, N, LHW, c, t_probe, 1.0);
    println!("  checkpoint: analytic {ck_an:.4}  monte-carlo {ck_mc:.4}");
    println!("  REFT      : analytic {re_an:.4}  monte-carlo {re_mc:.4}");
    assert!(
        (ck_an - ck_mc).abs() < 0.03,
        "ckpt analytic/MC diverge: {ck_an} vs {ck_mc}"
    );
    assert!(
        (re_an - re_mc).abs() < 0.03,
        "REFT analytic/MC diverge: {re_an} vs {re_mc}"
    );
    println!("  analytic curves match the sampled failure model ✓");
}
