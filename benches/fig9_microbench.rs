//! E3 — Fig. 9: single-node micro-benchmark.
//!
//! Paper setting: one node, 4 GPUs snapshotting 20 GB of synthetic
//! parameters. Reported series: device-to-host (d2h) speed, shared-memory
//! communication speed, and overall saving performance (perf) for CheckFreq,
//! TorchSnapshot, REFT-Sn and REFT-Ckpt.
//!
//! Two parts:
//! 1. modeled speeds on the simulated V100 node (paper-shape numbers);
//! 2. *measured* wall-time throughput of the real data-path primitives this
//!    repo executes (bucket memcpy into SMP buffers, XOR encode), so the sim
//!    constants stay honest.

use std::time::Instant;

use reft::config::{FtConfig, FtMethod};
use reft::hwsim::{ClusterHw, HwSpec};
use reft::snapshot::{cost, SnapshotPlan};
use reft::topology::{ParallelPlan, Topology};
use reft::util::human_secs;

const PAYLOAD: u64 = 20_000_000_000; // 20 GB, paper Fig. 9

fn main() {
    println!("=== Fig. 9 — single-node micro-benchmark (20 GB, 4 GPUs) ===\n");
    // single node, 4 DP ranks on its 4 GPUs
    let topo = Topology::build(ParallelPlan::dp_only(4), 1, 4).unwrap();
    let plan = SnapshotPlan::build(&topo, &[PAYLOAD]);

    println!(
        "{:<14} {:>12} {:>14} {:>12} {:>14}",
        "method", "d2h GB/s", "sha-mem GB/s", "perf GB/s", "save total"
    );
    let mut rows = Vec::new();
    for method in [
        FtMethod::CheckFreq,
        FtMethod::TorchSnapshot,
        FtMethod::ReftSn,
        FtMethod::ReftCkpt,
    ] {
        let ft = FtConfig { method, raim5: false, ..FtConfig::default() };
        let mut hw = ClusterHw::new(HwSpec::scaled(1, 4));
        let ctx = cost::SaveCtx { topo: &topo, plan: &plan, ft: &ft, iter_compute_secs: 1.0 };
        let c = cost::method_save_cost(&mut hw, &ctx);
        let d2h_speed = PAYLOAD as f64 / c.d2h / 1e9;
        let shamem_speed = if c.shamem > 0.0 {
            PAYLOAD as f64 / c.shamem / 1e9
        } else {
            0.0
        };
        println!(
            "{:<14} {:>12.2} {:>14.2} {:>12.2} {:>14}",
            c.method,
            d2h_speed,
            shamem_speed,
            c.speed() / 1e9,
            human_secs(c.total)
        );
        rows.push((c.method, d2h_speed, c.speed() / 1e9));
    }

    // paper-shape assertions (who wins, by roughly what factor)
    let get = |m: &str| rows.iter().find(|r| r.0 == m).unwrap();
    let cf = get("checkfreq");
    let ts = get("torchsnapshot");
    let sn = get("reft-sn");
    let ck = get("reft-ckpt");
    println!("\nshape checks vs paper Fig. 9:");
    println!(
        "  sharded d2h >= 3x CheckFreq d2h: {:.1}x  ({})",
        ts.1 / cf.1,
        ok(ts.1 / cf.1 >= 3.0)
    );
    println!(
        "  REFT-Sn perf > TorchSnapshot perf: {:.1}x  ({})",
        sn.2 / ts.2,
        ok(sn.2 > ts.2)
    );
    println!(
        "  REFT-Ckpt perf ~ TorchSnapshot class: {:.2}x  ({})",
        ck.2 / ts.2,
        ok((0.3..4.0).contains(&(ck.2 / ts.2)))
    );

    // ------------------------------------------------------------------
    // measured primitives (real bytes, this machine)
    // ------------------------------------------------------------------
    println!("\n--- measured data-path primitives (real wall time) ---");
    let n = 512 * 1024 * 1024usize; // 512 MiB working set
    let src = vec![0xA5u8; n];
    let mut dst = vec![0u8; n];
    dst.copy_from_slice(&src); // fault the pages in before timing

    let t0 = Instant::now();
    dst.copy_from_slice(&src);
    let memcpy_gbps = n as f64 / t0.elapsed().as_secs_f64() / 1e9;

    let t0 = Instant::now();
    reft::snapshot::bucket::copy_bucketed(&src, &mut dst, 0..n, 16 * 1024 * 1024, |_| {});
    let bucket_gbps = n as f64 / t0.elapsed().as_secs_f64() / 1e9;

    let t0 = Instant::now();
    reft::ec::xor_into(&mut dst, &src);
    let xor_gbps = n as f64 / t0.elapsed().as_secs_f64() / 1e9;

    println!("  memcpy (512 MiB)          : {memcpy_gbps:.2} GB/s");
    println!("  tiny-bucket copy (16 MiB) : {bucket_gbps:.2} GB/s");
    println!("  XOR encode                : {xor_gbps:.2} GB/s");
    println!(
        "  bucket overhead vs memcpy : {:.1}%  (tiny buckets must be ~free)",
        (memcpy_gbps / bucket_gbps - 1.0) * 100.0
    );
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
