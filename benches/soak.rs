//! The 10k-node failure-trace soak: correlated fault injection over the
//! full REFT control plane (paper fig. 8 regime, taken to 10 000 nodes),
//! plus the witness plane on the real fabric. Writes `BENCH_soak.json`.
//!
//! Usage:
//!   cargo bench --bench soak                  # full 10k schedule
//!   cargo bench --bench soak -- --smoke       # CI-sized 2k schedule
//!   cargo bench --bench soak -- --seed 1234   # replay a recorded schedule
//!   cargo bench --bench soak -- --out path    # artifact destination

use reft::soak::{run_scale, run_witness, write_bench_file, ScaleReport, SoakConfig};

const DEFAULT_SEED: u64 = 0x50AC_0001;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let flag = |name: &str| args.iter().any(|a| a == name);
    let value = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let seed: u64 = value("--seed")
        .map(|s| s.parse().expect("--seed takes a u64"))
        .unwrap_or(DEFAULT_SEED);
    let out = value("--out").unwrap_or_else(|| "BENCH_soak.json".to_string());
    let cfg = if flag("--smoke") {
        SoakConfig::smoke_2k(seed)
    } else {
        SoakConfig::paper_10k(seed)
    };

    println!(
        "=== soak: {} — {} nodes, {:.0}s horizon, seed {seed:#x} ===\n",
        cfg.name, cfg.nodes, cfg.horizon
    );

    let t0 = std::time::Instant::now();
    let scale = run_scale(&cfg).unwrap_or_else(|e| panic!("scale plane: {e:#}"));
    let wall = t0.elapsed().as_secs_f64();
    print_scale(&scale, wall);
    scale
        .check_invariants()
        .unwrap_or_else(|e| panic!("soak invariant violated: {e:#}"));
    println!("scale-plane invariants hold ✓\n");

    let witness = run_witness(seed).unwrap_or_else(|e| panic!("witness plane: {e:#}"));
    println!(
        "witness: {} incidents on the real fabric — {} SMP / {} RAIM5 / {} durable \
         restores, {} bytes bit-exact, {} brownout refusals, {} leaked keys ✓",
        witness.incidents,
        witness.smp_restores,
        witness.raim5_restores,
        witness.durable_restores,
        witness.bytes_verified,
        witness.brownout_refusals,
        witness.leaked_keys
    );

    write_bench_file(std::path::Path::new(&out), std::slice::from_ref(&scale), &witness)
        .unwrap();
    println!("\nartifact -> {out} (replay: --seed {seed:#x})");
}

fn print_scale(r: &ScaleReport, wall: f64) {
    println!(
        "{} incidents ({} events, {} overlapping) in {wall:.2}s wall",
        r.incidents_total, r.events_total, r.overlap_incidents
    );
    println!(
        "goodput {:.4} (floor {:.2}): productive {:.0}s, recovery {:.0}s, redo {:.0}s",
        r.goodput, r.goodput_floor, r.productive_secs, r.recovery_secs, r.redo_secs
    );
    println!(
        "{:<12} {:>9} {:>7} {:>13} {:>9}",
        "class", "incidents", "events", "recovery_s", "redo_s"
    );
    for (name, c) in [
        ("independent", &r.independent),
        ("rack_burst", &r.rack_burst),
        ("flap", &r.flap),
    ] {
        println!(
            "{name:<12} {:>9} {:>7} {:>13.1} {:>9.1}",
            c.incidents, c.events, c.recovery_secs, c.redo_secs
        );
    }
    println!(
        "recoveries: {} SMP, {} RAIM5, {} durable, {} fatal",
        r.smp_recoveries, r.raim5_recoveries, r.durable_recoveries, r.fatal_decisions
    );
    println!(
        "brownouts: {} windows, {} overlapped a durable recovery ({:.0}s stalled)",
        r.brownout_windows, r.brownout_overlaps, r.brownout_stall_secs
    );
    println!(
        "λ: knob {:.3e} → posterior {:.3e} (MLE {:.3e}, {} events)",
        r.lambda_knob, r.lambda_posterior, r.lambda_mle, r.events_total
    );
    println!(
        "cadence: snapshot {} steps; persist Eq.11 {} steps, effective {} steps",
        r.snapshot_steps_final, r.persist_steps_eq11, r.persist_steps_effective
    );
}
