//! E5/E6 — Fig. 10 (saving speed) + Fig. 11 (saving overhead) in strong
//! scaling: OPT-1.3B and OPT-2.7B under PP ∈ {1, 2, 4, 6} with TP=4, DP=1
//! (§6.1; RAIM5 off in the paper's strong-scaling runs due to GPU limits —
//! mirrored here).
//!
//! With DP=1 every SG has one node, so REFT's sharding is per-stage only:
//! speed grows with PP because stages persist/flush in parallel, while
//! CheckFreq's single-rank-per-stage copies and the shared cloud link
//! saturate. Overheads (Fig. 11) stay near-zero for REFT (tiny buckets) and
//! grow with payload for the unsharded baseline.

use reft::config::zoo;
use reft::snapshot::{cost, SnapshotPlan};
use reft::topology::{ParallelPlan, Topology};
use reft::util::human_secs;

fn main() {
    println!("=== Strong scaling — Fig. 10 (speed) + Fig. 11 (overhead) ===");
    let pps = [1usize, 2, 4, 6];
    for model in ["opt-1.3b", "opt-2.7b"] {
        let spec = zoo::zoo_model(model).unwrap();
        println!(
            "\n--- {} ({:.2}B params, payload {:.1} GB) — TP=4, DP=1 ---",
            model,
            spec.total_params() as f64 / 1e9,
            spec.save_bytes() as f64 / 1e9
        );
        println!("Fig. 10 — saving speed (GB/s):");
        println!(
            "{:<14} {:>9} {:>9} {:>9} {:>9}",
            "method", "PP-1", "PP-2", "PP-4", "PP-6"
        );
        let mut speed_tbl: Vec<(String, Vec<f64>)> = Vec::new();
        let mut stall_tbl: Vec<(String, Vec<f64>)> = Vec::new();
        for method in ["checkfreq", "torchsnapshot", "reft-sn", "reft-ckpt"] {
            let mut speeds = Vec::new();
            let mut stalls = Vec::new();
            for &pp in &pps {
                let topo = Topology::build(ParallelPlan::new(1, 4, pp), 6, 4).unwrap();
                let stage_bytes: Vec<u64> =
                    (0..pp).map(|s| spec.stage_params(s, pp) * 16).collect();
                let plan = SnapshotPlan::build(&topo, &stage_bytes);
                // paper's strong-scaling runs exclude RAIM5
                let costs = cost::compare_methods(&topo, &plan, 1.0, false);
                let c = costs.iter().find(|c| c.method == method).unwrap();
                speeds.push(c.speed() / 1e9);
                stalls.push(c.stall);
            }
            println!(
                "{:<14} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
                method, speeds[0], speeds[1], speeds[2], speeds[3]
            );
            speed_tbl.push((method.to_string(), speeds));
            stall_tbl.push((method.to_string(), stalls));
        }
        println!("Fig. 11 — saving overhead (training stall per save):");
        println!(
            "{:<14} {:>9} {:>9} {:>9} {:>9}",
            "method", "PP-1", "PP-2", "PP-4", "PP-6"
        );
        for (m, stalls) in &stall_tbl {
            println!(
                "{:<14} {:>9} {:>9} {:>9} {:>9}",
                m,
                human_secs(stalls[0]),
                human_secs(stalls[1]),
                human_secs(stalls[2]),
                human_secs(stalls[3])
            );
        }
        // shape checks
        let find = |tbl: &[(String, Vec<f64>)], m: &str| {
            tbl.iter().find(|t| t.0 == m).unwrap().1.clone()
        };
        let sn = find(&speed_tbl, "reft-sn");
        let cf = find(&speed_tbl, "checkfreq");
        let sn_stall = find(&stall_tbl, "reft-sn");
        let cf_stall = find(&stall_tbl, "checkfreq");
        println!("\nshape checks ({model}):");
        println!(
            "  REFT-Sn speed grows with PP: {:.2} -> {:.2} GB/s ({})",
            sn[0],
            sn[3],
            ok(sn[3] > sn[0])
        );
        println!(
            "  REFT-Sn > CheckFreq at every PP ({})",
            ok(sn.iter().zip(&cf).all(|(a, b)| a > b))
        );
        println!(
            "  REFT stall << CheckFreq stall: {} vs {} at PP-6 ({})",
            human_secs(sn_stall[3]),
            human_secs(cf_stall[3]),
            ok(sn_stall[3] < cf_stall[3] * 0.5)
        );
        assert!(sn.iter().zip(&cf).all(|(a, b)| a > b));
        assert!(sn_stall[3] < cf_stall[3]);
    }
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
