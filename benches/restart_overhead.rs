//! E7 — restarting & recomputation overhead (§6.2 "Restarting and
//! Recomputation Overhead").
//!
//! Paper protocol: during DP weak scaling, kill one node between two
//! consecutive saves, ten times; measure elastic restart cost. The paper's
//! finding: REFT's *parameter loading* is ~3.21x slower than a checkpoint
//! load (decode + gather beats a straight storage read only on recompute),
//! but because snapshots are far more frequent than checkpoints, REFT saves
//! >10 minutes of recomputation per failure.
//!
//! Part 1 models the paper testbed (OPT-350M, DP-24/6 nodes); part 2
//! measures the real decode path (live SMPs + RAIM5 XOR) on this machine.

use std::time::Instant;

use reft::collective;
use reft::config::FtConfig;
use reft::config::zoo;
use reft::ec::Raim5Group;
use reft::elastic::ReftCluster;
use reft::hwsim::{ClusterHw, HwSpec};
use reft::snapshot::{cost, SnapshotPlan};
use reft::topology::{ParallelPlan, Topology};
use reft::util::human_secs;
use reft::util::rng::Rng;

fn main() {
    println!("=== Restart & recomputation overhead (paper §6.2) ===\n");
    model_part();
    live_part();
}

fn model_part() {
    let spec = zoo::zoo_model("opt-350m").unwrap();
    let payload = spec.save_bytes();
    let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let plan = SnapshotPlan::build(&topo, &[payload]);
    let hwspec = HwSpec::paper_testbed();
    let iter_secs = 1.0; // per-iteration compute on the testbed class

    // save costs decide the save intervals via the Appendix-A optimum
    // (Eq. 5) at a per-node failure rate of 1e-5/s
    let lambda = 1e-5;
    let costs = cost::compare_methods(&topo, &plan, iter_secs, true);
    let sn = costs.iter().find(|c| c.method == "reft-sn").unwrap();
    let ck = costs.iter().find(|c| c.method == "torchsnapshot").unwrap();
    let sched =
        reft::reliability::intervals::schedule(sn.total, ck.total, iter_secs, lambda, 6);
    // snapshots can't run more often than their own makespan drains
    let sn_interval = sched.t_re_sn.max(sn.total).max(iter_secs);
    let ck_interval = sched.t_ckpt.max(ck.total);

    // restore costs
    let mut hw = ClusterHw::new(hwspec.clone());
    // checkpoint load: every node pulls its shard from cloud + deserialize + h2d
    let per_node = payload / 6;
    let fetch = hw
        .persist_to_cloud(0.0, &vec![per_node; 6]) // symmetric read cost
        .into_iter()
        .fold(0.0, f64::max);
    let deser = per_node as f64 / hwspec.serialize_bw;
    let h2d = (per_node / 4) as f64 / hwspec.pcie_bw;
    let ckpt_load = fetch + deser + h2d;

    // REFT restore: surviving nodes ship decode traffic over the inter-node
    // fabric, XOR decode on CPU, re-shard + h2d, plus a persist of the
    // reconstructed shard for the rejoining node (paper's step 5)
    let shard = payload / 6;
    let g = Raim5Group::plan(&vec![shard as usize; 6]).unwrap();
    let traffic = g.decode_traffic_bytes(0);
    let net = collective::p2p_time(traffic, hwspec.internode_bw, 100e-6);
    let xor = shard as f64 / hwspec.xor_bw;
    let reconstruct_persist = shard as f64 / hwspec.nic_bw;
    let reft_load = net + xor + reconstruct_persist + h2d;

    // lost work: uniform failure inside the save interval -> interval/2
    let reft_lost = sn_interval / 2.0;
    let ck_lost = ck_interval / 2.0;
    let resched = 30.0; // elastic rescheduling (TorchElastic rendezvous)

    println!("--- modeled on the paper testbed (OPT-350M, DP-24/6 nodes) ---");
    println!(
        "{:<22} {:>14} {:>14}",
        "", "checkpoint FT", "REFT"
    );
    println!(
        "{:<22} {:>14} {:>14}",
        "save interval",
        human_secs(ck_interval),
        human_secs(sn_interval)
    );
    println!(
        "{:<22} {:>14} {:>14}",
        "param load",
        human_secs(ckpt_load),
        human_secs(reft_load)
    );
    println!(
        "{:<22} {:>14} {:>14}",
        "lost recompute (avg)",
        human_secs(ck_lost),
        human_secs(reft_lost)
    );
    println!(
        "{:<22} {:>14} {:>14}",
        "reschedule",
        human_secs(resched),
        human_secs(resched)
    );
    let ck_total = ckpt_load + ck_lost + resched;
    let reft_total = reft_load + reft_lost + resched;
    println!(
        "{:<22} {:>14} {:>14}",
        "TOTAL restart",
        human_secs(ck_total),
        human_secs(reft_total)
    );
    println!(
        "\nload ratio REFT/ckpt: {:.2}x (paper: 3.21x — decode+gather vs straight read)",
        reft_load / ckpt_load
    );
    println!(
        "recompute saved by REFT: {} per failure (paper: >10 min)",
        human_secs(ck_lost - reft_lost)
    );
    assert!(reft_load > ckpt_load, "REFT load should cost more than a plain read");
    assert!(reft_total < ck_total, "REFT total restart must win");

    // the paper's 10-kill experiment: average over 10 failure times
    let mut rng = Rng::seed_from(7);
    let mut tot = (0.0, 0.0);
    for _ in 0..10 {
        let u: f64 = rng.f64();
        tot.0 += ckpt_load + resched + u * ck_interval;
        tot.1 += reft_load + resched + u * sn_interval;
    }
    println!(
        "10-kill average restart: checkpoint {} vs REFT {}",
        human_secs(tot.0 / 10.0),
        human_secs(tot.1 / 10.0)
    );
}

fn live_part() {
    println!("\n--- measured live recovery (real SMPs + XOR decode) ---");
    let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let payload_len = 192 * 1024 * 1024usize; // 192 MiB across 6 nodes
    let ft = FtConfig { bucket_bytes: 16 * 1024 * 1024, ..FtConfig::default() };
    let mut cluster = ReftCluster::start(topo, &[payload_len as u64], ft).unwrap();
    let mut rng = Rng::seed_from(3);
    let payload = reft::snapshot::SharedPayload::new(
        (0..payload_len).map(|_| rng.next_u64() as u8).collect(),
    );

    let t0 = Instant::now();
    cluster.snapshot_all(&[payload.clone()]).unwrap();
    let snap_t = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let clean = cluster.restore_all(&[]).unwrap();
    let restore_clean_t = t0.elapsed().as_secs_f64();
    assert_eq!(clean[0], payload);

    cluster.kill_node(2);
    let t0 = Instant::now();
    let decoded = cluster.restore_all(&[2]).unwrap();
    let restore_decode_t = t0.elapsed().as_secs_f64();
    assert_eq!(decoded[0], payload, "decode must be bit-exact");

    let gb = payload_len as f64 / 1e9;
    println!(
        "  snapshot (shard+bucket+parity): {}  ({:.2} GB/s)",
        human_secs(snap_t),
        gb / snap_t
    );
    println!(
        "  restore, all nodes alive      : {}  ({:.2} GB/s)",
        human_secs(restore_clean_t),
        gb / restore_clean_t
    );
    println!(
        "  restore, 1 node decoded       : {}  ({:.2} GB/s, {:.2}x clean restore)",
        human_secs(restore_decode_t),
        gb / restore_decode_t,
        restore_decode_t / restore_clean_t
    );
}
