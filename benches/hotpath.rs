//! §Perf — hot-path micro-benchmarks with real wall time (hand-rolled
//! harness; criterion is not in the offline crate set — median-of-N with
//! warmup, reporting MB/s or ns/op).
//!
//! Tracked paths (DESIGN.md §Perf):
//!   * XOR parity encode (`ec::xor_into`) vs the scalar reference and memcpy
//!     — target >= 1/2 memcpy (RAID5 write-penalty bound) — plus the striped
//!     multi-threaded `xor_into_parallel`;
//!   * tiny-bucket copy overhead vs bucket size;
//!   * checkpoint container encode (streaming CRC32, single pass);
//!   * live snapshot round (SMP channels + parity) throughput;
//!   * distributed in-memory restore: parallel gather vs the serial
//!     baseline at the default multi-stage/multi-node shape (parallel must
//!     be strictly faster — asserted);
//!   * per-iteration save stall, sync vs async coordinator (asserted);
//!   * observability overhead: the same async save path with the span
//!     tracer off vs on (asserted < 1% + 2 ms), plus the traced stall
//!     distribution (p50/p99) and a Perfetto trace artifact;
//!   * multipart part uploads: bounded in-node pool vs the serial lane
//!     under modeled RTT (asserted);
//!   * manifest codec: streaming single-pass vs the DOM round-trip,
//!     byte-identity checked inline (asserted);
//!   * durable restore verify: fused hash-in-copy + CRC combine vs the
//!     separate hash-after-copy loader (asserted);
//!   * PJRT dispatch overhead (adam on the tiny model), when artifacts exist.
//!
//! Emits a machine-readable `BENCH_hotpath.json` (override the path with
//! `BENCH_HOTPATH_JSON`) so CI can track the perf trajectory. `--smoke` (or
//! `BENCH_SMOKE=1`) shrinks sizes/iterations for an advisory CI run; every
//! assertion still fires.

use std::sync::Arc;
use std::time::{Duration, Instant};

use reft::checkpoint::{
    storage::step_key, CheckpointFile, LatencyStorage, MemStorage, SectionKind, Storage,
};
use reft::config::{FtConfig, PersistConfig};
use reft::elastic::{DurableTier, RecoveryPath, RecoveryPlan, ReftCluster};
use reft::ec::{xor_into, xor_into_parallel, xor_into_scalar};
use reft::metrics::{keys, Metrics};
use reft::persist::{self, PersistEngine};
use reft::snapshot::bucket::copy_bucketed;
use reft::snapshot::SharedPayload;
use reft::topology::{ParallelPlan, Topology};
use reft::util::json::Json;
use reft::util::rng::Rng;

fn bench<F: FnMut()>(name: &str, bytes_per_iter: usize, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let med = times[times.len() / 2];
    let gbps = bytes_per_iter as f64 / med / 1e9;
    println!("  {name:<38} {gbps:>8.2} GB/s   ({:.3} ms/iter)", med * 1e3);
    gbps
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    // JSON report: section name -> numbers (written at the end)
    let mut report: Vec<(String, Json)> = Vec::new();
    fn rec(r: &mut Vec<(String, Json)>, name: &str, pairs: Vec<(&str, f64)>) {
        r.push((
            name.to_string(),
            Json::obj(pairs.into_iter().map(|(k, v)| (k, Json::num(v))).collect()),
        ));
    }
    // §Perf gates are collected here and asserted only AFTER the JSON is on
    // disk, so a failed gate never loses the trend artifact CI collects
    let mut failures: Vec<String> = Vec::new();

    println!(
        "=== §Perf hot-path benchmarks (median of N, real wall time{}) ===\n",
        if smoke { ", SMOKE mode" } else { "" }
    );
    let mib = 1024 * 1024usize;
    let n = if smoke { 32 * mib } else { 256 * mib };
    let iters = if smoke { 3 } else { 9 };
    let mut rng = Rng::seed_from(1);
    let src: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
    let mut dst = vec![0u8; n];

    println!("XOR parity (RAIM5 encode/decode inner loop), {} MiB:", n / mib);
    let memcpy = bench("memcpy baseline", n, iters, || {
        dst.copy_from_slice(&src);
    });
    let xor_fast = bench("xor_into (word-unrolled)", n, iters, || {
        xor_into(&mut dst, &src);
    });
    let xor_par = bench("xor_into_parallel (striped threads)", n, iters, || {
        xor_into_parallel(&mut dst, &src);
    });
    let xor_slow = bench("xor_into_scalar (byte loop)", n, iters, || {
        xor_into_scalar(&mut dst, &src);
    });
    println!(
        "  -> word-unrolled/scalar: {:.2}x ; striped/serial: {:.2}x ; vs memcpy: {:.0}% (target >= 50%)\n",
        xor_fast / xor_slow,
        xor_par / xor_fast,
        xor_fast / memcpy * 100.0
    );
    rec(&mut report, "xor", vec![
        ("memcpy_gbps", memcpy),
        ("serial_gbps", xor_fast),
        ("parallel_gbps", xor_par),
        ("scalar_gbps", xor_slow),
    ]);
    // Both serial variants are memory-bound here: LLVM auto-vectorizes the
    // scalar loop too, so parity within 20% is expected; the real §Perf gate
    // is the RAID5 bound vs memcpy.
    if xor_fast < xor_slow * 0.8 {
        failures.push(format!(
            "word-unrolled XOR ({xor_fast:.2} GB/s) regressed far below the scalar loop ({xor_slow:.2} GB/s)"
        ));
    }
    if xor_fast < memcpy * 0.5 {
        failures.push(format!(
            "XOR parity ({xor_fast:.2} GB/s) below the RAID5 write-penalty bound (memcpy {memcpy:.2} GB/s)"
        ));
    }

    println!("tiny-bucket copy (snapshot d2h stand-in), {} MiB:", n / mib);
    let mut bucket_sections: Vec<(&str, f64)> = Vec::new();
    for (label, bucket) in [
        ("bucket_64k_gbps", 64 * 1024),
        ("bucket_1m_gbps", 1 << 20),
        ("bucket_16m_gbps", 16 << 20),
        ("bucket_all_gbps", n),
    ] {
        let pretty = format!("bucket = {} KiB", bucket / 1024);
        let g = bench(&pretty, n, if smoke { 3 } else { 5 }, || {
            copy_bucketed(&src, &mut dst, 0..n, bucket, |_| {});
        });
        bucket_sections.push((label, g));
    }
    rec(&mut report, "bucket_copy", bucket_sections);

    let ck = if smoke { 8 * mib } else { 64 * mib };
    println!("\ncheckpoint container encode (streaming CRC32 + frame), {} MiB payload:", ck / mib);
    let payload = src[..ck].to_vec();
    let enc = bench("CheckpointFile::encode", payload.len(), if smoke { 3 } else { 5 }, || {
        let mut f = reft::checkpoint::CheckpointFile::new("bench", 1);
        f.add_section(reft::checkpoint::SectionKind::StagePayload, 0, payload.clone());
        std::hint::black_box(f.encode());
    });
    rec(&mut report, "ckpt_encode", vec![("gbps", enc)]);

    let plen = if smoke { 12 * mib } else { 96 * mib };
    println!(
        "\nlive snapshot round (SMP channels + RAIM5 parity), {} MiB over 6 nodes:",
        plen / mib
    );
    let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let ft = FtConfig { bucket_bytes: 16 << 20, ..FtConfig::default() };
    let mut cluster = ReftCluster::start(topo, &[plen as u64], ft).unwrap();
    let payloads = vec![SharedPayload::copy_of(&src[..plen])];
    let snap = bench("snapshot_all (raim5 on)", plen, if smoke { 3 } else { 5 }, || {
        cluster.snapshot_all(&payloads).unwrap();
    });
    let rest = bench("restore_all (no loss)", plen, if smoke { 3 } else { 5 }, || {
        std::hint::black_box(cluster.restore_all(&[]).unwrap());
    });
    rec(&mut report, "snapshot_round", vec![
        ("snapshot_gbps", snap),
        ("restore_gbps", rest),
    ]);

    // Distributed in-memory restore, parallel vs serial, at the default
    // multi-stage/multi-node shape (paper Fig. 3: 2 DP x 4 TP x 3 PP on 6
    // nodes — three SGs gathering concurrently, shards fetched in parallel
    // within each SG, decode straight into the stitched buffer).
    let stage_mib = if smoke { 8 } else { 48 };
    println!(
        "\ndistributed in-memory restore, serial vs parallel \
         (3 stages x {stage_mib} MiB over 6 nodes, one node decoded):"
    );
    let topo3 = Topology::build(ParallelPlan::new(2, 4, 3), 6, 4).unwrap();
    let stage_bytes = vec![(stage_mib * mib) as u64; 3];
    let ft3 = FtConfig { bucket_bytes: 16 << 20, ..FtConfig::default() };
    let mut c3 = ReftCluster::start(topo3, &stage_bytes, ft3).unwrap();
    let data3: Vec<SharedPayload> = (0..3)
        .map(|i| SharedPayload::copy_of(&src[i * stage_mib * mib..(i + 1) * stage_mib * mib]))
        .collect();
    c3.snapshot_all(&data3).unwrap();
    let total3 = 3 * stage_mib * mib;
    let restore_iters = if smoke { 3 } else { 5 };
    let ser_clean = bench("restore_all_serial (no loss)", total3, restore_iters, || {
        std::hint::black_box(c3.restore_all_serial(&[]).unwrap());
    });
    let par_clean = bench("restore_all parallel (no loss)", total3, restore_iters, || {
        std::hint::black_box(c3.restore_all(&[]).unwrap());
    });
    c3.kill_node(4);
    let ser_decode = bench("restore_all_serial (1 node decoded)", total3, restore_iters, || {
        std::hint::black_box(c3.restore_all_serial(&[4]).unwrap());
    });
    let par_decode = bench("restore_all parallel (1 node decoded)", total3, restore_iters, || {
        std::hint::black_box(c3.restore_all(&[4]).unwrap());
    });
    println!(
        "  -> parallel/serial: {:.2}x clean, {:.2}x decode (must be > 1x)\n",
        par_clean / ser_clean,
        par_decode / ser_decode
    );
    rec(&mut report, "restore", vec![
        ("serial_clean_gbps", ser_clean),
        ("parallel_clean_gbps", par_clean),
        ("serial_decode_gbps", ser_decode),
        ("parallel_decode_gbps", par_decode),
        ("clean_speedup", par_clean / ser_clean),
        ("decode_speedup", par_decode / ser_decode),
    ]);
    if par_clean <= ser_clean {
        failures.push(format!(
            "parallel restore_all ({par_clean:.2} GB/s) must beat the serial \
             baseline ({ser_clean:.2} GB/s) at the default bench shape"
        ));
    }
    if par_decode <= ser_decode {
        failures.push(format!(
            "parallel decode restore ({par_decode:.2} GB/s) must beat the serial \
             baseline ({ser_decode:.2} GB/s)"
        ));
    }

    // The figure-9 story, live: per-iteration stall the save path adds to a
    // training loop, blocking vs the hierarchical async coordinator, at
    // EQUAL bucket size. Since the zero-copy payload refactor, neither path
    // copies payload bytes in-caller, so the stall is pure coordination
    // traffic: the blocking path issues EVERY bucket send inside the
    // iteration, the coordinator issues at most its per-node tick budget.
    // RAIM5 is off here to isolate that drain-interference story — parity
    // is L3 completion-time work and identical for both flavours (it is
    // measured, raim5 on, in the snapshot-round section above). The budget
    // is sized so the async round completes within the snapshot interval
    // (DESIGN.md budget sizing rule), so both flavours move every bucket.
    println!(
        "per-iteration save stall, sync vs async coordinator \
         ({} MiB over 6 nodes, 64 KiB buckets, snapshot every 5 iters):",
        plen / mib
    );
    let iters = 20usize;
    let interval = 5usize;
    let node_buckets = plen / 6 / (64 * 1024); // buckets per node per round
    let mk_cluster = |async_on: bool| {
        let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
        let ft = FtConfig {
            bucket_bytes: 64 * 1024,
            raim5: false,
            async_snapshot: async_on,
            drain_buckets_per_tick: node_buckets.div_ceil(interval - 1),
            ..FtConfig::default()
        };
        ReftCluster::start(topo, &[plen as u64], ft).unwrap()
    };
    let stall_run = |label: &str, async_on: bool| -> (f64, f64) {
        let mut cluster = mk_cluster(async_on);
        let (mut max_stall, mut total) = (0f64, 0f64);
        for it in 0..iters {
            let t0 = Instant::now();
            if it % interval == 0 {
                if async_on {
                    cluster.request_snapshot(payloads.clone()).unwrap();
                } else {
                    cluster.snapshot_all_blocking(&payloads).unwrap();
                }
            }
            if async_on {
                cluster.tick().unwrap();
            }
            let stall = t0.elapsed().as_secs_f64();
            max_stall = max_stall.max(stall);
            total += stall;
        }
        let mean = total / iters as f64;
        println!(
            "  {label:<38} max {:>8.3} ms/iter   mean {:>8.3} ms/iter",
            max_stall * 1e3,
            mean * 1e3
        );
        (max_stall, mean)
    };
    let (sync_stall, sync_mean) = stall_run("blocking snapshot_all (CheckFreq-shape)", false);
    let (async_stall, async_mean) = stall_run("coordinator enqueue + tick (REFT-Sn)", true);
    println!(
        "  -> async worst-case stall = {:.0}% of blocking (lower is better)\n",
        async_stall / sync_stall * 100.0
    );
    rec(&mut report, "save_stall", vec![
        ("blocking_max_ms", sync_stall * 1e3),
        ("blocking_mean_ms", sync_mean * 1e3),
        ("async_max_ms", async_stall * 1e3),
        ("async_mean_ms", async_mean * 1e3),
    ]);
    if async_stall >= sync_stall {
        failures.push(format!(
            "async per-iteration stall ({async_stall:.4}s) must be strictly lower \
             than blocking ({sync_stall:.4}s) at equal bucket size"
        ));
    }

    // Observability overhead — the "near-zero overhead" claim, measured:
    // the SAME async save path as above with the span tracer off vs on
    // (when on, every iteration records coordinator/SMP spans + instants
    // into the per-thread rings). Min-of-3 per flavour; the tracer costs
    // nanoseconds per event, so the gate is 1% relative with a 2 ms
    // absolute floor so scheduler noise can never decide it. The traced
    // run's per-iteration stalls feed a log2-bucket histogram, so this
    // section also publishes the stall distribution (p50/p99) the paper's
    // near-zero claim is about, and the traced event stream lands in
    // BENCH_trace.json (override: BENCH_TRACE_JSON) as the CI artifact.
    println!(
        "observability overhead, span tracer off vs on (async save path, {iters} iters):"
    );
    let obs_metrics = Metrics::new();
    let obs_run = |m: Option<&Metrics>| -> f64 {
        let mut cluster = mk_cluster(true);
        let mut total = 0f64;
        for it in 0..iters {
            let t0 = Instant::now();
            if it % interval == 0 {
                cluster.request_snapshot(payloads.clone()).unwrap();
            }
            cluster.tick().unwrap();
            let stall = t0.elapsed().as_secs_f64();
            if let Some(m) = m {
                m.record_secs_k(keys::SNAPSHOT_TICK, stall);
            }
            total += stall;
        }
        total
    };
    reft::obs::disable();
    let obs_off_s = (0..3).map(|_| obs_run(None)).fold(f64::MAX, f64::min);
    reft::obs::enable();
    let obs_on_s = (0..3)
        .map(|_| obs_run(Some(&obs_metrics)))
        .fold(f64::MAX, f64::min);
    let obs_dump = reft::obs::drain();
    reft::obs::disable();
    let trace_path = std::env::var("BENCH_TRACE_JSON")
        .unwrap_or_else(|_| "BENCH_trace.json".to_string());
    std::fs::write(&trace_path, reft::obs::chrome_trace_json(&obs_dump))
        .expect("writing bench trace");
    let tick_p50 = obs_metrics.timer_quantile("snapshot_tick", 0.50);
    let tick_p99 = obs_metrics.timer_quantile("snapshot_tick", 0.99);
    println!(
        "  tracer off                             {:>8.3} ms total",
        obs_off_s * 1e3
    );
    println!(
        "  tracer on                              {:>8.3} ms total ({:+.2}% overhead, gate < 1% + 2 ms)",
        obs_on_s * 1e3,
        (obs_on_s / obs_off_s - 1.0) * 100.0
    );
    println!(
        "  traced stall p50 {:.3} ms / p99 {:.3} ms; {} events ({} dropped) -> {trace_path}\n",
        tick_p50 * 1e3,
        tick_p99 * 1e3,
        obs_dump.events.len(),
        obs_dump.dropped
    );
    rec(&mut report, "obs_overhead", vec![
        ("off_s", obs_off_s),
        ("on_s", obs_on_s),
        ("overhead_ratio", obs_on_s / obs_off_s),
        ("stall_p50_ms", tick_p50 * 1e3),
        ("stall_p99_ms", tick_p99 * 1e3),
        ("events", obs_dump.events.len() as f64),
        ("dropped", obs_dump.dropped as f64),
    ]);
    if obs_dump.events.is_empty() {
        failures.push("traced async save path recorded no span events".to_string());
    }
    if obs_on_s > obs_off_s * 1.01 + 0.002 {
        failures.push(format!(
            "tracing-on async save path ({obs_on_s:.4}s) exceeded tracing-off \
             ({obs_off_s:.4}s) by more than the 1% + 2 ms observability budget"
        ));
    }

    // REFT-Ckpt durable tier (§6.1 "an SMP-driven persist to cloud that
    // never blocks training"): trainer-thread cost of one persist event,
    // inline encode+put (the pre-engine behaviour) vs an enqueue to the
    // background persistence engine. The engine's writer workers pull clean
    // shards from the SMPs and commit an atomic manifest off-thread, so the
    // training-side stall must be strictly below the inline baseline.
    println!(
        "durable persist, inline put vs background engine ({} MiB over 6 nodes):",
        plen / mib
    );
    let events = if smoke { 3 } else { 5 };
    let mut cluster_p = mk_cluster(false);
    cluster_p.snapshot_all_blocking(&payloads).unwrap();
    let inline_store = Arc::new(MemStorage::new());
    let (mut inline_max, mut inline_total) = (0f64, 0f64);
    for i in 0..events {
        let t0 = Instant::now();
        let mut f = CheckpointFile::new("bench-inline", (i + 1) as u64);
        f.add_section(SectionKind::StagePayload, 0, payloads[0].as_slice().to_vec());
        inline_store
            .put(&step_key("bench-inline", (i + 1) as u64), &f.encode())
            .unwrap();
        let dt = t0.elapsed().as_secs_f64();
        inline_max = inline_max.max(dt);
        inline_total += dt;
    }
    let engine_store = Arc::new(MemStorage::new());
    let engine = PersistEngine::start(
        "bench-engine",
        Arc::clone(&engine_store),
        cluster_p.plan.clone(),
        PersistConfig {
            enabled: true,
            throttle_bytes_per_sec: 0,
            chunk_bytes: 1 << 20,
            ..PersistConfig::default()
        },
    );
    let (mut engine_max, mut engine_total) = (0f64, 0f64);
    for i in 0..events {
        let t0 = Instant::now();
        engine
            .enqueue((i + 1) as u64, cluster_p.persist_sources(), vec![])
            .unwrap();
        let dt = t0.elapsed().as_secs_f64();
        engine_max = engine_max.max(dt);
        engine_total += dt;
    }
    engine.flush().unwrap(); // shutdown barrier, off the measured path
    let pstats = engine.stats();
    assert_eq!(
        pstats.manifests_committed as usize, events,
        "engine must commit every round: {:?}",
        pstats.last_error
    );
    // sanity: the durable copy is complete and byte-identical
    let (_, persisted_stages) =
        persist::load_latest(engine_store.as_ref(), "bench-engine")
            .unwrap()
            .expect("committed manifest resolves");
    assert_eq!(persisted_stages[0], payloads[0].as_slice());
    println!(
        "  inline encode+put                      max {:>8.3} ms/event   mean {:>8.3} ms/event",
        inline_max * 1e3,
        inline_total / events as f64 * 1e3
    );
    println!(
        "  engine enqueue (background drain)      max {:>8.3} ms/event   mean {:>8.3} ms/event",
        engine_max * 1e3,
        engine_total / events as f64 * 1e3
    );
    println!(
        "  -> engine trainer-thread stall = {:.2}% of inline (lower is better)\n",
        engine_total / inline_total * 100.0
    );
    rec(&mut report, "persist_async_vs_inline", vec![
        ("inline_max_ms", inline_max * 1e3),
        ("inline_mean_ms", inline_total / events as f64 * 1e3),
        ("engine_max_ms", engine_max * 1e3),
        ("engine_mean_ms", engine_total / events as f64 * 1e3),
        ("stall_ratio", engine_total / inline_total),
    ]);
    if engine_total >= inline_total {
        failures.push(format!(
            "persist engine trainer-thread stall ({engine_total:.4}s) must be strictly \
             below the inline encode+put baseline ({inline_total:.4}s)"
        ));
    }

    // Pipelined multi-job engine vs the sequential baseline: the same 4
    // persist jobs drained against a latency-injected remote store (each
    // put pays a modeled RTT — that latency, not local memcpy, is what the
    // durable tier actually hides). Depth 1 is the pre-pipeline engine:
    // one job's uploads fully serialize behind the previous job's. Depth 3
    // overlaps fetch/upload across jobs while the commit turnstile keeps
    // manifests landing in enqueue order, so the queue must drain strictly
    // faster.
    let put_ms = 5u64;
    let pipe_jobs = 4u64;
    println!(
        "pipelined persist engine vs sequential ({pipe_jobs} jobs, {} MiB over 6 nodes, \
         {put_ms} ms/put modeled RTT):",
        plen / mib
    );
    let drain = |depth: usize| -> f64 {
        let store: Arc<dyn Storage> = Arc::new(LatencyStorage::new(
            MemStorage::new(),
            Duration::from_millis(put_ms),
            Duration::ZERO,
        ));
        let engine = PersistEngine::start(
            "bench-pipe",
            Arc::clone(&store),
            cluster_p.plan.clone(),
            PersistConfig {
                enabled: true,
                throttle_bytes_per_sec: 0,
                chunk_bytes: 1 << 20,
                keep_last: 8, // retain all 4 jobs: GC deletes would distort the drain time
                pipeline_jobs: depth,
                multipart_part_bytes: 0,
                ..PersistConfig::default()
            },
        );
        let t0 = Instant::now();
        for j in 0..pipe_jobs {
            engine
                .enqueue((j + 1) * 10, cluster_p.persist_sources(), vec![])
                .unwrap();
        }
        engine.flush().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        let st = engine.stats();
        assert_eq!(
            st.manifests_committed, pipe_jobs,
            "every job must commit: {:?}",
            st.last_error
        );
        dt
    };
    // best-of-2 per flavour: the drain is latency-dominated, so one stray
    // scheduler hiccup must not decide the gate
    let seq_s = drain(1).min(drain(1));
    let pipe_s = drain(3).min(drain(3));
    println!(
        "  sequential (depth 1)                   {:>8.1} ms queue drain",
        seq_s * 1e3
    );
    println!(
        "  pipelined  (depth 3)                   {:>8.1} ms queue drain",
        pipe_s * 1e3
    );
    println!("  -> pipelined/sequential: {:.2}x faster (must be > 1x)\n", seq_s / pipe_s);
    rec(&mut report, "persist_pipelined_vs_sequential", vec![
        ("sequential_s", seq_s),
        ("pipelined_s", pipe_s),
        ("speedup", seq_s / pipe_s),
        ("jobs", pipe_jobs as f64),
        ("put_latency_ms", put_ms as f64),
    ]);
    if pipe_s >= seq_s {
        failures.push(format!(
            "pipelined persist drain ({pipe_s:.4}s) must be strictly faster than the \
             sequential baseline ({seq_s:.4}s) for >= 2 queued jobs"
        ));
    }

    // Adaptive pipeline depth vs the static depths it chooses between: the
    // same latency-injected queue drained at static depth 1, static depth
    // 3, and with the EWMA controller picking the depth live (starting at
    // the max, shrinking only when uploads are too cheap to overlap). With
    // RTT-dominated puts the controller must keep the pipeline deep —
    // asserted no slower than the best static depth (with slack for the
    // first job's learning observation) and strictly faster than the
    // sequential engine.
    println!(
        "adaptive pipeline depth vs static ({pipe_jobs} jobs, {} MiB over 6 nodes, \
         {put_ms} ms/put modeled RTT):",
        plen / mib
    );
    let drain_cfg = |depth: usize, adaptive: bool| -> (f64, usize) {
        let store: Arc<dyn Storage> = Arc::new(LatencyStorage::new(
            MemStorage::new(),
            Duration::from_millis(put_ms),
            Duration::ZERO,
        ));
        let engine = PersistEngine::start(
            "bench-adaptive",
            Arc::clone(&store),
            cluster_p.plan.clone(),
            PersistConfig {
                enabled: true,
                throttle_bytes_per_sec: 0,
                chunk_bytes: 1 << 20,
                keep_last: 8,
                pipeline_jobs: depth,
                multipart_part_bytes: 0,
                adaptive_depth: adaptive,
                ..PersistConfig::default()
            },
        );
        let t0 = Instant::now();
        for j in 0..pipe_jobs {
            engine
                .enqueue((j + 1) * 10, cluster_p.persist_sources(), vec![])
                .unwrap();
        }
        engine.flush().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        let st = engine.stats();
        assert_eq!(
            st.manifests_committed, pipe_jobs,
            "every job must commit: {:?}",
            st.last_error
        );
        (dt, engine.pipeline_depth())
    };
    let static1_s = drain_cfg(1, false).0.min(drain_cfg(1, false).0);
    let static3_s = drain_cfg(3, false).0.min(drain_cfg(3, false).0);
    let (a1, depth1) = drain_cfg(3, true);
    let (a2, depth2) = drain_cfg(3, true);
    let adaptive_s = a1.min(a2);
    let final_depth = if a1 <= a2 { depth1 } else { depth2 };
    let best_static = static1_s.min(static3_s);
    println!(
        "  static depth 1                         {:>8.1} ms queue drain",
        static1_s * 1e3
    );
    println!(
        "  static depth 3                         {:>8.1} ms queue drain",
        static3_s * 1e3
    );
    println!(
        "  adaptive (max 3)                       {:>8.1} ms queue drain, settled depth {final_depth}",
        adaptive_s * 1e3
    );
    rec(&mut report, "persist_adaptive_depth", vec![
        ("static1_s", static1_s),
        ("static3_s", static3_s),
        ("adaptive_s", adaptive_s),
        ("best_static_s", best_static),
        ("final_depth", final_depth as f64),
        ("put_latency_ms", put_ms as f64),
    ]);
    if adaptive_s >= static1_s {
        failures.push(format!(
            "adaptive depth drain ({adaptive_s:.4}s) must beat the sequential \
             engine ({static1_s:.4}s) under RTT-dominated uploads"
        ));
    }
    if adaptive_s > best_static * 1.30 {
        failures.push(format!(
            "adaptive depth drain ({adaptive_s:.4}s) must be no slower than the best \
             static depth ({best_static:.4}s, +30% learning slack)"
        ));
    }
    if final_depth < 2 {
        failures.push(format!(
            "with {put_ms} ms/put RTT the controller must converge to a deep \
             pipeline, not depth {final_depth}"
        ));
    }

    // Parallel sharded manifest load vs the serial baseline: the
    // checkpoint-fallback restart path. One multipart manifest (4 parts
    // per shard) against a latency-injected remote store; the parallel
    // gather overlaps the per-part RTTs and the CRC verification across
    // scoped threads, stitching straight into the pre-allocated stage
    // buffers, and must be strictly faster than the serial read loop.
    let get_ms = 2u64;
    println!(
        "durable manifest load, serial vs parallel gather ({} MiB over 6 nodes, \
         multipart, {get_ms} ms/get modeled RTT):",
        plen / mib
    );
    let load_store: Arc<dyn Storage> = Arc::new(LatencyStorage::new(
        MemStorage::new(),
        Duration::ZERO,
        Duration::from_millis(get_ms),
    ));
    let load_engine = PersistEngine::start(
        "bench-load",
        Arc::clone(&load_store),
        cluster_p.plan.clone(),
        PersistConfig {
            enabled: true,
            throttle_bytes_per_sec: 0,
            chunk_bytes: 1 << 20,
            multipart_part_bytes: (plen / 6 / 4).max(4096),
            ..PersistConfig::default()
        },
    );
    load_engine
        .enqueue(10, cluster_p.persist_sources(), vec![])
        .unwrap();
    load_engine.flush().unwrap();
    assert_eq!(
        load_engine.stats().manifests_committed, 1,
        "bench manifest must commit: {:?}",
        load_engine.stats().last_error
    );
    let man = persist::PersistManifest::decode(
        &load_store.get(&persist::manifest_key("bench-load", 10)).unwrap(),
    )
    .unwrap();
    assert!(
        man.shards.iter().all(|s| s.parts.len() >= 2),
        "bench shape must exercise the multipart layout"
    );
    let load_iters = if smoke { 3 } else { 5 };
    let load_ser = bench("load_manifest_payload_serial", plen, load_iters, || {
        std::hint::black_box(
            persist::load_manifest_payload_serial(load_store.as_ref(), &man).unwrap(),
        );
    });
    let load_par = bench("load_manifest_payload (parallel)", plen, load_iters, || {
        std::hint::black_box(
            persist::load_manifest_payload(load_store.as_ref(), &man).unwrap(),
        );
    });
    println!("  -> parallel/serial: {:.2}x (must be > 1x)\n", load_par / load_ser);
    // byte identity against the serial oracle, while both are at hand
    assert_eq!(
        persist::load_manifest_payload(load_store.as_ref(), &man).unwrap(),
        persist::load_manifest_payload_serial(load_store.as_ref(), &man).unwrap(),
        "parallel manifest load diverged from the serial oracle"
    );
    rec(&mut report, "manifest_load_parallel_vs_serial", vec![
        ("serial_gbps", load_ser),
        ("parallel_gbps", load_par),
        ("speedup", load_par / load_ser),
        ("get_latency_ms", get_ms as f64),
    ]);
    if load_par <= load_ser {
        failures.push(format!(
            "parallel manifest load ({load_par:.2} GB/s) must be strictly faster than \
             the serial baseline ({load_ser:.2} GB/s)"
        ));
    }

    // Bounded in-node part-upload pool vs the serial part loop: the same
    // single-job multipart drain against a latency-injected store. The
    // per-part RTT, not local memcpy, dominates a real remote upload; the
    // pool overlaps those RTTs within each shard (parts still land in the
    // manifest in k-order, proven in ft_integration), so the drain must be
    // strictly faster than the one-part-at-a-time lane.
    let part_put_ms = 4u64;
    println!(
        "multipart part uploads, serial lane vs bounded pool ({} MiB over 6 nodes, \
         8 parts/shard, {part_put_ms} ms/put modeled RTT):",
        plen / mib
    );
    let drain_parts = |streams: usize| -> f64 {
        let store: Arc<dyn Storage> = Arc::new(LatencyStorage::new(
            MemStorage::new(),
            Duration::from_millis(part_put_ms),
            Duration::ZERO,
        ));
        let engine = PersistEngine::start(
            "bench-parts",
            Arc::clone(&store),
            cluster_p.plan.clone(),
            PersistConfig {
                enabled: true,
                throttle_bytes_per_sec: 0,
                chunk_bytes: 1 << 20,
                pipeline_jobs: 1,
                multipart_part_bytes: (plen / 6 / 8).max(4096),
                multipart_streams: streams,
                ..PersistConfig::default()
            },
        );
        let t0 = Instant::now();
        engine
            .enqueue(10, cluster_p.persist_sources(), vec![])
            .unwrap();
        engine.flush().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        let st = engine.stats();
        assert_eq!(st.manifests_committed, 1, "{:?}", st.last_error);
        assert_eq!(st.parts_uploaded, 6 * 8, "bench shape must be 8 parts/shard");
        dt
    };
    // best-of-2 per flavour: latency-dominated, one hiccup must not gate
    let parts_serial_s = drain_parts(1).min(drain_parts(1));
    let parts_pooled_s = drain_parts(4).min(drain_parts(4));
    println!(
        "  serial lane (1 stream)                 {:>8.1} ms shard drain",
        parts_serial_s * 1e3
    );
    println!(
        "  bounded pool (4 streams)               {:>8.1} ms shard drain",
        parts_pooled_s * 1e3
    );
    println!(
        "  -> pooled/serial: {:.2}x faster (must be > 1x)\n",
        parts_serial_s / parts_pooled_s
    );
    rec(&mut report, "multipart_parallel_parts", vec![
        ("serial_s", parts_serial_s),
        ("parallel_s", parts_pooled_s),
        ("speedup", parts_serial_s / parts_pooled_s),
        ("streams", 4.0),
        ("put_latency_ms", part_put_ms as f64),
    ]);
    if parts_pooled_s >= parts_serial_s {
        failures.push(format!(
            "pooled part uploads ({parts_pooled_s:.4}s) must be strictly faster than \
             the serial part lane ({parts_serial_s:.4}s) under RTT-dominated puts"
        ));
    }

    // Streaming manifest codec vs the DOM round-trip it replaced: the
    // commit/restore metadata path at a big part count. The streaming
    // writer emits bytes straight into the output buffer and the streaming
    // parser walks the text without ever building the intermediate `Json`
    // tree; byte identity with the DOM oracle is asserted inline.
    let codec_shards = if smoke { 192 } else { 768 };
    println!(
        "manifest codec, streaming vs DOM round-trip ({codec_shards} shards x 16 parts):"
    );
    let mut big = persist::PersistManifest {
        model: "bench-codec".into(),
        step: 120,
        version: 12,
        snapshot_step: 115,
        base_step: None,
        stage_bytes: vec![plen as u64; 3],
        shards: Vec::new(),
        atoms: Vec::new(),
    };
    for i in 0..codec_shards {
        big.shards.push(persist::ShardEntry {
            key: persist::shard_key("bench-codec", 120, i % 3, i),
            stage: i % 3,
            node: i,
            offset: (i as u64) << 20,
            len: 1 << 20,
            crc32: 0x9E37_79B9u32.wrapping_mul(i as u32 + 1),
            extents: vec![],
            parts: (0..16)
                .map(|p| persist::PartEntry {
                    key: persist::part_key("bench-codec", 120, i % 3, i, p),
                    len: 64 * 1024,
                    crc32: 0x85EB_CA6Bu32.wrapping_mul((i * 16 + p) as u32 + 1),
                })
                .collect(),
        });
    }
    let codec_text = big.encode();
    assert_eq!(
        codec_text,
        big.encode_dom(),
        "streaming manifest codec must be byte-identical to the DOM oracle"
    );
    assert_eq!(
        persist::PersistManifest::decode(&codec_text).unwrap(),
        big,
        "streaming parse must round-trip"
    );
    let codec_iters = if smoke { 20 } else { 60 };
    let codec_dom = bench("DOM encode+decode (baseline)", codec_text.len(), codec_iters, || {
        let text = big.encode_dom();
        std::hint::black_box(persist::PersistManifest::decode_dom(&text).unwrap());
    });
    let codec_stream = bench("streaming encode+decode", codec_text.len(), codec_iters, || {
        let text = big.encode();
        std::hint::black_box(persist::PersistManifest::decode(&text).unwrap());
    });
    println!(
        "  -> streaming/DOM: {:.2}x (must be > 1x)\n",
        codec_stream / codec_dom
    );
    rec(&mut report, "manifest_streaming_vs_dom", vec![
        ("dom_gbps", codec_dom),
        ("streaming_gbps", codec_stream),
        ("speedup", codec_stream / codec_dom),
        ("manifest_bytes", codec_text.len() as f64),
    ]);
    if codec_stream <= codec_dom {
        failures.push(format!(
            "streaming manifest codec ({codec_stream:.3} GB/s) must be strictly faster \
             than the DOM round-trip ({codec_dom:.3} GB/s)"
        ));
    }

    // Fused CRC restore vs the separate-verify loader it replaced: the same
    // committed multipart manifest on a plain in-memory store (no modeled
    // RTT — this gate is about CPU passes, not latency hiding). The
    // separate loader copies each part, re-hashes it, then naively re-hashes
    // the whole stitched shard for the shard-level check — two hash passes
    // per byte. The fused loader hashes in the same pass that fills the
    // buffer and folds the part CRCs into the shard check via GF(2) combine
    // — one pass per byte, so it must be strictly faster.
    println!(
        "durable restore verify, separate vs fused CRC ({} MiB over 6 nodes, multipart):",
        plen / mib
    );
    let fused_store: Arc<dyn Storage> = Arc::new(MemStorage::new());
    let fused_engine = PersistEngine::start(
        "bench-fused",
        Arc::clone(&fused_store),
        cluster_p.plan.clone(),
        PersistConfig {
            enabled: true,
            throttle_bytes_per_sec: 0,
            chunk_bytes: 1 << 20,
            multipart_part_bytes: (plen / 6 / 4).max(4096),
            ..PersistConfig::default()
        },
    );
    fused_engine
        .enqueue(10, cluster_p.persist_sources(), vec![])
        .unwrap();
    fused_engine.flush().unwrap();
    assert_eq!(
        fused_engine.stats().manifests_committed, 1,
        "fused-restore bench manifest must commit: {:?}",
        fused_engine.stats().last_error
    );
    let fused_man = persist::PersistManifest::decode(
        &fused_store
            .get(&persist::manifest_key("bench-fused", 10))
            .unwrap(),
    )
    .unwrap();
    let verify_sep = bench("separate verify (hash after copy)", plen, load_iters, || {
        std::hint::black_box(
            persist::load_manifest_payload_separate(fused_store.as_ref(), &fused_man)
                .unwrap(),
        );
    });
    let verify_fused = bench("fused verify (hash in copy + combine)", plen, load_iters, || {
        std::hint::black_box(
            persist::load_manifest_payload(fused_store.as_ref(), &fused_man).unwrap(),
        );
    });
    println!(
        "  -> fused/separate: {:.2}x (must be > 1x)\n",
        verify_fused / verify_sep
    );
    // byte identity against the separate-verify oracle, while both at hand
    assert_eq!(
        persist::load_manifest_payload(fused_store.as_ref(), &fused_man).unwrap(),
        persist::load_manifest_payload_separate(fused_store.as_ref(), &fused_man).unwrap(),
        "fused restore diverged from the separate-verify oracle"
    );
    rec(&mut report, "crc_fused_restore", vec![
        ("separate_gbps", verify_sep),
        ("fused_gbps", verify_fused),
        ("speedup", verify_fused / verify_sep),
    ]);
    if verify_fused <= verify_sep {
        failures.push(format!(
            "fused-CRC restore ({verify_fused:.2} GB/s) must be strictly faster than \
             the separate-verify loader ({verify_sep:.2} GB/s)"
        ));
    }

    // Sparse delta snapshots (PR 7): ship only changed bytes, end to end.
    // A delta-enabled cluster+engine twin runs one base round plus four
    // 10%-extent-churn rounds against a full-capture twin. Gates: (a) at
    // 10% churn the SMP plane enqueues AND the durable plane persists
    // < 25% of the full baseline's bytes; (b) the 4-deep delta chain
    // restores byte-identical to the full-capture oracle; (c) at 100%
    // churn the delta path's wall time is within 10% of full capture
    // (the planner degrades to Full, the engine uploads shard bytes
    // directly and collapses the manifest to a fresh base).
    let dsz = if smoke { 8 * mib } else { 48 * mib };
    let dext = 64 * 1024usize;
    let churn_len = dsz / 10 / dext * dext; // ~10% of the payload, extent-aligned
    println!(
        "sparse delta snapshots ({} MiB over 6 nodes, {} KiB extents, 10% churn/round):",
        dsz / mib,
        dext / 1024
    );
    let mk_delta_cluster = |delta: bool| -> ReftCluster {
        let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
        let ft = FtConfig {
            bucket_bytes: 16 << 20,
            delta_extent_bytes: if delta { dext } else { 0 },
            delta_chain_max: 16,
            ..FtConfig::default()
        };
        ReftCluster::start(topo, &[dsz as u64], ft).unwrap()
    };
    let mk_delta_engine = |name: &str, store: &Arc<MemStorage>, cl: &ReftCluster, delta: bool| {
        PersistEngine::start(
            name,
            Arc::clone(store) as Arc<dyn Storage>,
            cl.plan.clone(),
            PersistConfig {
                enabled: true,
                throttle_bytes_per_sec: 0,
                chunk_bytes: 1 << 20,
                keep_last: 8,
                delta_extent_bytes: if delta { dext } else { 0 },
                delta_chain_max: 16,
                ..PersistConfig::default()
            },
        )
    };
    let mut d_cluster = mk_delta_cluster(true);
    let mut f_cluster = mk_delta_cluster(false);
    let d_store = Arc::new(MemStorage::new());
    let f_store = Arc::new(MemStorage::new());
    let d_engine = mk_delta_engine("bench-delta-d", &d_store, &d_cluster, true);
    let f_engine = mk_delta_engine("bench-delta-f", &f_store, &f_cluster, false);
    let mut d_master = src[..dsz].to_vec();
    for round in 0..5u64 {
        if round > 0 {
            // rounds 1..4 churn one fresh extent-aligned 10% region each
            let start = (round as usize - 1) * 2 * churn_len;
            for b in &mut d_master[start..start + churn_len] {
                *b ^= 0x5A;
            }
        }
        let p = vec![SharedPayload::new(d_master.clone())];
        d_cluster.snapshot_all(&p).unwrap();
        f_cluster.snapshot_all(&p).unwrap();
        let step = 10 * (round + 1);
        d_engine.enqueue(step, d_cluster.persist_sources(), vec![]).unwrap();
        f_engine.enqueue(step, f_cluster.persist_sources(), vec![]).unwrap();
        d_engine.flush().unwrap();
        f_engine.flush().unwrap();
    }
    let d_stats = d_engine.stats();
    let f_stats = f_engine.stats();
    assert_eq!(d_stats.manifests_committed, 5, "{:?}", d_stats.last_error);
    assert_eq!(d_stats.jobs_aborted, 0, "{:?}", d_stats.last_error);
    assert_eq!(f_stats.persisted_bytes, 5 * dsz as u64, "full twin ships the model each round");
    // SMP plane: the planner's shipped bytes for the four sparse rounds vs
    // the full twin's four payloads
    let ds = d_cluster.delta_stats().unwrap();
    let smp_delta = ds.shipped_bytes - dsz as u64; // minus the base round
    let smp_ratio = smp_delta as f64 / (4 * dsz) as f64;
    // durable plane: delta bytes persisted vs four full captures
    let persist_ratio = d_stats.persisted_delta_bytes as f64 / (4 * dsz) as f64;
    println!(
        "  SMP-enqueued delta bytes               {:>8.1}% of full baseline (gate < 25%)",
        smp_ratio * 100.0
    );
    println!(
        "  persisted delta bytes                  {:>8.1}% of full baseline (gate < 25%)",
        persist_ratio * 100.0
    );
    // 4-deep chain restore == the full-capture oracle == the live payload
    let (d_man, d_stages) = persist::load_latest(d_store.as_ref(), "bench-delta-d")
        .unwrap()
        .expect("delta chain resolves");
    let (f_man, f_stages) = persist::load_latest(f_store.as_ref(), "bench-delta-f")
        .unwrap()
        .expect("full twin resolves");
    assert_eq!((d_man.step, f_man.step), (50, 50));
    assert_eq!(d_man.base_step, Some(40), "four-deep chain tip links to its predecessor");
    assert_eq!(d_stages, f_stages, "delta chain diverged from the full-capture oracle");
    assert_eq!(d_stages[0], d_master, "restore diverged from the live payload");
    // 100% churn: every byte changes every round; fresh twins, best-of-2
    let full_churn_run = |delta: bool, tag: &str| -> f64 {
        let mut cluster = mk_delta_cluster(delta);
        let store = Arc::new(MemStorage::new());
        let engine = mk_delta_engine(tag, &store, &cluster, delta);
        let mut m = src[..dsz].to_vec();
        let mut total = 0f64;
        for round in 0..3u64 {
            for b in &mut m {
                *b = b.wrapping_add(1);
            }
            let p = vec![SharedPayload::new(m.clone())];
            let t0 = Instant::now();
            cluster.snapshot_all(&p).unwrap();
            engine.enqueue(10 * (round + 1), cluster.persist_sources(), vec![]).unwrap();
            engine.flush().unwrap();
            total += t0.elapsed().as_secs_f64();
        }
        assert_eq!(engine.stats().manifests_committed, 3, "{:?}", engine.stats().last_error);
        total
    };
    let churn_full_s = full_churn_run(false, "bench-churn-f").min(full_churn_run(false, "bench-churn-f2"));
    let churn_delta_s = full_churn_run(true, "bench-churn-d").min(full_churn_run(true, "bench-churn-d2"));
    println!(
        "  100% churn, full capture               {:>8.1} ms / 3 rounds",
        churn_full_s * 1e3
    );
    println!(
        "  100% churn, delta path                 {:>8.1} ms / 3 rounds ({:.0}% of full, gate <= 110%)\n",
        churn_delta_s * 1e3,
        churn_delta_s / churn_full_s * 100.0
    );
    rec(&mut report, "sparse_delta_bytes", vec![
        ("smp_delta_ratio", smp_ratio),
        ("persist_delta_ratio", persist_ratio),
        ("chain_depth", 4.0),
        ("full_churn_full_s", churn_full_s),
        ("full_churn_delta_s", churn_delta_s),
        ("full_churn_overhead", churn_delta_s / churn_full_s),
        ("extent_bytes", dext as f64),
    ]);
    if smp_ratio >= 0.25 {
        failures.push(format!(
            "sparse delta SMP plane shipped {:.1}% of the full baseline at 10% churn \
             (gate < 25%)",
            smp_ratio * 100.0
        ));
    }
    if persist_ratio >= 0.25 {
        failures.push(format!(
            "sparse delta durable plane persisted {:.1}% of the full baseline at 10% \
             churn (gate < 25%)",
            persist_ratio * 100.0
        ));
    }
    if churn_delta_s > churn_full_s * 1.10 {
        failures.push(format!(
            "100%-churn delta path ({churn_delta_s:.4}s) must be no slower than full \
             capture ({churn_full_s:.4}s) + 10%"
        ));
    }

    // Recovery control plane: the decision tree's predicted tier vs the
    // tier recovery actually uses, across the three leaf classes — with
    // one deliberately stale probe so the misprediction counter is
    // provably wired. The counters land in the JSON report; CI publishes
    // them as the advisory misprediction artifact.
    println!("recovery control plane, predicted vs actual tier:");
    let rp_topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let rp_metrics = Metrics::new();
    // a stale legacy checkpoint behind the committed manifests, so the
    // legacy leaf is reachable when the manifest tier refuses
    {
        let mut f = CheckpointFile::new("bench-engine", 1);
        f.add_section(SectionKind::StagePayload, 0, payloads[0].as_slice().to_vec());
        engine_store
            .put(&step_key("bench-engine", 1), &f.encode())
            .unwrap();
    }
    // (a) software failure: the tree predicts the in-memory fabric, and
    // the fabric serves
    let plan = RecoveryPlan::probe(&rp_topo, &[], true, engine_store.as_ref(), "bench-engine");
    plan.record_predicted(&rp_metrics);
    assert_eq!(plan.predicted(), Some(RecoveryPath::InMemory));
    assert!(cluster_p.restore_all(&[]).is_ok());
    plan.record_actual(&rp_metrics, RecoveryPath::InMemory);
    // (b) protection exceeded: the manifest tier predicted up front — and
    // the resolver serves exactly that tier
    cluster_p.kill_node(1);
    cluster_p.kill_node(2);
    let plan =
        RecoveryPlan::probe(&rp_topo, &[1, 2], true, engine_store.as_ref(), "bench-engine");
    plan.record_predicted(&rp_metrics);
    assert_eq!(plan.predicted(), Some(RecoveryPath::Durable(DurableTier::Manifest)));
    let legacy_key = engine_store.latest_for("bench-engine");
    assert!(
        persist::resolve_for_recovery(
            engine_store.as_ref(),
            "bench-engine",
            1,
            legacy_key.as_deref()
        )
        .is_some(),
        "committed manifests must serve the predicted tier"
    );
    plan.record_actual(&rp_metrics, RecoveryPath::Durable(DurableTier::Manifest));
    // (c) stale probe: the shards rot AFTER the plan is made; the loader
    // refuses every manifest, crosses to legacy, and the counter says why
    let plan =
        RecoveryPlan::probe(&rp_topo, &[1, 2], true, engine_store.as_ref(), "bench-engine");
    plan.record_predicted(&rp_metrics);
    for step in persist::persisted_steps(engine_store.as_ref(), "bench-engine") {
        let man = persist::PersistManifest::decode(
            &engine_store
                .get(&persist::manifest_key("bench-engine", step))
                .unwrap(),
        )
        .unwrap();
        for sh in &man.shards {
            if sh.parts.is_empty() {
                engine_store.put(&sh.key, &vec![0xEE; sh.len as usize]).unwrap();
            } else {
                for p in &sh.parts {
                    engine_store.put(&p.key, &vec![0xEE; p.len as usize]).unwrap();
                }
            }
        }
    }
    let legacy_key = engine_store.latest_for("bench-engine");
    assert!(
        persist::resolve_for_recovery(
            engine_store.as_ref(),
            "bench-engine",
            1,
            legacy_key.as_deref()
        )
        .is_none(),
        "rotted shards must refuse the manifest tier"
    );
    plan.record_actual(&rp_metrics, RecoveryPath::Durable(DurableTier::Legacy));
    let plans = rp_metrics.counter("recovery_plans");
    let mispredicted = rp_metrics.counter("recovery_mispredictions");
    assert_eq!((plans, mispredicted), (3, 1), "exactly the stale probe mispredicts");
    println!(
        "  {plans} plans: inmemory {} / manifest {} / legacy {}  -> mispredictions {mispredicted}\n",
        rp_metrics.counter("recovery_predicted_inmemory"),
        rp_metrics.counter("recovery_predicted_manifest"),
        rp_metrics.counter("recovery_predicted_legacy"),
    );
    rec(&mut report, "recovery_plan", vec![
        ("plans", plans as f64),
        ("predicted_inmemory", rp_metrics.counter("recovery_predicted_inmemory") as f64),
        ("predicted_manifest", rp_metrics.counter("recovery_predicted_manifest") as f64),
        ("predicted_legacy", rp_metrics.counter("recovery_predicted_legacy") as f64),
        ("mispredictions", mispredicted as f64),
    ]);

    // Reshape-on-restore: regather a 3-stage manifest into a 2-stage shape
    // through the atom-index range-fetch plan, vs the dense same-shape
    // restore. Gates: the reshaped plan must fetch no more shard bytes than
    // the dense restore (the atom index adds only manifest-side metadata,
    // measured below as its encode overhead), and the reshaped stream must
    // be byte-identical to the dense payload.
    let rs_stage = if smoke { 512 * 1024 } else { 8 * mib };
    println!(
        "reshape-on-restore, 3-stage -> 2-stage regather ({} MiB total):",
        3 * rs_stage / mib
    );
    let rs_store = MemStorage::new();
    let rs_bytes = vec![rs_stage as u64; 3];
    let mut rs_shards = Vec::new();
    {
        let mut rng = Rng::seed_from(0x5EA5);
        for stage in 0..3usize {
            // 4 shards per stage, the engine's usual sharding grain
            let chunk = rs_stage / 4;
            for node in 0..4usize {
                let body: Vec<u8> = (0..chunk).map(|_| rng.next_u64() as u8).collect();
                let key = persist::shard_key("bench-reshape", 10, stage, node);
                rs_store.put(&key, &body).unwrap();
                rs_shards.push(persist::ShardEntry {
                    key,
                    stage,
                    node,
                    offset: (node * chunk) as u64,
                    len: chunk as u64,
                    crc32: crc32fast::hash(&body),
                    extents: vec![],
                    parts: vec![],
                });
            }
        }
    }
    let rs_atoms = persist::derive_atoms(&rs_bytes, &rs_shards).unwrap();
    let rs_man = persist::PersistManifest {
        model: "bench-reshape".into(),
        step: 10,
        version: 1,
        snapshot_step: 10,
        stage_bytes: rs_bytes.clone(),
        shards: rs_shards,
        base_step: None,
        atoms: rs_atoms,
    };
    rs_store
        .put(&persist::manifest_key("bench-reshape", 10), &rs_man.encode())
        .unwrap();
    let mut bare = rs_man.clone();
    bare.atoms = vec![];
    let index_overhead = rs_man.encode().len() - bare.encode().len();
    let rs_total = 3 * rs_stage;
    let rs_target = vec![(rs_total / 2) as u64; 2];
    let rs_iters = if smoke { 5 } else { 15 };
    let dense_gbps = bench("dense restore (source shape)", rs_total, rs_iters, || {
        std::hint::black_box(persist::load_manifest_payload(&rs_store, &rs_man).unwrap());
    });
    let reshape_gbps = bench("reshaped restore (2-stage target)", rs_total, rs_iters, || {
        std::hint::black_box(
            persist::reshape_restore(
                &rs_store,
                &rs_man,
                persist::StageCodec::Opaque,
                &rs_target,
                8,
            )
            .unwrap(),
        );
    });
    let rs_plan =
        persist::ReshapePlan::plan(&rs_man, persist::StageCodec::Opaque, &rs_target).unwrap();
    let dense_out = persist::load_manifest_payload(&rs_store, &rs_man).unwrap();
    let (reshaped_out, rs_fetched) = persist::reshape_restore(
        &rs_store,
        &rs_man,
        persist::StageCodec::Opaque,
        &rs_target,
        8,
    )
    .unwrap();
    assert_eq!(
        reshaped_out.concat(),
        dense_out.concat(),
        "reshaped restore must be stream-identical to the dense restore"
    );
    println!(
        "  -> fetched {rs_fetched} of {rs_total} dense bytes ({} pieces, atom index \
         {index_overhead} manifest bytes)\n",
        rs_plan.pieces.len()
    );
    rec(&mut report, "reshape_restore", vec![
        ("dense_gbps", dense_gbps),
        ("reshape_gbps", reshape_gbps),
        ("fetched_bytes", rs_fetched as f64),
        ("dense_bytes", rs_total as f64),
        ("index_overhead_bytes", index_overhead as f64),
        ("pieces", rs_plan.pieces.len() as f64),
    ]);
    if rs_fetched > rs_total as u64 + index_overhead as u64 {
        failures.push(format!(
            "reshaped restore fetched {rs_fetched} bytes, more than the dense restore's \
             {rs_total} + the {index_overhead}-byte atom index"
        ));
    }

    // PJRT dispatch overhead (needs artifacts)
    if std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        println!("\nPJRT dispatch (tiny adam artifact, 234k params):");
        let man = reft::runtime::Manifest::load("artifacts", "tiny").unwrap();
        let full = man.full.as_ref().unwrap();
        let mut eng = reft::runtime::Engine::cpu("artifacts").unwrap();
        let np = full.n_params;
        let p = vec![0.1f32; np];
        let z = vec![0f32; np];
        let path = full.artifacts.get("adam").unwrap().to_string();
        // warmup compiles
        eng.run(&path, &[
            reft::runtime::lit_f32(&p, &[np]).unwrap(),
            reft::runtime::lit_f32(&z, &[np]).unwrap(),
            reft::runtime::lit_f32(&z, &[np]).unwrap(),
            reft::runtime::lit_f32(&p, &[np]).unwrap(),
            reft::runtime::lit_f32_scalar_vec(1.0),
        ])
        .unwrap();
        let mut times = Vec::new();
        for _ in 0..20 {
            let t0 = Instant::now();
            let outs = eng
                .run(&path, &[
                    reft::runtime::lit_f32(&p, &[np]).unwrap(),
                    reft::runtime::lit_f32(&z, &[np]).unwrap(),
                    reft::runtime::lit_f32(&z, &[np]).unwrap(),
                    reft::runtime::lit_f32(&p, &[np]).unwrap(),
                    reft::runtime::lit_f32_scalar_vec(1.0),
                ])
                .unwrap();
            std::hint::black_box(outs);
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(f64::total_cmp);
        let med = times[times.len() / 2];
        println!(
            "  adam step (fused Pallas kernel)       {:>8.3} ms median  ({:.2} GB/s state)",
            med * 1e3,
            (np * 4 * 7) as f64 / med / 1e9
        );
        rec(&mut report, "pjrt_adam", vec![("median_ms", med * 1e3)]);
    } else {
        println!("\n(skip PJRT dispatch bench — run `make artifacts` first)");
    }

    // machine-readable trend artifact
    let json = Json::obj(vec![
        ("bench", Json::str("hotpath")),
        ("smoke", Json::from(smoke)),
        ("gates_failed", Json::from(failures.len())),
        (
            "sections",
            Json::Obj(report.into_iter().collect()),
        ),
    ]);
    let out_path = std::env::var("BENCH_HOTPATH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    std::fs::write(&out_path, format!("{json}\n")).expect("writing bench report");
    println!("\nwrote {out_path}");

    // gates fire last: the artifact above survives a failed run
    assert!(
        failures.is_empty(),
        "§Perf gates failed:\n  - {}",
        failures.join("\n  - ")
    );
}
