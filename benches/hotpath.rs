//! §Perf — hot-path micro-benchmarks with real wall time (hand-rolled
//! harness; criterion is not in the offline crate set — median-of-N with
//! warmup, reporting MB/s or ns/op).
//!
//! Tracked paths (DESIGN.md §Perf):
//!   * XOR parity encode (`ec::xor_into`) vs the scalar reference and memcpy
//!     — target >= 1/2 memcpy (RAID5 write-penalty bound);
//!   * tiny-bucket copy overhead vs bucket size;
//!   * checkpoint container encode (CRC32 stream);
//!   * live snapshot round (SMP channels + parity) throughput;
//!   * PJRT dispatch overhead (adam on the tiny model), when artifacts exist.

use std::time::Instant;

use reft::config::FtConfig;
use reft::ec::{xor_into, xor_into_scalar};
use reft::elastic::ReftCluster;
use reft::snapshot::bucket::copy_bucketed;
use reft::topology::{ParallelPlan, Topology};
use reft::util::rng::Rng;

fn bench<F: FnMut()>(name: &str, bytes_per_iter: usize, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let med = times[times.len() / 2];
    let gbps = bytes_per_iter as f64 / med / 1e9;
    println!("  {name:<38} {gbps:>8.2} GB/s   ({:.3} ms/iter)", med * 1e3);
    gbps
}

fn main() {
    println!("=== §Perf hot-path benchmarks (median of 9, real wall time) ===\n");
    let n = 256 * 1024 * 1024usize;
    let mut rng = Rng::seed_from(1);
    let src: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
    let mut dst = vec![0u8; n];

    println!("XOR parity (RAIM5 encode/decode inner loop), 256 MiB:");
    let memcpy = bench("memcpy baseline", n, 9, || {
        dst.copy_from_slice(&src);
    });
    let xor_fast = bench("xor_into (word-unrolled)", n, 9, || {
        xor_into(&mut dst, &src);
    });
    let xor_slow = bench("xor_into_scalar (byte loop)", n, 9, || {
        xor_into_scalar(&mut dst, &src);
    });
    println!(
        "  -> word-unrolled/scalar: {:.2}x ; vs memcpy: {:.0}% (target >= 50%)\n",
        xor_fast / xor_slow,
        xor_fast / memcpy * 100.0
    );
    // Both variants are memory-bound here: LLVM auto-vectorizes the scalar
    // loop too, so parity within 20% is expected; the real §Perf gate is the
    // RAID5 bound vs memcpy.
    assert!(
        xor_fast >= xor_slow * 0.8,
        "word-unrolled XOR regressed far below the scalar loop"
    );
    assert!(
        xor_fast >= memcpy * 0.5,
        "XOR parity below the RAID5 write-penalty bound"
    );

    println!("tiny-bucket copy (snapshot d2h stand-in), 256 MiB:");
    for bucket in [64 * 1024, 1 << 20, 16 << 20, 256 << 20] {
        let label = format!("bucket = {} KiB", bucket / 1024);
        bench(&label, n, 5, || {
            copy_bucketed(&src, &mut dst, 0..n, bucket, |_| {});
        });
    }

    println!("\ncheckpoint container encode (CRC32 + frame), 64 MiB payload:");
    let payload = src[..64 * 1024 * 1024].to_vec();
    bench("CheckpointFile::encode", payload.len(), 5, || {
        let mut f = reft::checkpoint::CheckpointFile::new("bench", 1);
        f.add_section(reft::checkpoint::SectionKind::StagePayload, 0, payload.clone());
        std::hint::black_box(f.encode());
    });

    println!("\nlive snapshot round (SMP channels + RAIM5 parity), 96 MiB over 6 nodes:");
    let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let plen = 96 * 1024 * 1024usize;
    let payload: Vec<u8> = src[..plen].to_vec();
    let ft = FtConfig { bucket_bytes: 16 << 20, ..FtConfig::default() };
    let mut cluster = ReftCluster::start(topo, &[plen as u64], ft).unwrap();
    let payloads = vec![payload];
    bench("snapshot_all (raim5 on)", plen, 5, || {
        cluster.snapshot_all(&payloads).unwrap();
    });
    bench("restore_all (no loss)", plen, 5, || {
        std::hint::black_box(cluster.restore_all(&[]).unwrap());
    });

    // The figure-9 story, live: per-iteration stall the save path adds to a
    // training loop, blocking vs the hierarchical async coordinator, at
    // EQUAL bucket size. The blocking path pays shard copies + sends + parity
    // inside the iteration; the coordinator pays an enqueue (one payload
    // capture) plus a bounded per-tick bucket budget.
    println!(
        "\nper-iteration save stall, sync vs async coordinator \
         (96 MiB over 6 nodes, 1 MiB buckets, snapshot every 5 iters):"
    );
    let iters = 20usize;
    let interval = 5usize;
    let mk_cluster = |async_on: bool| {
        let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
        let ft = FtConfig {
            bucket_bytes: 1 << 20,
            async_snapshot: async_on,
            drain_buckets_per_tick: 4,
            ..FtConfig::default()
        };
        ReftCluster::start(topo, &[plen as u64], ft).unwrap()
    };
    let stall_run = |label: &str, async_on: bool| -> f64 {
        let mut cluster = mk_cluster(async_on);
        let (mut max_stall, mut total) = (0f64, 0f64);
        for it in 0..iters {
            let t0 = Instant::now();
            if it % interval == 0 {
                if async_on {
                    cluster.request_snapshot(payloads.clone()).unwrap();
                } else {
                    cluster.snapshot_all_blocking(&payloads).unwrap();
                }
            }
            if async_on {
                cluster.tick().unwrap();
            }
            let stall = t0.elapsed().as_secs_f64();
            max_stall = max_stall.max(stall);
            total += stall;
        }
        println!(
            "  {label:<38} max {:>8.3} ms/iter   mean {:>8.3} ms/iter",
            max_stall * 1e3,
            total / iters as f64 * 1e3
        );
        max_stall
    };
    let sync_stall = stall_run("blocking snapshot_all (CheckFreq-shape)", false);
    let async_stall = stall_run("coordinator enqueue + tick (REFT-Sn)", true);
    println!(
        "  -> async worst-case stall = {:.0}% of blocking (lower is better)\n",
        async_stall / sync_stall * 100.0
    );
    assert!(
        async_stall < sync_stall,
        "async per-iteration stall ({async_stall:.4}s) must be strictly lower \
         than blocking ({sync_stall:.4}s) at equal bucket size"
    );

    // PJRT dispatch overhead (needs artifacts)
    if std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        println!("\nPJRT dispatch (tiny adam artifact, 234k params):");
        let man = reft::runtime::Manifest::load("artifacts", "tiny").unwrap();
        let full = man.full.as_ref().unwrap();
        let mut eng = reft::runtime::Engine::cpu("artifacts").unwrap();
        let np = full.n_params;
        let p = vec![0.1f32; np];
        let z = vec![0f32; np];
        let path = full.artifacts.get("adam").unwrap().to_string();
        // warmup compiles
        eng.run(&path, &[
            reft::runtime::lit_f32(&p, &[np]).unwrap(),
            reft::runtime::lit_f32(&z, &[np]).unwrap(),
            reft::runtime::lit_f32(&z, &[np]).unwrap(),
            reft::runtime::lit_f32(&p, &[np]).unwrap(),
            reft::runtime::lit_f32_scalar_vec(1.0),
        ])
        .unwrap();
        let mut times = Vec::new();
        for _ in 0..20 {
            let t0 = Instant::now();
            let outs = eng
                .run(&path, &[
                    reft::runtime::lit_f32(&p, &[np]).unwrap(),
                    reft::runtime::lit_f32(&z, &[np]).unwrap(),
                    reft::runtime::lit_f32(&z, &[np]).unwrap(),
                    reft::runtime::lit_f32(&p, &[np]).unwrap(),
                    reft::runtime::lit_f32_scalar_vec(1.0),
                ])
                .unwrap();
            std::hint::black_box(outs);
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(f64::total_cmp);
        println!(
            "  adam step (fused Pallas kernel)       {:>8.3} ms median  ({:.2} GB/s state)",
            times[times.len() / 2] * 1e3,
            (np * 4 * 7) as f64 / times[times.len() / 2] / 1e9
        );
    } else {
        println!("\n(skip PJRT dispatch bench — run `make artifacts` first)");
    }
}
