//! E4 — weak scaling (§6.2a): saving speed of OPT-125M and OPT-350M
//! pretraining under DP ∈ {1, 4, 12, 24}, per fault-tolerance method.
//!
//! Paper headlines reproduced in shape:
//!   * REFT-Sn scales ~18.7x from DP-1 to DP-24 on OPT-350M;
//!   * at DP-24 REFT-Sn is ~14.11x TorchSnapshot and ~106x CheckFreq;
//!   * REFT-Ckpt trails TorchSnapshot slightly (tiny buckets trade top
//!     speed for minimal interference).

use reft::config::zoo;
use reft::snapshot::{cost, SnapshotPlan};
use reft::topology::{ParallelPlan, Topology};

fn main() {
    println!("=== Weak scaling — saving speed (GB/s), paper §6.2a ===");
    let dps = [1usize, 4, 12, 24];
    for model in ["opt-125m", "opt-350m"] {
        let spec = zoo::zoo_model(model).unwrap();
        let payload = spec.save_bytes();
        println!(
            "\n--- {} ({:.0}M params, payload {:.2} GB) ---",
            model,
            spec.total_params() as f64 / 1e6,
            payload as f64 / 1e9
        );
        println!(
            "{:<14} {:>9} {:>9} {:>9} {:>9}",
            "method", "DP-1", "DP-4", "DP-12", "DP-24"
        );
        let mut table: Vec<(String, Vec<f64>)> = Vec::new();
        for method in ["checkfreq", "torchsnapshot", "reft-sn", "reft-ckpt"] {
            let mut speeds = Vec::new();
            for &dp in &dps {
                let nodes = dp.div_ceil(4).max(1);
                let topo = Topology::build(ParallelPlan::dp_only(dp), nodes, 4).unwrap();
                let plan = SnapshotPlan::build(&topo, &[payload]);
                let costs = cost::compare_methods(&topo, &plan, 1.0, true);
                let c = costs.iter().find(|c| c.method == method).unwrap();
                speeds.push(c.speed() / 1e9);
            }
            println!(
                "{:<14} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
                method, speeds[0], speeds[1], speeds[2], speeds[3]
            );
            table.push((method.to_string(), speeds));
        }
        let find = |m: &str| &table.iter().find(|t| t.0 == m).unwrap().1;
        let sn = find("reft-sn");
        let ts = find("torchsnapshot");
        let cf = find("checkfreq");
        println!("\nshape checks ({model}):");
        println!(
            "  REFT-Sn scaling DP-1 -> DP-24: {:.1}x   (paper: 18.74x on OPT-350M)",
            sn[3] / sn[0]
        );
        println!(
            "  REFT-Sn / TorchSnapshot @DP-24: {:.1}x  (paper: 14.11x)",
            sn[3] / ts[3]
        );
        println!(
            "  REFT-Sn / CheckFreq    @DP-24: {:.1}x  (paper: 106.02x)",
            sn[3] / cf[3]
        );
        assert!(sn[3] / ts[3] > 4.0, "REFT/TS ratio collapsed");
        assert!(sn[3] / cf[3] > 25.0, "REFT/CF ratio collapsed");
        assert!(sn[3] > sn[0] * 4.0, "weak scaling is flat");
    }
}
