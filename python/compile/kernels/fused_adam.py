"""Fused Adam optimizer update as a Pallas kernel over flat parameter buffers.

The REFT data path manages every pipeline stage's parameters as one flat f32
buffer (that is what gets sharded, bucketed, snapshotted and XOR-parity-coded),
so the optimizer consumes the same layout. A naive jnp Adam emits 8+ separate
elementwise HLO ops, each a full read+write pass over params/moments (4 buffers
x several passes of HBM traffic). This kernel fuses the whole update into one
pass: read (p, m, v, g) tiles, write (p', m', v') tiles.

TPU structure: a 1-D grid over ``block`` -sized tiles of the flat buffer; this
is VPU (vector unit) work, so ``block`` is a multiple of the 8x128 vreg lane
layout (default 64Ki elements = 256 KiB/input tile; 7 tiles resident -> ~1.8 MiB
of VMEM, well within budget, leaving headroom for double buffering).

Per-element roofline: 4 f32 reads + 3 f32 writes = 28 B of HBM traffic for
~12 flops -> firmly memory-bound; fusing is the whole optimization (one pass
instead of the ~4x the unfused chain pays). The bias-correction scalars depend
on the step count, which changes every iteration, so ``step`` is a runtime
``f32[1]`` input (kept in SMEM on real TPU) rather than a compile-time constant
— the rust runtime bumps it without re-compiling the artifact.

Hyper-parameters (lr, betas, eps, weight decay) are compile-time constants baked
into the HLO, matching how the rust coordinator treats them (fixed per run).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 65536

# NOTE on interpret=True performance: each grid step of an interpreted
# pallas_call lowers to a dynamic-update-slice over the FULL output buffer
# inside an XLA while loop, so many small blocks are quadratic in total
# traffic on CPU. The AOT exporter therefore passes block >= n (one grid
# step). The 64Ki default documents the *TPU* tiling (8x128 vreg multiples,
# ~1.8 MiB VMEM residency) that a Mosaic build would use.
AOT_BLOCK = 1 << 26  # >= any exported model's stage size -> single grid step


def _adam_kernel(step_ref, p_ref, m_ref, v_ref, g_ref, po_ref, mo_ref, vo_ref,
                 *, lr, beta1, beta2, eps, weight_decay):
    t = step_ref[0]
    p = p_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    g = g_ref[...]
    if weight_decay != 0.0:
        g = g + weight_decay * p  # decoupled-free (classic Adam w/ L2) form
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * (g * g)
    # bias correction: 1 - beta^t, computed from the runtime step scalar
    bc1 = 1.0 - jnp.exp(t * jnp.log(beta1))
    bc2 = 1.0 - jnp.exp(t * jnp.log(beta2))
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    po_ref[...] = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def fused_adam(p, m, v, g, step, *, lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8,
               weight_decay=0.0, block=DEFAULT_BLOCK):
    """One Adam step over flat f32 buffers.

    Args:
      p, m, v, g: ``f32[n]`` parameters, first/second moments, gradients.
      step: ``f32[1]`` 1-based step count (for bias correction).
    Returns:
      ``(p', m', v')`` updated flat buffers.
    """
    (n,) = p.shape
    block = min(block, n)
    # pad to a whole number of blocks; padded lanes are dropped on return
    pad = (-n) % block
    if pad:
        zpad = lambda a: jnp.pad(a, (0, pad))
        p, m, v, g = zpad(p), zpad(m), zpad(v), zpad(g)
    nblocks = (n + pad) // block

    kern = functools.partial(
        _adam_kernel, lr=lr, beta1=beta1, beta2=beta2, eps=eps, weight_decay=weight_decay
    )
    out_shape = [jax.ShapeDtypeStruct((n + pad,), jnp.float32)] * 3
    tile = pl.BlockSpec((block,), lambda i: (i,))
    p2, m2, v2 = pl.pallas_call(
        kern,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # step scalar, broadcast to all tiles
            tile, tile, tile, tile,
        ],
        out_specs=[tile, tile, tile],
        out_shape=out_shape,
        interpret=True,
    )(step, p, m, v, g)
    if pad:
        p2, m2, v2 = p2[:n], m2[:n], v2[:n]
    return p2, m2, v2
