"""FlashAttention-2 style causal attention as a Pallas kernel (fwd + bwd).

The paper's training workload (OPT pretraining) spends its forward/backward hot
spot in attention. The original systems are CUDA-era (threadblocks over shared
memory); here the same insight — never materialise the [T, T] score matrix in
slow memory, stream K/V tiles through fast memory with an online softmax — is
re-expressed for TPU structure:

* **HBM->VMEM schedule**: the grid is ``(heads, num_q_blocks)``; each program
  holds one ``[block_q, d]`` Q tile plus streaming ``[block_k, d]`` K/V tiles
  in VMEM (BlockSpec for Q/O; ``pl.ds`` dynamic slices for the K/V stream),
  the role threadblock-staged shared memory played on GPUs.
* **MXU tiles**: both matmuls (``q @ k^T`` and ``p @ v``) are
  ``[block_q, d] x [d, block_k]`` / ``[block_q, block_k] x [block_k, d]``
  shapes; with the default ``block_q = block_k = 128`` and ``d`` a multiple of
  128 these map onto the 128x128 systolic array. ``preferred_element_type`` is
  f32 so a bf16 deployment accumulates in f32 on the MXU.
* **Online softmax**: running max ``m`` and normaliser ``l`` carried through a
  ``fori_loop`` over K blocks, exactly FlashAttention-2 (rescale-once variant).

VMEM footprint estimate (per program, f32):
    Q tile     block_q * d * 4
  + K,V tiles  2 * block_k * d * 4
  + O accum    block_q * d * 4
  + m,l,lse    3 * block_q * 4
  ~= (2*block_q + 2*block_k) * d * 4 bytes
For block_q = block_k = 128, d = 128 that is ~256 KiB — comfortably inside the
~16 MiB/core VMEM budget, leaving room for double buffering of the K/V stream
(the Mosaic pipeliner's job on real TPU; a no-op under interpret=True).

The backward pass is the FlashAttention-2 two-kernel split:
  * ``dkv`` kernel: grid over K blocks, streams Q/dO blocks (parallel over the
    K dimension, no atomics — each program owns its dK/dV tile);
  * ``dq`` kernel: grid over Q blocks, streams K/V blocks.
Residuals are ``(q, k, v, o, lse)`` with ``delta = rowsum(do * o)`` computed
per-tile, so the [T, T] matrix is never materialised in the backward either.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated against the pure-jnp oracle in
``ref.py`` (pytest + hypothesis), and real-TPU performance is *estimated* from
the VMEM/MXU structure above (DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _pick_block(seq: int, want: int) -> int:
    """Largest divisor of ``seq`` that is <= want (kernel requires seq % block == 0)."""
    b = min(want, seq)
    while seq % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_q, block_k, seq, scale, causal):
    """One (head, q-block) program of the online-softmax forward."""
    qi = pl.program_id(1)
    q = q_ref[0, :, :] * scale  # [block_q, d]

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros(q.shape, dtype=jnp.float32)

    # In causal mode, K blocks strictly after this Q block contribute nothing.
    # ceil-divide: a partial trailing K block still overlaps the causal band
    # when block_q is not a multiple of block_k.
    num_kb = -((qi + 1) * block_q // -block_k) if causal else seq // block_k

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.ds(kb * block_k, block_k), :]  # [block_k, d]
        v = v_ref[0, pl.ds(kb * block_k, block_k), :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [block_q, block_k]
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_cur, l_cur, acc

    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    o_ref[0, :, :] = acc / l[:, None]
    lse_ref[0, :] = m + jnp.log(l)


def _fwd(q, k, v, *, block_q, block_k, causal):
    """q, k, v: [h, seq, d] -> (o [h, seq, d], lse [h, seq])."""
    h, seq, d = q.shape
    block_q = _pick_block(seq, block_q)
    block_k = _pick_block(seq, block_k)
    scale = 1.0 / (d ** 0.5)
    grid = (h, seq // block_q)
    kern = functools.partial(
        _fwd_kernel, block_q=block_q, block_k=block_k, seq=seq, scale=scale, causal=causal
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((1, seq, d), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((1, seq, d), lambda hh, i: (hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((1, block_q), lambda hh, i: (hh, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, seq, d), jnp.float32),
            jax.ShapeDtypeStruct((h, seq), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
                *, block_q, block_k, seq, scale, causal):
    """One (head, k-block) program: accumulate dK/dV by streaming Q/dO blocks."""
    ki = pl.program_id(1)
    k = k_ref[0, :, :]  # [block_k, d]
    v = v_ref[0, :, :]

    dk0 = jnp.zeros(k.shape, dtype=jnp.float32)
    dv0 = jnp.zeros(v.shape, dtype=jnp.float32)

    # Causal: Q blocks strictly before this K block see none of it.
    qb_start = ki * block_k // block_q if causal else 0
    num_qb = seq // block_q

    def body(qb, carry):
        dk, dv = carry
        q = q_ref[0, pl.ds(qb * block_q, block_q), :] * scale  # [block_q, d]
        do = do_ref[0, pl.ds(qb * block_q, block_q), :]
        lse = lse_ref[0, pl.ds(qb * block_q, block_q)]
        delta = delta_ref[0, pl.ds(qb * block_q, block_q)]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [block_q, block_k]
        if causal:
            rows = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # softmax probabilities, recomputed
        dv = dv + jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])  # [block_q, block_k]
        dk = dk + jnp.dot(ds.T, q, preferred_element_type=jnp.float32)
        return dk, dv

    dk, dv = jax.lax.fori_loop(qb_start, num_qb, body, (dk0, dv0))
    dk_ref[0, :, :] = dk  # note: q already carries `scale`, so dk is w.r.t. raw k
    dv_ref[0, :, :] = dv


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               *, block_q, block_k, seq, scale, causal):
    """One (head, q-block) program: accumulate dQ by streaming K/V blocks."""
    qi = pl.program_id(1)
    q = q_ref[0, :, :] * scale
    do = do_ref[0, :, :]
    lse = lse_ref[0, :]
    delta = delta_ref[0, :]

    dq0 = jnp.zeros(q.shape, dtype=jnp.float32)
    num_kb = -((qi + 1) * block_q // -block_k) if causal else seq // block_k

    def body(kb, dq):
        k = k_ref[0, pl.ds(kb * block_k, block_k), :]
        v = v_ref[0, pl.ds(kb * block_k, block_k), :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        return dq + jnp.dot(ds, k, preferred_element_type=jnp.float32)

    dq = jax.lax.fori_loop(0, num_kb, body, dq0)
    dq_ref[0, :, :] = dq * scale  # chain rule through q * scale


def _bwd(block_q, block_k, causal, res, do):
    q, k, v, o, lse = res
    h, seq, d = q.shape
    block_q = _pick_block(seq, block_q)
    block_k = _pick_block(seq, block_k)
    scale = 1.0 / (d ** 0.5)
    delta = jnp.sum(do * o, axis=-1)  # [h, seq]

    dkv = pl.pallas_call(
        functools.partial(
            _dkv_kernel, block_q=block_q, block_k=block_k, seq=seq, scale=scale, causal=causal
        ),
        grid=(h, seq // block_k),
        in_specs=[
            pl.BlockSpec((1, seq, d), lambda hh, i: (hh, 0, 0)),      # q (streamed)
            pl.BlockSpec((1, block_k, d), lambda hh, i: (hh, i, 0)),  # k (owned tile)
            pl.BlockSpec((1, block_k, d), lambda hh, i: (hh, i, 0)),  # v
            pl.BlockSpec((1, seq, d), lambda hh, i: (hh, 0, 0)),      # do (streamed)
            pl.BlockSpec((1, seq), lambda hh, i: (hh, 0)),            # lse
            pl.BlockSpec((1, seq), lambda hh, i: (hh, 0)),            # delta
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda hh, i: (hh, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, seq, d), jnp.float32),
            jax.ShapeDtypeStruct((h, seq, d), jnp.float32),
        ],
        interpret=True,
    )
    dk, dv = dkv(q, k, v, do, lse, delta)

    dq = pl.pallas_call(
        functools.partial(
            _dq_kernel, block_q=block_q, block_k=block_k, seq=seq, scale=scale, causal=causal
        ),
        grid=(h, seq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hh, i: (hh, i, 0)),  # q (owned tile)
            pl.BlockSpec((1, seq, d), lambda hh, i: (hh, 0, 0)),      # k (streamed)
            pl.BlockSpec((1, seq, d), lambda hh, i: (hh, 0, 0)),      # v
            pl.BlockSpec((1, block_q, d), lambda hh, i: (hh, i, 0)),  # do
            pl.BlockSpec((1, block_q), lambda hh, i: (hh, i)),        # lse
            pl.BlockSpec((1, block_q), lambda hh, i: (hh, i)),        # delta
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, seq, d), jnp.float32),
        interpret=True,
    )(q, k, v, do, lse, delta)

    return dq, dk, dv


# ---------------------------------------------------------------------------
# public entry point (differentiable)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K, causal=True):
    """Causal multi-head attention over ``[heads, seq, d]`` inputs.

    Softmax scaling ``1/sqrt(d)`` is applied internally. Differentiable via a
    custom VJP whose forward *and* backward are Pallas kernels (FlashAttention-2
    recompute style). ``vmap`` over a leading batch axis is supported.
    """
    o, _ = _fwd(q, k, v, block_q=block_q, block_k=block_k, causal=causal)
    return o


def _vjp_fwd(q, k, v, block_q, block_k, causal):
    o, lse = _fwd(q, k, v, block_q=block_q, block_k=block_k, causal=causal)
    return o, (q, k, v, o, lse)


def _vjp_bwd(block_q, block_k, causal, res, do):
    return _bwd(block_q, block_k, causal, res, do)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
