"""Layer-1 Pallas kernels for the REFT reproduction.

All kernels are authored for TPU structure (VMEM tiling via BlockSpec, MXU-shaped
matmul tiles) but lowered with ``interpret=True`` so the resulting HLO executes
on the CPU PJRT plugin used by the rust runtime. See DESIGN.md
§Hardware-Adaptation for the CUDA->TPU mapping rationale.
"""

from .flash_attention import flash_attention
from .fused_adam import fused_adam

__all__ = ["flash_attention", "fused_adam"]
