"""Pure-jnp oracles for the Pallas kernels (the build-time correctness signal).

These are the mathematically transparent implementations the kernels are
verified against in ``python/tests/`` (pytest + hypothesis shape/dtype sweeps).
They deliberately materialise the full score matrix / use the unfused update
chain so any kernel bug shows up as a numeric divergence, not a shared mistake.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, causal=True):
    """Plain softmax attention over ``[heads, seq, d]`` (scores materialised)."""
    h, seq, d = q.shape
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
        s = jnp.where(mask[None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def ref_adam(p, m, v, g, step, *, lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8,
             weight_decay=0.0):
    """Textbook Adam on flat buffers; ``step`` is a 1-based python/array scalar."""
    t = jnp.asarray(step, dtype=jnp.float32).reshape(())
    if weight_decay != 0.0:
        g = g + weight_decay * p
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1 ** t)
    v_hat = v_new / (1.0 - beta2 ** t)
    p_new = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return p_new, m_new, v_new
