"""Layer-2: OPT-style decoder-only transformer in JAX, split into pipeline
stage functions for the rust coordinator.

Every pipeline stage's parameters live in ONE flat f32 buffer. That is a
deliberate contract with the rust side: the flat buffer is the unit that REFT
shards across the sharding group, copies device->host in tiny buckets, double-
buffers on the SMP and XOR-parity-codes in RAIM5. The stage functions take the
flat buffer and unflatten it internally (XLA folds the slices/reshapes away),
so rust never needs to know the pytree structure — only the manifest's
(name, shape, offset, init) records, which it uses for initialisation.

Stage functions exported per model (see aot.py):
  stage0_fwd   (flat[N0], tokens i32[B,T])            -> y f32[B,T,D]
  stage0_bwd   (flat, tokens, dy)                     -> grads f32[N0]
  mid{i}_fwd   (flat[Ni], x f32[B,T,D])               -> y
  mid{i}_bwd   (flat, x, dy)                          -> (dx, grads)
  last_fwd     (flat[NL], x, targets i32[B,T])        -> loss f32[]
  last_fwdbwd  (flat, x, targets)                     -> (loss, dx, grads)
  fwd_bwd      (flat[N], tokens, targets)             -> (loss, grads)
  adam_*       (p, m, v, g, step f32[1])              -> (p', m', v')

Backward stages recompute the forward from the stage input (activation
rematerialisation) — the standard memory/compute trade for pipeline training,
and it keeps each bwd artifact self-contained (no residual plumbing across the
rust boundary).

Architecture (OPT family): learned positional embeddings, pre-LN blocks,
GELU MLP (4x), untied LM head, causal attention via the L1 Pallas
flash-attention kernel, Adam via the L1 fused-adam kernel.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import flash_attention, fused_adam
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int
    batch: int  # microbatch size the artifacts are specialised for
    use_pallas: bool = True  # False -> ref attention (debug / ablation)

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


PRESETS = {
    # integration-test scale: compiles + runs in milliseconds
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=4, n_heads=4,
                        d_ff=256, seq=32, batch=2),
    # end-to-end example scale (~34M params): a few hundred steps on 1 CPU core
    "e2e-25m": ModelConfig("e2e-25m", vocab=8192, d_model=512, n_layers=8,
                           n_heads=8, d_ff=2048, seq=128, batch=4),
    # ~124M params: runnable, exported on demand (heavier compile/exec)
    "e2e-100m": ModelConfig("e2e-100m", vocab=32768, d_model=768, n_layers=12,
                            n_heads=12, d_ff=3072, seq=256, batch=2),
}


# ---------------------------------------------------------------------------
# parameter layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple
    init: str  # "normal:<std>" | "zeros" | "ones"

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def block_specs(cfg: ModelConfig, layer: int) -> list:
    """Parameter layout of one pre-LN transformer block."""
    d, f = cfg.d_model, cfg.d_ff
    p = f"h{layer}."
    std = "normal:0.02"
    return [
        ParamSpec(p + "ln1_g", (d,), "ones"),
        ParamSpec(p + "ln1_b", (d,), "zeros"),
        ParamSpec(p + "w_qkv", (d, 3 * d), std),
        ParamSpec(p + "b_qkv", (3 * d,), "zeros"),
        ParamSpec(p + "w_o", (d, d), std),
        ParamSpec(p + "b_o", (d,), "zeros"),
        ParamSpec(p + "ln2_g", (d,), "ones"),
        ParamSpec(p + "ln2_b", (d,), "zeros"),
        ParamSpec(p + "w_fc", (d, f), std),
        ParamSpec(p + "b_fc", (f,), "zeros"),
        ParamSpec(p + "w_proj", (f, d), std),
        ParamSpec(p + "b_proj", (d,), "zeros"),
    ]


def split_layers(n_layers: int, n_stages: int) -> list:
    """Balanced contiguous layer split (earlier stages get the remainder)."""
    assert 1 <= n_stages <= n_layers
    base, rem = divmod(n_layers, n_stages)
    out, start = [], 0
    for s in range(n_stages):
        cnt = base + (1 if s < rem else 0)
        out.append(list(range(start, start + cnt)))
        start += cnt
    return out


def stage_specs(cfg: ModelConfig, stage: int, n_stages: int) -> list:
    """Flat-buffer layout of one pipeline stage."""
    layers = split_layers(cfg.n_layers, n_stages)[stage]
    specs = []
    if stage == 0:
        specs.append(ParamSpec("tok_emb", (cfg.vocab, cfg.d_model), "normal:0.02"))
        specs.append(ParamSpec("pos_emb", (cfg.seq, cfg.d_model), "normal:0.02"))
    for l in layers:
        specs.extend(block_specs(cfg, l))
    if stage == n_stages - 1:
        specs.append(ParamSpec("lnf_g", (cfg.d_model,), "ones"))
        specs.append(ParamSpec("lnf_b", (cfg.d_model,), "zeros"))
        specs.append(ParamSpec("lm_head", (cfg.d_model, cfg.vocab), "normal:0.02"))
    return specs


def specs_size(specs) -> int:
    return sum(s.size for s in specs)


def unflatten(flat: jnp.ndarray, specs) -> dict:
    """Slice the flat buffer into named tensors (static offsets; XLA folds it)."""
    out, off = {}, 0
    for s in specs:
        out[s.name] = flat[off:off + s.size].reshape(s.shape)
        off += s.size
    return out


def init_params(key, specs) -> jnp.ndarray:
    """Python-side init (mirrors the rust-side manifest-driven init)."""
    parts = []
    for s in specs:
        if s.init == "zeros":
            parts.append(jnp.zeros((s.size,), jnp.float32))
        elif s.init == "ones":
            parts.append(jnp.ones((s.size,), jnp.float32))
        else:
            std = float(s.init.split(":")[1])
            key, sub = jax.random.split(key)
            parts.append(jax.random.normal(sub, (s.size,), jnp.float32) * std)
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# forward pieces
# ---------------------------------------------------------------------------


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(cfg: ModelConfig, p: dict, prefix: str, x):
    """x: [B, T, D] -> [B, T, D] causal MHA via the Pallas kernel."""
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    qkv = x @ p[prefix + "w_qkv"] + p[prefix + "b_qkv"]  # [B,T,3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    # [B,T,D] -> [B,H,T,dh]
    to_heads = lambda a: a.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    q, k, v = to_heads(q), to_heads(k), to_heads(v)
    if cfg.use_pallas:
        o = jax.vmap(lambda qq, kk, vv: flash_attention(qq, kk, vv))(q, k, v)
    else:
        o = jax.vmap(lambda qq, kk, vv: kref.ref_attention(qq, kk, vv))(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    return o @ p[prefix + "w_o"] + p[prefix + "b_o"]


def _block(cfg: ModelConfig, p: dict, layer: int, x):
    pre = f"h{layer}."
    x = x + _attention(cfg, p, pre, _layer_norm(x, p[pre + "ln1_g"], p[pre + "ln1_b"]))
    hdn = _layer_norm(x, p[pre + "ln2_g"], p[pre + "ln2_b"])
    hdn = jax.nn.gelu(hdn @ p[pre + "w_fc"] + p[pre + "b_fc"], approximate=True)
    return x + hdn @ p[pre + "w_proj"] + p[pre + "b_proj"]


def stage_forward(cfg: ModelConfig, stage: int, n_stages: int) -> Callable:
    """Build the forward fn of one stage over its flat param buffer.

    first stage : (flat, tokens)      -> hidden
    mid stage   : (flat, hidden)      -> hidden
    last stage  : (flat, hidden, tgt) -> loss   (mean token cross-entropy)
    """
    specs = stage_specs(cfg, stage, n_stages)
    layers = split_layers(cfg.n_layers, n_stages)[stage]
    first, last = stage == 0, stage == n_stages - 1

    def hidden_path(p, x):
        for l in layers:
            x = _block(cfg, p, l, x)
        return x

    if first and last:  # single-stage model == full model w/o loss split
        def fn(flat, tokens, targets):
            p = unflatten(flat, specs)
            x = p["tok_emb"][tokens] + p["pos_emb"][None, :tokens.shape[1], :]
            x = hidden_path(p, x)
            return _loss_head(cfg, p, x, targets)
        return fn
    if first:
        def fn(flat, tokens):
            p = unflatten(flat, specs)
            x = p["tok_emb"][tokens] + p["pos_emb"][None, :tokens.shape[1], :]
            return hidden_path(p, x)
        return fn
    if last:
        def fn(flat, x, targets):
            p = unflatten(flat, specs)
            x = hidden_path(p, x)
            return _loss_head(cfg, p, x, targets)
        return fn

    def fn(flat, x):
        p = unflatten(flat, specs)
        return hidden_path(p, x)
    return fn


def _loss_head(cfg: ModelConfig, p: dict, x, targets):
    x = _layer_norm(x, p["lnf_g"], p["lnf_b"])
    logits = x @ p["lm_head"]  # [B,T,V]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# exported entry points (what aot.py lowers)
# ---------------------------------------------------------------------------


def make_stage_fns(cfg: ModelConfig, stage: int, n_stages: int) -> dict:
    """Forward/backward closures for one stage, keyed by artifact kind."""
    fwd = stage_forward(cfg, stage, n_stages)
    first, last = stage == 0, stage == n_stages - 1
    out = {}

    if first and last:
        def fwd_bwd(flat, tokens, targets):
            loss, grads = jax.value_and_grad(fwd)(flat, tokens, targets)
            return loss, grads
        out["fwd_bwd"] = fwd_bwd
        return out

    if first:
        out["fwd"] = fwd

        def bwd(flat, tokens, dy):
            _, pull = jax.vjp(lambda f: fwd(f, tokens), flat)
            (dflat,) = pull(dy)
            return dflat
        out["bwd"] = bwd
    elif last:
        def last_fwd(flat, x, targets):
            return fwd(flat, x, targets)
        out["fwd"] = last_fwd

        def fwdbwd(flat, x, targets):
            (loss, (dflat, dx)) = jax.value_and_grad(fwd, argnums=(0, 1))(flat, x, targets)
            return loss, dx, dflat
        out["fwdbwd"] = fwdbwd
    else:
        out["fwd"] = fwd

        def bwd(flat, x, dy):
            _, pull = jax.vjp(fwd, flat, x)
            dflat, dx = pull(dy)
            return dx, dflat
        out["bwd"] = bwd
    return out


def make_full_fwd_bwd(cfg: ModelConfig) -> Callable:
    """(flat, tokens, targets) -> (loss, grads) over the whole model (DP mode)."""
    fn = stage_forward(cfg, 0, 1)

    def fwd_bwd(flat, tokens, targets):
        loss, grads = jax.value_and_grad(fn)(flat, tokens, targets)
        return loss, grads
    return fwd_bwd


def make_adam(cfg: ModelConfig, lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8,
              weight_decay=0.0) -> Callable:
    """(p, m, v, g, step) -> (p', m', v') via the fused Pallas kernel.

    Exports use one grid step (block >= n): under interpret=True each grid
    step costs a full-buffer dynamic-update-slice, so fine CPU tiling is
    pathological — see kernels/fused_adam.py.
    """
    from .kernels.fused_adam import AOT_BLOCK

    def adam(p, m, v, g, step):
        if cfg.use_pallas:
            return fused_adam(p, m, v, g, step, lr=lr, beta1=beta1, beta2=beta2,
                              eps=eps, weight_decay=weight_decay,
                              block=AOT_BLOCK)
        return kref.ref_adam(p, m, v, g, step[0], lr=lr, beta1=beta1,
                             beta2=beta2, eps=eps, weight_decay=weight_decay)
    return adam
