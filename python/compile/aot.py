"""AOT pipeline: lower L2 stage functions (which embed the L1 Pallas kernels)
to HLO *text* artifacts + a JSON manifest for the rust runtime.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONLY here, at build time (`make artifacts`). The rust binary is
self-contained afterwards.

Usage:
    python -m compile.aot --out ../artifacts --model tiny --stages 4
    python -m compile.aot --out ../artifacts --model e2e-25m --stages 2 --full
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> dict:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return {"path": os.path.relpath(path), "bytes": len(text)}


def spec_f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def spec_i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def export_model(cfg: M.ModelConfig, n_stages: int, out_dir: str, *,
                 with_full: bool, lr: float) -> dict:
    """Export one model's stage artifacts + manifest dict."""
    mdir = os.path.join(out_dir, cfg.name)
    os.makedirs(mdir, exist_ok=True)
    B, T, D = cfg.batch, cfg.seq, cfg.d_model
    adam = M.make_adam(cfg, lr=lr)

    layer_split = M.split_layers(cfg.n_layers, n_stages)
    stages = []
    for s in range(n_stages):
        specs = M.stage_specs(cfg, s, n_stages)
        n_params = M.specs_size(specs)
        fns = M.make_stage_fns(cfg, s, n_stages)
        first, last = s == 0, s == n_stages - 1
        arts = {}

        flat = spec_f32(n_params)
        hid = spec_f32(B, T, D)
        tok = spec_i32(B, T)

        if first and last:
            arts["fwd_bwd"] = lower_to_file(
                fns["fwd_bwd"], (flat, tok, tok), os.path.join(mdir, f"stage{s}_fwd_bwd.hlo.txt"))
        elif first:
            arts["fwd"] = lower_to_file(
                fns["fwd"], (flat, tok), os.path.join(mdir, f"stage{s}_fwd.hlo.txt"))
            arts["bwd"] = lower_to_file(
                fns["bwd"], (flat, tok, hid), os.path.join(mdir, f"stage{s}_bwd.hlo.txt"))
        elif last:
            arts["fwd"] = lower_to_file(
                fns["fwd"], (flat, hid, tok), os.path.join(mdir, f"stage{s}_fwd.hlo.txt"))
            arts["fwdbwd"] = lower_to_file(
                fns["fwdbwd"], (flat, hid, tok), os.path.join(mdir, f"stage{s}_fwdbwd.hlo.txt"))
        else:
            arts["fwd"] = lower_to_file(
                fns["fwd"], (flat, hid), os.path.join(mdir, f"stage{s}_fwd.hlo.txt"))
            arts["bwd"] = lower_to_file(
                fns["bwd"], (flat, hid, hid), os.path.join(mdir, f"stage{s}_bwd.hlo.txt"))

        arts["adam"] = lower_to_file(
            adam, (flat, flat, flat, flat, spec_f32(1)),
            os.path.join(mdir, f"adam_stage{s}.hlo.txt"))

        params, off = [], 0
        for sp in specs:
            params.append({"name": sp.name, "shape": list(sp.shape),
                           "offset": off, "size": sp.size, "init": sp.init})
            off += sp.size
        stages.append({
            "index": s,
            "kind": "single" if (first and last) else
                    ("first" if first else ("last" if last else "mid")),
            "layers": layer_split[s],
            "n_params": n_params,
            "artifacts": arts,
            "params": params,
        })

    manifest = {
        "model": cfg.name,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "seq": cfg.seq,
            "batch": cfg.batch, "lr": lr,
        },
        "n_stages": n_stages,
        "total_params": sum(st["n_params"] for st in stages),
        "stages": stages,
    }

    if with_full and n_stages > 1:
        # whole-model fwd_bwd + adam for pure-DP runs on the same preset
        specs = M.stage_specs(cfg, 0, 1)
        n_total = M.specs_size(specs)
        flat = spec_f32(n_total)
        tok = spec_i32(B, T)
        full_arts = {
            "fwd_bwd": lower_to_file(M.make_full_fwd_bwd(cfg), (flat, tok, tok),
                                     os.path.join(mdir, "full_fwd_bwd.hlo.txt")),
            "adam": lower_to_file(adam, (flat, flat, flat, flat, spec_f32(1)),
                                  os.path.join(mdir, "adam_full.hlo.txt")),
        }
        params, off = [], 0
        for sp in specs:
            params.append({"name": sp.name, "shape": list(sp.shape),
                           "offset": off, "size": sp.size, "init": sp.init})
            off += sp.size
        manifest["full"] = {"n_params": n_total, "artifacts": full_arts, "params": params}

    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def export_golden(cfg: M.ModelConfig, n_stages: int, out_dir: str) -> None:
    """Emit seeded example inputs + expected outputs so the rust integration
    tests can verify end-to-end numerics of the loaded artifacts (this is the
    cross-language correctness contract)."""
    import numpy as np

    mdir = os.path.join(out_dir, cfg.name, "golden")
    os.makedirs(mdir, exist_ok=True)
    key = jax.random.PRNGKey(1234)
    tokens = jax.random.randint(jax.random.PRNGKey(5678), (cfg.batch, cfg.seq),
                                0, cfg.vocab, dtype=jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)

    stage_flats = []
    for s in range(n_stages):
        key, sub = jax.random.split(key)
        stage_flats.append(M.init_params(sub, M.stage_specs(cfg, s, n_stages)))
    full_flat = jnp.concatenate(stage_flats)

    loss, grads = M.make_full_fwd_bwd(cfg)(full_flat, tokens, targets)
    adam = M.make_adam(cfg, lr=1e-3)
    m = jnp.zeros_like(full_flat)
    v = jnp.zeros_like(full_flat)
    p2, m2, v2 = adam(full_flat, m, v, grads, jnp.ones(1))

    def dump(name, arr, dtype):
        np.asarray(arr, dtype=dtype).tofile(os.path.join(mdir, name))

    dump("full_flat.f32", full_flat, np.float32)
    dump("tokens.i32", tokens, np.int32)
    dump("targets.i32", targets, np.int32)
    dump("grads.f32", grads, np.float32)
    dump("adam_p.f32", p2, np.float32)
    dump("adam_m.f32", m2, np.float32)
    dump("adam_v.f32", v2, np.float32)

    # staged pipeline trace: y0 -> ... -> loss + per-stage grads
    acts, x = [], tokens
    for s in range(n_stages - 1):
        fns = M.make_stage_fns(cfg, s, n_stages)
        x = fns["fwd"](stage_flats[s], x) if s else fns["fwd"](stage_flats[0], tokens)
        acts.append(x)
        dump(f"act{s}.f32", x, np.float32)
    last = M.make_stage_fns(cfg, n_stages - 1, n_stages)
    loss_staged, dx, _glast = last["fwdbwd"](stage_flats[-1], acts[-1], targets)
    dump("dx_last.f32", dx, np.float32)

    meta = {
        "loss": float(loss),
        "loss_staged": float(loss_staged),
        "grads_l2": float(jnp.sqrt((grads ** 2).sum())),
        "n_params": int(full_flat.shape[0]),
        "stage_sizes": [int(f.shape[0]) for f in stage_flats],
    }
    with open(os.path.join(mdir, "golden.json"), "w") as f:
        json.dump(meta, f, indent=1)


DEFAULT_EXPORTS = [
    # (preset, n_stages, with_full)  — what `make artifacts` builds
    ("tiny", 4, True),
    ("e2e-25m", 2, True),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default=None, help="preset name; default = standard set")
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--full", action="store_true", help="also export whole-model fwd_bwd")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    jobs = ([(args.model, args.stages, args.full)] if args.model
            else DEFAULT_EXPORTS)
    for preset, n_stages, full in jobs:
        cfg = M.PRESETS[preset]
        man = export_model(cfg, n_stages, args.out, with_full=full, lr=args.lr)
        if preset == "tiny":
            export_golden(cfg, n_stages, args.out)
        print(f"exported {preset}: {man['total_params']} params, "
              f"{n_stages} stages -> {args.out}/{preset}/")


if __name__ == "__main__":
    main()
