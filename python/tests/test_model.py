"""L2 model correctness: stage composition, shapes, gradients, layouts.

Key invariant for the whole system: running the pipeline stage functions in
sequence (with activation hand-off) must equal the whole-model function — the
rust pipeline engine depends on that equivalence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]
CFG_REF = M.ModelConfig(**{**CFG.__dict__, "use_pallas": False})


def tiny_batch(seed=0):
    k = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(k, (CFG.batch, CFG.seq), 0, CFG.vocab)
    targets = jnp.roll(tokens, -1, axis=1)
    return tokens, targets


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------


def test_split_layers_balanced_and_contiguous():
    for n_layers in range(1, 13):
        for n_stages in range(1, n_layers + 1):
            split = M.split_layers(n_layers, n_stages)
            flat = [l for part in split for l in part]
            assert flat == list(range(n_layers))
            sizes = [len(p) for p in split]
            assert max(sizes) - min(sizes) <= 1


@pytest.mark.parametrize("n_stages", [1, 2, 4])
def test_stage_specs_cover_model(n_stages):
    total = sum(M.specs_size(M.stage_specs(CFG, s, n_stages)) for s in range(n_stages))
    assert total == M.specs_size(M.stage_specs(CFG, 0, 1))


def test_unflatten_roundtrip():
    specs = M.stage_specs(CFG, 0, 2)
    n = M.specs_size(specs)
    flat = jnp.arange(n, dtype=jnp.float32)
    p = M.unflatten(flat, specs)
    off = 0
    for s in specs:
        np.testing.assert_array_equal(
            p[s.name].reshape(-1), flat[off:off + s.size])
        off += s.size


def test_init_matches_spec_kinds():
    specs = M.stage_specs(CFG, 1, 2)
    flat = M.init_params(jax.random.PRNGKey(0), specs)
    p = M.unflatten(flat, specs)
    for s in specs:
        if s.init == "ones":
            np.testing.assert_array_equal(p[s.name], jnp.ones(s.shape))
        elif s.init == "zeros":
            np.testing.assert_array_equal(p[s.name], jnp.zeros(s.shape))
        else:
            std = float(s.init.split(":")[1])
            assert abs(float(p[s.name].std()) - std) < std  # loose sanity


# ---------------------------------------------------------------------------
# stage composition == full model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_stages", [2, 4])
def test_pipeline_composition_equals_full_model(n_stages):
    tokens, targets = tiny_batch()
    key = jax.random.PRNGKey(42)
    full_specs = M.stage_specs(CFG, 0, 1)
    # build per-stage params, then concatenate into the full flat layout
    stage_flats = []
    for s in range(n_stages):
        key, sub = jax.random.split(key)
        stage_flats.append(M.init_params(sub, M.stage_specs(CFG, s, n_stages)))
    full_flat = jnp.concatenate(stage_flats)
    assert full_flat.shape[0] == M.specs_size(full_specs)

    # full model loss
    full_fn = M.stage_forward(CFG, 0, 1)
    loss_full = full_fn(full_flat, tokens, targets)

    # staged loss
    x = tokens
    for s in range(n_stages):
        fn = M.stage_forward(CFG, s, n_stages)
        if s == n_stages - 1:
            loss_staged = fn(stage_flats[s], x, targets)
        else:
            x = fn(stage_flats[s], x)
    np.testing.assert_allclose(loss_full, loss_staged, rtol=1e-5, atol=1e-5)


def test_staged_grads_equal_full_grads():
    n_stages = 2
    tokens, targets = tiny_batch(3)
    key = jax.random.PRNGKey(7)
    flats = []
    for s in range(n_stages):
        key, sub = jax.random.split(key)
        flats.append(M.init_params(sub, M.stage_specs(CFG, s, n_stages)))
    full_flat = jnp.concatenate(flats)

    loss_full, g_full = M.make_full_fwd_bwd(CFG)(full_flat, tokens, targets)

    fns0 = M.make_stage_fns(CFG, 0, n_stages)
    fns1 = M.make_stage_fns(CFG, 1, n_stages)
    y0 = fns0["fwd"](flats[0], tokens)
    loss, dx, g1 = fns1["fwdbwd"](flats[1], y0, targets)
    g0 = fns0["bwd"](flats[0], tokens, dx)

    np.testing.assert_allclose(loss, loss_full, rtol=1e-5, atol=1e-5)
    n0 = flats[0].shape[0]
    np.testing.assert_allclose(g0, g_full[:n0], rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(g1, g_full[n0:], rtol=5e-4, atol=5e-5)


def test_pallas_and_ref_model_agree():
    """The whole transformer with the Pallas kernels == with ref attention."""
    tokens, targets = tiny_batch(1)
    flat = M.init_params(jax.random.PRNGKey(5), M.stage_specs(CFG, 0, 1))
    loss_pallas = M.stage_forward(CFG, 0, 1)(flat, tokens, targets)
    loss_ref = M.stage_forward(CFG_REF, 0, 1)(flat, tokens, targets)
    np.testing.assert_allclose(loss_pallas, loss_ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# training sanity
# ---------------------------------------------------------------------------


def test_loss_decreases_under_adam():
    tokens, targets = tiny_batch(2)
    flat = M.init_params(jax.random.PRNGKey(0), M.stage_specs(CFG, 0, 1))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    fwd_bwd = jax.jit(M.make_full_fwd_bwd(CFG))
    adam = jax.jit(M.make_adam(CFG, lr=1e-3))
    losses = []
    for step in range(1, 11):
        loss, g = fwd_bwd(flat, tokens, targets)
        losses.append(float(loss))
        flat, m, v = adam(flat, m, v, g, jnp.array([float(step)], jnp.float32))
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()


def test_loss_is_log_vocab_at_init_scale():
    """Random init -> loss ~ ln(vocab)."""
    tokens, targets = tiny_batch(4)
    flat = M.init_params(jax.random.PRNGKey(9), M.stage_specs(CFG, 0, 1))
    loss = float(M.stage_forward(CFG, 0, 1)(flat, tokens, targets))
    assert abs(loss - np.log(CFG.vocab)) < 1.0
