"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps the shape space (heads, seq, d_head, block sizes) and the
dtype-adjacent knobs; every property asserts allclose against ref.py. These are
the build-time gate for the artifacts the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention, fused_adam
from compile.kernels import ref
from compile.kernels.flash_attention import _pick_block

jax.config.update("jax_enable_x64", False)

ATOL = 2e-5
RTOL = 2e-5


def rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# flash attention — forward
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(1, 4),
    seq_pow=st.integers(2, 7),  # seq in [4, 128]
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwd_matches_ref(h, seq_pow, d, causal, seed):
    seq = 2 ** seq_pow
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k0, (h, seq, d), jnp.float32)
    k = jax.random.normal(k1, (h, seq, d), jnp.float32)
    v = jax.random.normal(k2, (h, seq, d), jnp.float32)
    o = flash_attention(q, k, v, causal=causal)
    o_ref = ref.ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)


@settings(max_examples=10, deadline=None)
@given(
    seq=st.sampled_from([24, 48, 96]),  # non-power-of-two seq exercises _pick_block
    block_q=st.sampled_from([8, 16, 128]),
    block_k=st.sampled_from([8, 24, 128]),
)
def test_fwd_block_size_invariance(seq, block_q, block_k):
    q, k, v = rand(1, 2, seq, 16), rand(2, 2, seq, 16), rand(3, 2, seq, 16)
    o = flash_attention(q, k, v, block_q=block_q, block_k=block_k)
    o_ref = ref.ref_attention(q, k, v)
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)


def test_pick_block_divides():
    for seq in [1, 2, 24, 96, 128, 100, 17]:
        for want in [1, 8, 64, 128, 1000]:
            b = _pick_block(seq, want)
            assert 1 <= b <= max(1, min(want, seq)) and seq % b == 0


def test_fwd_under_jit_and_vmap():
    q, k, v = rand(1, 3, 2, 32, 16), rand(2, 3, 2, 32, 16), rand(3, 3, 2, 32, 16)
    f = jax.jit(jax.vmap(lambda a, b, c: flash_attention(a, b, c)))
    o = f(q, k, v)
    o_ref = jax.vmap(lambda a, b, c: ref.ref_attention(a, b, c))(q, k, v)
    np.testing.assert_allclose(o, o_ref, atol=ATOL, rtol=RTOL)


# ---------------------------------------------------------------------------
# flash attention — backward (custom VJP kernels)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 3),
    seq=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([8, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_bwd_matches_ref_vjp(h, seq, d, causal, seed):
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(k0, (h, seq, d), jnp.float32)
    k = jax.random.normal(k1, (h, seq, d), jnp.float32)
    v = jax.random.normal(k2, (h, seq, d), jnp.float32)
    do = jax.random.normal(k3, (h, seq, d), jnp.float32)

    _, pull = jax.vjp(lambda a, b, c: flash_attention(a, b, c, causal=causal), q, k, v)
    dq, dk, dv = pull(do)
    _, pull_ref = jax.vjp(lambda a, b, c: ref.ref_attention(a, b, c, causal=causal), q, k, v)
    dq_r, dk_r, dv_r = pull_ref(do)
    np.testing.assert_allclose(dq, dq_r, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(dk, dk_r, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(dv, dv_r, atol=5e-5, rtol=5e-5)


def test_bwd_through_scalar_loss():
    q, k, v = rand(7, 2, 32, 16), rand(8, 2, 32, 16), rand(9, 2, 32, 16)
    g = jax.grad(lambda a: flash_attention(a, k, v).sum())(q)
    g_ref = jax.grad(lambda a: ref.ref_attention(a, k, v).sum())(q)
    np.testing.assert_allclose(g, g_ref, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# fused adam
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5000),
    step=st.integers(1, 10_000),
    lr=st.sampled_from([1e-4, 1e-3, 1e-2]),
    wd=st.sampled_from([0.0, 0.01]),
    block=st.sampled_from([64, 256, 65536]),
    seed=st.integers(0, 2**31 - 1),
)
def test_adam_matches_ref(n, step, lr, wd, block, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = jax.random.normal(ks[0], (n,), jnp.float32)
    m = jax.random.normal(ks[1], (n,), jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[2], (n,), jnp.float32)) * 0.01
    g = jax.random.normal(ks[3], (n,), jnp.float32)
    stepf = jnp.array([float(step)], jnp.float32)

    out = fused_adam(p, m, v, g, stepf, lr=lr, weight_decay=wd, block=block)
    out_ref = ref.ref_adam(p, m, v, g, float(step), lr=lr, weight_decay=wd)
    for a, b, name in zip(out, out_ref, ["p", "m", "v"]):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-5, err_msg=name)


def test_adam_moments_start_zero():
    """First step from zero moments == SGD-ish step of size ~lr (bias-corrected)."""
    n = 128
    p = jnp.ones((n,))
    g = jnp.ones((n,))
    z = jnp.zeros((n,))
    p2, m2, v2 = fused_adam(p, z, z, g, jnp.ones(1), lr=1e-3)
    # bias correction makes m_hat = g, v_hat = g^2 -> update = lr * sign(g)
    np.testing.assert_allclose(p2, p - 1e-3 / (1.0 + 1e-8), rtol=1e-6)
    np.testing.assert_allclose(m2, 0.1 * g, rtol=1e-6)
    np.testing.assert_allclose(v2, 0.001 * g * g, rtol=1e-4)


def test_adam_padding_tail_not_written():
    """n not divisible by block: outputs only cover [0, n)."""
    n, block = 100, 64
    p = jnp.arange(n, dtype=jnp.float32)
    z = jnp.zeros(n)
    g = jnp.ones(n)
    p2, m2, v2 = fused_adam(p, z, z, g, jnp.ones(1), block=block)
    assert p2.shape == (n,) and m2.shape == (n,) and v2.shape == (n,)
