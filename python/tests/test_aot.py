"""AOT export: the HLO-text artifacts + manifest the rust runtime consumes.

Verifies the lowering pipeline (StableHLO -> XlaComputation -> HLO text)
produces parseable modules with the expected parameter/result signature, and
that the manifest is consistent with the model layout.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def tiny_export(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    man = aot.export_model(M.PRESETS["tiny"], 2, out, with_full=True, lr=1e-3)
    return out, man


def test_manifest_stage_layout(tiny_export):
    out, man = tiny_export
    assert man["n_stages"] == 2
    total = sum(s["n_params"] for s in man["stages"])
    assert total == man["total_params"]
    for st in man["stages"]:
        off = 0
        for p in st["params"]:
            assert p["offset"] == off
            sz = 1
            for d in p["shape"]:
                sz *= d
            assert sz == p["size"]
            off += p["size"]
        assert off == st["n_params"]


def test_manifest_artifacts_exist_and_nonempty(tiny_export):
    out, man = tiny_export
    mdir = os.path.join(out, "tiny")
    for st in man["stages"]:
        for kind, art in st["artifacts"].items():
            path = os.path.join(mdir, os.path.basename(art["path"]))
            assert os.path.isfile(path), (kind, path)
            assert os.path.getsize(path) > 100
    assert "full" in man


def test_hlo_text_is_valid_hlo(tiny_export):
    out, man = tiny_export
    mdir = os.path.join(out, "tiny")
    text = open(os.path.join(
        mdir, os.path.basename(man["stages"][0]["artifacts"]["fwd"]["path"]))).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # parameters: flat f32 params + i32 tokens
    assert "f32[" in text and "s32[" in text


def test_manifest_json_roundtrip(tiny_export):
    out, man = tiny_export
    with open(os.path.join(out, "tiny", "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == json.loads(json.dumps(man))


def test_hlo_text_parses_back(tiny_export):
    """The text must round-trip through XLA's HLO parser — the exact mechanism
    the rust side (HloModuleProto::from_text_file) relies on."""
    out, man = tiny_export
    from jax._src.lib import xla_client as xc
    path = os.path.join(out, "tiny", "full_fwd_bwd.hlo.txt")
    mod = xc._xla.hlo_module_from_text(open(path).read())
    assert "full" in mod.name or "fwd" in mod.name or len(mod.name) > 0


def test_golden_consistent_with_eager(tmp_path):
    """golden/ files (the rust integration tests' numeric contract) must match
    an eager recompute with the same seeds."""
    import numpy as np
    out = str(tmp_path)
    cfg = M.PRESETS["tiny"]
    aot.export_golden(cfg, 2, out)
    g = os.path.join(out, "tiny", "golden")
    meta = json.load(open(os.path.join(g, "golden.json")))

    flat = np.fromfile(os.path.join(g, "full_flat.f32"), dtype=np.float32)
    tokens = np.fromfile(os.path.join(g, "tokens.i32"), dtype=np.int32).reshape(
        cfg.batch, cfg.seq)
    targets = np.fromfile(os.path.join(g, "targets.i32"), dtype=np.int32).reshape(
        cfg.batch, cfg.seq)
    grads = np.fromfile(os.path.join(g, "grads.f32"), dtype=np.float32)
    assert flat.shape[0] == meta["n_params"] == sum(meta["stage_sizes"])

    loss_e, grads_e = M.make_full_fwd_bwd(cfg)(
        jnp.asarray(flat), jnp.asarray(tokens), jnp.asarray(targets))
    np.testing.assert_allclose(float(loss_e), meta["loss"], rtol=1e-5)
    np.testing.assert_allclose(grads_e, grads, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        float(jnp.sqrt((grads_e ** 2).sum())), meta["grads_l2"], rtol=1e-4)
    # the staged loss must agree with the full-model loss
    np.testing.assert_allclose(meta["loss_staged"], meta["loss"], rtol=1e-5)
