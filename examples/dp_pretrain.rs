//! DP pretraining with live FT-method comparison: run the same tiny workload
//! under each fault-tolerance method and report measured wall-time costs plus
//! the modeled Fig. 3-style utilization breakdown.
//!
//! ```bash
//! cargo run --release --example dp_pretrain            # tiny, 10 steps each
//! cargo run --release --example dp_pretrain -- --steps 20 --dp 4
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use reft::checkpoint::MemStorage;
use reft::config::{FtMethod, RunConfig};
use reft::hwsim::{ClusterHw, HwSpec};
use reft::snapshot::{cost, SnapshotPlan};
use reft::topology::{ParallelPlan, Topology};
use reft::trainer::DpTrainer;
use reft::util::human_secs;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut flags: HashMap<String, String> = HashMap::new();
    let mut i = 0;
    while i + 1 < args.len() {
        flags.insert(args[i].trim_start_matches("--").into(), args[i + 1].clone());
        i += 2;
    }
    let steps: usize = flags.get("steps").map(|s| s.parse()).unwrap_or(Ok(10))?;
    let dp: usize = flags.get("dp").map(|s| s.parse()).unwrap_or(Ok(2))?;

    println!("== DP pretraining: fault-tolerance method comparison ==");
    println!("model=tiny dp={dp} steps={steps}\n");
    println!(
        "{:<14} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "method", "final loss", "fwd_bwd mean", "save mean", "save count", "wall (s)"
    );

    for method in [
        FtMethod::None,
        FtMethod::CheckFreq,
        FtMethod::TorchSnapshot,
        FtMethod::ReftSn,
        FtMethod::ReftCkpt,
    ] {
        let mut cfg = RunConfig::default();
        cfg.model = "tiny".into();
        cfg.plan = ParallelPlan::dp_only(dp);
        cfg.nodes = dp.div_ceil(4).max(2);
        cfg.ft.method = method;
        cfg.ft.snapshot_interval = 1;
        let t0 = std::time::Instant::now();
        let mut tr = DpTrainer::new(cfg, Arc::new(MemStorage::new()))?;
        let losses = tr.run(steps)?;
        let wall = t0.elapsed().as_secs_f64();
        let fwd = tr.metrics.timer("fwd_bwd");
        let save = if method == FtMethod::ReftSn || method == FtMethod::ReftCkpt {
            tr.metrics.timer("snapshot")
        } else {
            tr.metrics.timer("ckpt_put")
        };
        println!(
            "{:<14} {:>10.4} {:>12} {:>12} {:>12} {:>10.2}",
            method.name(),
            losses.last().unwrap(),
            human_secs(fwd.mean()),
            human_secs(save.mean()),
            save.count,
            wall
        );
    }

    // modeled utilization breakdown (Fig. 3 flavour) on the paper testbed:
    // OPT-2.7B, 2 DP x 4 TP x 3 PP, per-iteration compute ~ 1 s
    println!("\n== modeled utilization during 3D pretraining (Fig. 3 shape) ==");
    let spec = reft::config::zoo::zoo_model("opt-2.7b").unwrap();
    let topo = Topology::build(ParallelPlan::new(2, 4, 3), 6, 4)?;
    let stage_bytes: Vec<u64> = (0..3).map(|s| spec.stage_params(s, 3) * 16).collect();
    let plan = SnapshotPlan::build(&topo, &stage_bytes);
    let iter_secs = 1.0;
    for (name, method, raim5) in [
        ("no-ft", reft::config::FtMethod::None, false),
        ("reft-sn", reft::config::FtMethod::ReftSn, true),
    ] {
        let ft = reft::config::FtConfig { method, raim5, ..Default::default() };
        let mut hw = ClusterHw::new(HwSpec::paper_testbed());
        let ctx = cost::SaveCtx { topo: &topo, plan: &plan, ft: &ft, iter_compute_secs: iter_secs };
        let c = cost::method_save_cost(&mut hw, &ctx);
        let bubble = reft::pipeline::bubble_fraction(3, 8);
        let gpu_util = (1.0 - bubble) * iter_secs / (iter_secs + c.stall);
        let cpu_util = if method == reft::config::FtMethod::None {
            0.05 // data loading only
        } else {
            0.05 + (c.shamem + c.ec_encode) / (iter_secs + c.stall)
        };
        println!(
            "  {name:<8} GPU busy ~{:>5.1}%   CPU busy ~{:>5.1}%   (save total {} / stall {})",
            gpu_util * 100.0,
            cpu_util.min(1.0) * 100.0,
            human_secs(c.total),
            human_secs(c.stall)
        );
    }
    println!("\n(the paper's Fig. 3 point: 3D pretraining leaves the CPU nearly idle —");
    println!(" REFT spends that headroom on fault tolerance instead of GPU time)");
    Ok(())
}
