// §Perf dev probe: live snapshot/restore throughput across RAIM5/bucket
// configurations (used for the EXPERIMENTS.md §Perf iteration log).
use reft::config::FtConfig;
use reft::elastic::ReftCluster;
use reft::snapshot::SharedPayload;
use reft::topology::{ParallelPlan, Topology};
use std::time::Instant;

fn main() {
    let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
    let plen = 192 * 1024 * 1024usize;
    let payload = SharedPayload::new(vec![0xABu8; plen]);
    for (raim5, bucket) in [(false, 16<<20), (true, 16<<20), (true, 1<<20), (true, 64<<20)] {
        let ft = FtConfig { bucket_bytes: bucket, raim5, ..FtConfig::default() };
        let mut c = ReftCluster::start(topo.clone(), &[plen as u64], ft).unwrap();
        let payloads = vec![payload.clone()]; // Arc clone — zero-copy
        c.snapshot_all(&payloads).unwrap(); // warm
        let t0 = Instant::now();
        for _ in 0..3 { c.snapshot_all(&payloads).unwrap(); }
        let dt = t0.elapsed().as_secs_f64() / 3.0;
        println!("raim5={raim5} bucket={}MiB: snapshot {:.0} ms ({:.2} GB/s)", bucket>>20, dt*1e3, plen as f64/dt/1e9);
        let t0 = Instant::now();
        for _ in 0..3 { std::hint::black_box(c.restore_all(&[]).unwrap()); }
        let dt = t0.elapsed().as_secs_f64() / 3.0;
        println!("  restore {:.0} ms ({:.2} GB/s)", dt*1e3, plen as f64/dt/1e9);
    }
}
