//! End-to-end validation driver (DESIGN.md "End-to-end validation"):
//! pretrain a transformer for a few hundred steps on synthetic corpus data
//! with REFT fault tolerance, surviving one software failure and one node
//! failure mid-run, and log the loss curve.
//!
//! ```bash
//! make artifacts
//! # full run (~25M params, 300 steps — budget ~1-2 h on 1 CPU core):
//! cargo run --release --example train_e2e
//! # quick run on the tiny model:
//! cargo run --release --example train_e2e -- --model tiny --steps 40
//! # 2-stage pipeline flavour:
//! cargo run --release --example train_e2e -- --model e2e-25m --pp 2 --steps 100
//! # replay a run bit-for-bit (data stream + init are seed-derived):
//! cargo run --release --example train_e2e -- --seed 1234
//! ```
//!
//! Outputs `artifacts/e2e_loss.csv` (step, loss, event) — the run recorded in
//! EXPERIMENTS.md.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use reft::checkpoint::{DirStorage, Storage};
use reft::config::{FtMethod, RunConfig};
use reft::pipeline::Schedule;
use reft::topology::ParallelPlan;
use reft::trainer::{DpTrainer, PipelineTrainer};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut flags: HashMap<String, String> = HashMap::new();
    let mut i = 0;
    while i + 1 < args.len() + 1 {
        if i + 1 >= args.len() && args.get(i).map(|a| a.starts_with("--")).unwrap_or(false) {
            anyhow::bail!("flag {} needs a value", args[i]);
        }
        if i >= args.len() {
            break;
        }
        flags.insert(
            args[i].trim_start_matches("--").to_string(),
            args.get(i + 1).cloned().unwrap_or_default(),
        );
        i += 2;
    }

    let model = flags.get("model").cloned().unwrap_or_else(|| "e2e-25m".into());
    let steps: usize = flags.get("steps").map(|s| s.parse()).unwrap_or(Ok(300))?;
    let pp: usize = flags.get("pp").map(|s| s.parse()).unwrap_or(Ok(1))?;
    let dp: usize = flags.get("dp").map(|s| s.parse()).unwrap_or(Ok(2))?;
    // hierarchical async snapshot coordination (§4.1) is the default here;
    // `--async false` runs the blocking save path — comparing the two runs'
    // "save stall" lines is the live sync-vs-async measurement
    let async_on = flags
        .get("async")
        .map(|s| s == "true" || s == "1")
        .unwrap_or(true);
    // `--persist false` reverts to the inline trainer-thread puts — the two
    // runs' "persist" report lines are the live engine-vs-inline comparison
    let persist_on = flags
        .get("persist")
        .map(|s| s == "true" || s == "1")
        .unwrap_or(true);
    // `--auto-cadence true` turns the whole adaptive control plane on:
    // Eq. 9 snapshot cadence, Eq. 11 persist cadence, adaptive pipeline
    // depth. Off by default so the static knobs stay the baseline run.
    let auto_cadence = flags
        .get("auto-cadence")
        .map(|s| s == "true" || s == "1")
        .unwrap_or(false);
    // `--delta-extent N` turns the sparse-snapshot layer on (0 = off):
    // extent tables diff consecutive rounds so both planes ship only the
    // changed bytes; the control-plane line reports the resulting ratio
    let delta_extent: usize = flags.get("delta-extent").map(|s| s.parse()).unwrap_or(Ok(0))?;
    // `--trace-out PATH` turns the span tracer on for the whole run and
    // writes a Chrome/Perfetto trace there at the end — load it in
    // https://ui.perfetto.dev to see the enqueue→drain→persist chain per
    // round. Tracing must be enabled before the trainer spawns its SMP and
    // persist threads so their per-thread buffers capture from step 0.
    let trace_out = flags.get("trace-out").cloned();
    if trace_out.is_some() {
        reft::obs::enable();
    }
    // `--seed N` replays the exact run: parameter init and the synthetic
    // corpus stream both derive from RunConfig::seed, so a recorded seed
    // reproduces the loss curve byte for byte
    let seed: u64 = flags.get("seed").map(|s| s.parse()).unwrap_or(Ok(RunConfig::default().seed))?;

    let mut cfg = RunConfig::default();
    cfg.model = model.clone();
    cfg.seed = seed;
    cfg.plan = if pp > 1 {
        ParallelPlan::new(dp, 1, pp)
    } else {
        ParallelPlan::dp_only(dp)
    };
    cfg.nodes = (dp * pp).div_ceil(4).max(2);
    cfg.microbatches = 2;
    cfg.ft.method = FtMethod::ReftCkpt;
    cfg.ft.snapshot_interval = 5;
    cfg.ft.persist_every = 4; // durable checkpoint every 20 steps
    cfg.ft.raim5 = true;
    cfg.ft.async_snapshot = async_on;
    // durable tier via the background persistence engine: persists drain
    // off the training thread, commit atomic manifests, keep-last-3. The
    // engine overlaps up to 2 jobs (fetch/upload pipelined, commits stay
    // ordered) and lands big shards as resumable multipart parts with
    // per-part CRCs (256 KiB here so the small e2e payloads exercise it).
    cfg.ft.persist.enabled = persist_on;
    cfg.ft.persist.keep_last = 3;
    cfg.ft.persist.pipeline_jobs = 2;
    cfg.ft.persist.multipart_part_bytes = 256 * 1024;
    // the adaptive control plane (all three decision layers)
    cfg.ft.auto_snapshot_interval = auto_cadence;
    cfg.ft.persist.auto_interval = auto_cadence;
    cfg.ft.persist.adaptive_depth = auto_cadence;
    // sparse delta snapshots (same clamp as the CLI: 0 disables)
    cfg.ft.delta_extent_bytes = if delta_extent == 0 { 0 } else { delta_extent.max(1024) };

    // fresh checkpoint dir per run: a stale checkpoint from an earlier run
    // must never satisfy this run's fallback path
    let ckpt_dir = format!("{}/e2e_ckpts_{}", cfg.artifacts_dir, std::process::id());
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let storage: Arc<dyn Storage> = Arc::new(DirStorage::new(&ckpt_dir)?);

    println!("== REFT end-to-end driver ==");
    println!(
        "model={model} steps={steps} plan=dp{dp}/pp{pp} ft=reft-ckpt \
         snapshot_every=5 persist_every=20 async_snapshot={async_on} \
         persist_engine={persist_on} auto_cadence={auto_cadence} \
         delta_extent={} seed={seed}",
        cfg.ft.delta_extent_bytes
    );

    // inject only after at least one snapshot round exists (interval 5)
    let sw_fail_at = (steps / 3).max(6);
    let hw_fail_at = (2 * steps / 3).max(12);
    let mut rows: Vec<(u64, f32, &'static str)> = Vec::new();
    let t0 = Instant::now();

    macro_rules! drive {
        ($tr:expr, $step_fn:expr, $recover:expr) => {{
            let mut done = 0usize;
            let inmem_before = $tr.metrics.counter("recoveries_inmemory");
            while done < steps {
                let (step_no, loss) = $step_fn($tr)?;
                done += 1;
                let mut event = "";
                if done == sw_fail_at {
                    println!("!! injecting SOFTWARE failure at step {step_no}");
                    $tr.inject_software_failure();
                    let resumed = $recover($tr, &[])?;
                    println!("   recovered from SMPs at step {resumed}");
                    event = "sw-failure+smp-recover";
                } else if done == hw_fail_at && $tr.topo.sharding_group(0).len() >= 2 {
                    println!("!! injecting NODE failure (node 0) at step {step_no}");
                    $tr.inject_node_failure(0);
                    let resumed = $recover($tr, &[0])?;
                    let path = if $tr.metrics.counter("recoveries_inmemory") > inmem_before {
                        "RAIM5 decode from SG peers"
                    } else {
                        "durable checkpoint (SG had no peers)"
                    };
                    println!("   recovered via {path} at step {resumed}");
                    event = "hw-failure+recover";
                }
                rows.push((step_no, loss, event));
                if done % 10 == 0 || done == steps {
                    let dt = t0.elapsed().as_secs_f64();
                    println!(
                        "step {step_no:>5}  loss {loss:.4}   ({:.2} s/step)",
                        dt / done as f64
                    );
                }
            }
            if $tr.topo.sharding_group(0).len() < 2 {
                println!(
                    "(node-failure injection skipped: single-node sharding group \
                     has no RAIM5 peers — see examples/failure_recovery.rs)"
                );
            }
            // the sync-vs-async stall measurement: with --async true the
            // "snapshot" timer is the L1 enqueue and "snapshot_tick" the L2
            // per-iteration drain; with --async false "snapshot" is the full
            // blocking round. Compare the two runs' max values.
            let snap = $tr.metrics.timer("snapshot");
            let tick = $tr.metrics.timer("snapshot_tick");
            println!(
                "save stall ({}): snapshot() max {:.3} ms / mean {:.3} ms over {} calls; \
                 tick max {:.3} ms / mean {:.3} ms over {} ticks",
                if async_on { "async enqueue" } else { "blocking round" },
                snap.max * 1e3,
                snap.mean() * 1e3,
                snap.count,
                tick.max * 1e3,
                tick.mean() * 1e3,
                tick.count
            );
            // drain the durable tier before reading its counters: the only
            // blocking persistence call, and it happens after training
            $tr.flush_persist()?;
            let pstall = $tr.metrics.timer("persist_stall");
            let pflush = $tr.metrics.timer("persist_flush");
            println!(
                "persist stall ({}): {} bytes drained in {} manifests \
                 ({} aborted); {} multipart parts uploaded / {} reused; \
                 trainer-thread stall max {:.3} ms / mean {:.3} ms \
                 over {} enqueues; shutdown flush {:.1} ms",
                if persist_on { "background engine" } else { "inline put" },
                $tr.metrics.counter("persisted_bytes"),
                $tr.metrics.counter("persist_commits"),
                $tr.metrics.counter("persist_aborts"),
                $tr.metrics.counter("persist_parts_uploaded"),
                $tr.metrics.counter("persist_parts_reused"),
                pstall.max * 1e3,
                pstall.mean() * 1e3,
                pstall.count,
                pflush.total * 1e3,
            );
            if !persist_on {
                let enc = $tr.metrics.timer("ckpt_encode");
                let put = $tr.metrics.timer("ckpt_put");
                println!(
                    "  (inline baseline: encode mean {:.3} ms + put mean {:.3} ms \
                     per checkpoint, on the training thread)",
                    enc.mean() * 1e3,
                    put.mean() * 1e3
                );
            }
            // the adaptive control plane's run report: where each decision
            // layer landed, whether the recovery predictions held, and how
            // much of the durable traffic the sparse-delta layer saved
            let pfull = $tr.metrics.counter("persisted_full_bytes");
            let pdelta = $tr.metrics.counter("persisted_delta_bytes");
            let delta_pct = if pfull + pdelta == 0 {
                0.0
            } else {
                pdelta as f64 * 100.0 / (pfull + pdelta) as f64
            };
            println!(
                "control plane: snapshot cadence {} steps (λ {:.2e}), persist cadence {} \
                 steps, pipeline depth {}; recovery plans {} \
                 (inmem {} / manifest {} / legacy {}) mispredictions {}; \
                 persisted full/delta {pfull}/{pdelta} B (delta share {delta_pct:.1}%)",
                $tr.metrics
                    .gauge_value("snapshot_interval_steps")
                    .unwrap_or(cfg.ft.snapshot_interval as f64),
                $tr.metrics.gauge_value("snapshot_lambda_node").unwrap_or(0.0),
                $tr.metrics
                    .gauge_value("persist_interval_steps")
                    .unwrap_or((cfg.ft.persist_every * cfg.ft.snapshot_interval) as f64),
                $tr.metrics
                    .gauge_value("persist_pipeline_depth")
                    .unwrap_or(cfg.ft.persist.pipeline_jobs as f64),
                $tr.metrics.counter("recovery_plans"),
                $tr.metrics.counter("recovery_predicted_inmemory"),
                $tr.metrics.counter("recovery_predicted_manifest"),
                $tr.metrics.counter("recovery_predicted_legacy"),
                $tr.metrics.counter("recovery_mispredictions"),
            );
            // the same report as one machine-readable line: field names are
            // the metrics keys themselves so CI greps and dashboards never
            // chase a renamed column (keys alphabetical — util/json.rs
            // JsonWriter round-trips byte-identically through JsonReader)
            let mut w = reft::util::json::JsonWriter::with_capacity(512);
            w.begin_obj();
            w.key("persist_aborts");
            w.u64($tr.metrics.counter("persist_aborts"));
            w.key("persist_commits");
            w.u64($tr.metrics.counter("persist_commits"));
            w.key("persist_interval_steps");
            w.num(
                $tr.metrics
                    .gauge_value("persist_interval_steps")
                    .unwrap_or((cfg.ft.persist_every * cfg.ft.snapshot_interval) as f64),
            );
            w.key("persist_pipeline_depth");
            w.num(
                $tr.metrics
                    .gauge_value("persist_pipeline_depth")
                    .unwrap_or(cfg.ft.persist.pipeline_jobs as f64),
            );
            w.key("persist_stall_p99_s");
            w.num($tr.metrics.timer_quantile("persist_stall", 0.99));
            w.key("persisted_bytes");
            w.u64($tr.metrics.counter("persisted_bytes"));
            w.key("persisted_delta_bytes");
            w.u64(pdelta);
            w.key("persisted_full_bytes");
            w.u64(pfull);
            w.key("recoveries_inmemory");
            w.u64($tr.metrics.counter("recoveries_inmemory"));
            w.key("recovery_mispredictions");
            w.u64($tr.metrics.counter("recovery_mispredictions"));
            w.key("recovery_plans");
            w.u64($tr.metrics.counter("recovery_plans"));
            w.key("recovery_predicted_inmemory");
            w.u64($tr.metrics.counter("recovery_predicted_inmemory"));
            w.key("recovery_predicted_legacy");
            w.u64($tr.metrics.counter("recovery_predicted_legacy"));
            w.key("recovery_predicted_manifest");
            w.u64($tr.metrics.counter("recovery_predicted_manifest"));
            w.key("snapshot_interval_steps");
            w.num(
                $tr.metrics
                    .gauge_value("snapshot_interval_steps")
                    .unwrap_or(cfg.ft.snapshot_interval as f64),
            );
            w.key("snapshot_lambda_node");
            w.num($tr.metrics.gauge_value("snapshot_lambda_node").unwrap_or(0.0));
            w.key("snapshot_stall_p99_s");
            w.num($tr.metrics.timer_quantile("snapshot", 0.99));
            w.end_obj();
            println!(
                "control_plane_json: {}",
                String::from_utf8(w.finish()).expect("json is utf-8")
            );
            format!("{}", $tr.metrics.to_json())
        }};
    }

    let metrics_json = if pp > 1 {
        let mut tr = PipelineTrainer::new(cfg.clone(), storage, Schedule::OneFOneB)?;
        drive!(
            &mut tr,
            |t: &mut PipelineTrainer| -> anyhow::Result<(u64, f32)> {
                let loss = t.step()?;
                Ok((t.stages[0].step, loss))
            },
            |t: &mut PipelineTrainer, dead: &[usize]| t.recover(dead)
        )
    } else {
        let mut tr = DpTrainer::new(cfg.clone(), storage)?;
        drive!(
            &mut tr,
            |t: &mut DpTrainer| -> anyhow::Result<(u64, f32)> {
                let rep = t.step()?;
                Ok((rep.step, rep.loss))
            },
            |t: &mut DpTrainer, dead: &[usize]| t.recover(dead)
        )
    };

    // loss curve out
    let csv_path = format!("{}/e2e_loss.csv", cfg.artifacts_dir);
    let mut csv = String::from("step,loss,event\n");
    for (s, l, e) in &rows {
        csv.push_str(&format!("{s},{l},{e}\n"));
    }
    std::fs::write(&csv_path, csv)?;

    let first = rows.iter().take(5).map(|r| r.1).sum::<f32>() / 5.0;
    let last = rows.iter().rev().take(5).map(|r| r.1).sum::<f32>() / 5.0;
    println!("\nloss: first-5 mean {first:.4} -> last-5 mean {last:.4}");
    println!("wall time: {:.1} s total", t0.elapsed().as_secs_f64());
    println!("loss curve written to {csv_path}");
    println!("metrics: {metrics_json}");
    if let Some(path) = trace_out.as_deref() {
        let dump = reft::obs::drain();
        let n = dump.events.len();
        let dropped = dump.dropped;
        std::fs::write(path, reft::obs::chrome_trace_json(&dump))?;
        println!("trace: {n} events ({dropped} dropped) written to {path}");
    }
    if steps >= 100 {
        anyhow::ensure!(last < first, "loss did not descend");
        println!("\nE2E OK: loss descended through 1 software + 1 hardware failure");
    } else if last < first {
        println!("\nE2E OK: loss descended through 1 software + 1 hardware failure");
    } else {
        println!(
            "\nE2E OK: survived 1 software + 1 hardware failure (short run: \
             loss trend not asserted under {steps} steps)"
        );
    }
    Ok(())
}
