//! Failure-recovery walkthrough at the byte level: the paper's Fig. 2
//! workflow on a live 6-node cluster — snapshot, lose nodes in different
//! patterns, watch the elastic decision tree pick SMP-restore / RAIM5-decode
//! / checkpoint-fallback, and verify every recovered byte.
//!
//! ```bash
//! cargo run --release --example failure_recovery
//! cargo run --release --example failure_recovery -- --seed 1234   # replay
//! ```
//! (No artifacts needed — this exercises the FT fabric directly.)

use reft::config::FtConfig;
use reft::elastic::{
    decide, DurableAvailability, DurableTier, NodeStatus, RecoveryDecision, RecoveryPath,
    RecoveryPlan, ReftCluster,
};
use reft::snapshot::SharedPayload;
use reft::topology::{ParallelPlan, Topology};
use reft::util::human_bytes;
use reft::util::rng::Rng;

fn payloads(stage_bytes: &[u64], seed: u64) -> Vec<SharedPayload> {
    let mut rng = Rng::seed_from(seed);
    stage_bytes
        .iter()
        .map(|&b| SharedPayload::new((0..b).map(|_| rng.next_u64() as u8).collect()))
        .collect()
}

fn main() -> anyhow::Result<()> {
    // `--trace-out PATH`: record the walkthrough's elastic/SMP span stream
    // and write a Chrome/Perfetto trace at the end (same flag as train_e2e)
    let args: Vec<String> = std::env::args().skip(1).collect();
    let trace_out = args
        .iter()
        .position(|a| a == "--trace-out")
        .and_then(|i| args.get(i + 1).cloned());
    if trace_out.is_some() {
        reft::obs::enable();
    }
    // `--seed N` replays the walkthrough byte for byte: both clusters'
    // payloads fork off this one master through the hwsim seed-stream
    // discipline (domain-tagged forks, so extra draws in one consumer
    // never shift another)
    let master_seed: u64 = args
        .iter()
        .position(|a| a == "--seed")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.parse().expect("--seed takes a u64"))
        .unwrap_or(42);
    let mut payload_rng = reft::hwsim::seed::stream(master_seed, reft::hwsim::seed::PAYLOAD);

    // the paper's Fig. 3 topology: 2 DP x 4 TP x 3 PP on 6 nodes x 4 GPUs
    let topo = Topology::build(ParallelPlan::new(2, 4, 3), 6, 4)?;
    let stage_bytes = vec![8_000_000u64, 6_000_000, 7_000_000];
    let ft = FtConfig::default();

    println!("== REFT failure-recovery walkthrough ==");
    println!("seed: {master_seed} (replay with --seed {master_seed})");
    println!("topology: 2 DP x 4 TP x 3 PP on 6 nodes (paper Fig. 3 setup)");
    for sg in topo.sharding_groups() {
        println!("  SG_{} (stage {}) = nodes {:?}", sg.stage, sg.stage, sg.nodes);
    }

    println!("\n-- bring-up + first snapshot round --");
    let mut cluster = ReftCluster::start(topo.clone(), &stage_bytes, ft)?;
    let data = payloads(&stage_bytes, payload_rng.next_u64());
    let v = cluster.snapshot_all(&data)?;
    println!(
        "snapshot v{v}: {} sharded across SGs, RAIM5 parity placed",
        human_bytes(stage_bytes.iter().sum())
    );
    println!(
        "SMP-resident bytes: {}",
        human_bytes(cluster.resident_bytes()? as u64)
    );

    // scenario 1: software failure — SMPs untouched
    println!("\n-- scenario 1: software failure on node 2 --");
    let mut status = vec![NodeStatus::Healthy; 6];
    status[2] = NodeStatus::Unhealthy;
    let d = decide(&topo, &status, true, DurableAvailability { legacy: true, legacy_step: Some(40), ..Default::default() });
    println!("decision: {d:?}");
    assert_eq!(d, RecoveryDecision::ResumeFromSmp);
    let restored = cluster.restore_all(&[])?;
    assert_eq!(restored, data);
    println!("restored all 3 stage payloads bit-exact from SMPs ✓");

    // scenario 2: single node loss — RAIM5 decode
    println!("\n-- scenario 2: hardware failure, node 4 offline --");
    let mut status = vec![NodeStatus::Healthy; 6];
    status[4] = NodeStatus::Offline;
    let d = decide(&topo, &status, true, DurableAvailability { legacy: true, legacy_step: Some(40), ..Default::default() });
    println!("decision: {d:?}");
    cluster.kill_node(4);
    let restored = cluster.restore_all(&[4])?;
    assert_eq!(restored, data);
    println!("node 4's shard XOR-decoded from SG peers, payloads bit-exact ✓");
    cluster.replace_node(4)?;
    let v = cluster.snapshot_all(&data)?;
    println!("substitute node joined; snapshot v{v} re-covers the full group ✓");

    // scenario 3: two losses in one SG — exceeds protection
    println!("\n-- scenario 3: nodes 0 and 3 offline (both in SG_0) --");
    let mut status = vec![NodeStatus::Healthy; 6];
    status[0] = NodeStatus::Offline;
    status[3] = NodeStatus::Offline;
    let d = decide(&topo, &status, true, DurableAvailability { legacy: true, legacy_step: Some(40), ..Default::default() });
    println!("decision: {d:?}");
    assert_eq!(d, RecoveryDecision::LoadCheckpoint { tier: DurableTier::Legacy });
    cluster.kill_node(0);
    cluster.kill_node(3);
    let err = cluster.restore_all(&[0, 3]).unwrap_err();
    println!("in-memory restore correctly refused: {err}");
    println!("(training would reload the latest REFT-Ckpt from storage)");

    // scenario 4: RAIM5 disabled
    println!("\n-- scenario 4: same single-node loss with RAIM5 disabled --");
    let d = decide(
        &topo,
        &{
            let mut s = vec![NodeStatus::Healthy; 6];
            s[4] = NodeStatus::Offline;
            s
        },
        false,
        DurableAvailability { legacy: true, legacy_step: Some(40), ..Default::default() },
    );
    println!("decision: {d:?} (no parity -> must hit storage)");
    assert_eq!(d, RecoveryDecision::LoadCheckpoint { tier: DurableTier::Legacy });

    // scenario 5: same loss pattern, but a persistence-engine manifest has
    // committed — the decision names the manifest tier (sharded CRC-verified
    // parallel load) instead of the legacy inline blob
    println!("\n-- scenario 5: protection exceeded with a committed manifest --");
    let d = decide(
        &topo,
        &{
            let mut s = vec![NodeStatus::Healthy; 6];
            s[0] = NodeStatus::Offline;
            s[3] = NodeStatus::Offline;
            s
        },
        true,
        DurableAvailability {
            manifest: true,
            legacy: true,
            manifest_step: Some(60),
            legacy_step: Some(40),
        },
    );
    println!("decision: {d:?} (manifest tier preferred)");
    assert_eq!(d, RecoveryDecision::LoadCheckpoint { tier: DurableTier::Manifest });

    // scenario 6: the full control-plane flow the trainers run — probe the
    // durable tiers, plan BEFORE any restore attempt, execute, and account
    // predicted vs actual (the misprediction counter)
    println!("\n-- scenario 6: RecoveryPlan — probe first, restore second --");
    let storage = reft::checkpoint::MemStorage::new();
    let metrics = reft::metrics::Metrics::new();
    let plan = RecoveryPlan::probe(&topo, &[], true, &storage, "walkthrough");
    plan.record_predicted(&metrics);
    println!(
        "software failure, empty store: decision {:?} -> predicted {:?}",
        plan.decision,
        plan.predicted()
    );
    assert_eq!(plan.predicted(), Some(RecoveryPath::InMemory));
    let restored = cluster2_restore(&topo, &stage_bytes, payload_rng.next_u64())?;
    plan.record_actual(&metrics, RecoveryPath::InMemory);
    println!(
        "restored {} bytes from a fresh fabric; plans {} mispredictions {}",
        restored,
        metrics.counter("recovery_plans"),
        metrics.counter("recovery_mispredictions"),
    );
    assert_eq!(metrics.counter("recovery_mispredictions"), 0);

    println!("\nall scenarios behaved per the paper's recovery tree ✓");
    if let Some(path) = trace_out.as_deref() {
        let dump = reft::obs::drain();
        let n = dump.events.len();
        std::fs::write(path, reft::obs::chrome_trace_json(&dump))?;
        println!("trace: {n} events written to {path}");
    }
    Ok(())
}

/// A fresh protected fabric restored end to end — scenario 6's "actual"
/// leg (the walkthrough cluster above has two nodes down by now).
fn cluster2_restore(topo: &Topology, stage_bytes: &[u64], seed: u64) -> anyhow::Result<usize> {
    let mut cluster = ReftCluster::start(topo.clone(), stage_bytes, FtConfig::default())?;
    let data = payloads(stage_bytes, seed);
    cluster.snapshot_all(&data)?;
    let restored = cluster.restore_all(&[])?;
    anyhow::ensure!(restored == data, "scenario 6 restore diverged");
    Ok(restored.iter().map(Vec::len).sum())
}
