//! Quickstart: train a tiny transformer with REFT in-memory fault tolerance,
//! crash the training process, and resume from the SMPs — in ~30 seconds.
//!
//! ```bash
//! make artifacts            # once: AOT-lower the JAX/Pallas model
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use reft::checkpoint::MemStorage;
use reft::config::{FtMethod, RunConfig};
use reft::topology::ParallelPlan;
use reft::trainer::DpTrainer;

fn main() -> anyhow::Result<()> {
    // 1. configure: tiny model, 2-way data parallelism, REFT-Sn snapshots
    //    every step, RAIM5 parity on.
    let mut cfg = RunConfig::default();
    cfg.model = "tiny".into();
    cfg.plan = ParallelPlan::dp_only(2);
    cfg.nodes = 2;
    cfg.ft.method = FtMethod::ReftSn;
    cfg.ft.snapshot_interval = 1;

    println!("== REFT quickstart ==");
    println!("loading AOT artifacts (JAX/Pallas -> HLO text -> PJRT) ...");
    let mut trainer = DpTrainer::new(cfg, Arc::new(MemStorage::new()))?;
    println!(
        "model `{}`: {} params, snapshots sharded over {} nodes\n",
        trainer.cfg.model,
        trainer.manifest().total_params,
        trainer.topo.nodes_in_use()
    );

    // 2. train a few steps — every step ends with an async sharded snapshot
    //    into the per-node SMPs.
    for _ in 0..5 {
        let rep = trainer.step()?;
        println!("step {:>2}  loss {:.4}  [snapshotted]", rep.step, rep.loss);
    }

    // 3. kill the training processes (software failure): parameters in "GPU
    //    memory" are gone, but the SMPs — separate processes — still hold the
    //    last clean snapshot.
    println!("\n!! injecting software failure (training processes die)");
    trainer.inject_software_failure();

    // 4. elastic restart: restore bit-exact from the SMPs and keep going.
    let resumed = trainer.recover(&[])?;
    println!("recovered from SMPs at step {resumed} (bit-exact)\n");
    for _ in 0..3 {
        let rep = trainer.step()?;
        println!("step {:>2}  loss {:.4}", rep.step, rep.loss);
    }

    println!("\nmetrics: {}", trainer.metrics.to_json());
    println!("\nok — see examples/train_e2e.rs for the full 3D + RAIM5 demo");
    Ok(())
}
