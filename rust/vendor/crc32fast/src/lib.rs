//! Offline shim for the subset of `crc32fast` this workspace uses.
//!
//! The build environment has no crates.io access, so the real `crc32fast`
//! is replaced by this API-compatible vendored crate. Covered surface:
//!
//! * [`hash`] — one-shot CRC-32 (IEEE / zlib polynomial, reflected)
//! * [`Hasher`] — streaming `new` / `update` / `finalize`, plus
//!   [`Hasher::combine`]: fold an independently hashed suffix into a prefix
//!   hasher in O(log len) (the zlib `crc32_combine` GF(2)-matrix trick),
//!   which is what lets `CheckpointFile::encode` hash each section body
//!   exactly once while still producing a whole-file trailer CRC.
//!
//! The kernel is table-driven slice-by-8 (eight 256-entry tables built at
//! compile time), processing eight input bytes per step — within a small
//! factor of the SIMD paths of the real crate and far faster than a
//! bytewise loop; exact same output for every input.

const POLY: u32 = 0xEDB8_8320; // reflected IEEE 802.3 polynomial

static TABLES: [[u32; 256]; 8] = build_tables();

const fn build_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut k = 0;
        while k < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            k += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut j = 1;
    while j < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = t[j - 1][i];
            t[j][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            i += 1;
        }
        j += 1;
    }
    t
}

/// Incremental zlib-style CRC update: `crc32_update(crc32_update(0, a), b)`
/// equals `crc32_update(0, a ++ b)`.
fn crc32_update(crc: u32, mut buf: &[u8]) -> u32 {
    let mut crc = !crc;
    while buf.len() >= 8 {
        let lo = crc ^ u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
        let hi = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
        crc = TABLES[7][(lo & 0xFF) as usize]
            ^ TABLES[6][((lo >> 8) & 0xFF) as usize]
            ^ TABLES[5][((lo >> 16) & 0xFF) as usize]
            ^ TABLES[4][(lo >> 24) as usize]
            ^ TABLES[3][(hi & 0xFF) as usize]
            ^ TABLES[2][((hi >> 8) & 0xFF) as usize]
            ^ TABLES[1][((hi >> 16) & 0xFF) as usize]
            ^ TABLES[0][(hi >> 24) as usize];
        buf = &buf[8..];
    }
    for &b in buf {
        crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// One-shot CRC-32 of `buf`.
pub fn hash(buf: &[u8]) -> u32 {
    crc32_update(0, buf)
}

/// Streaming CRC-32 hasher.
#[derive(Debug, Clone, Default)]
pub struct Hasher {
    crc: u32,
    amount: u64,
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { crc: 0, amount: 0 }
    }

    /// Resume from a known state (`crc` over `amount` prior bytes).
    pub fn new_with_initial_len(crc: u32, amount: u64) -> Hasher {
        Hasher { crc, amount }
    }

    pub fn update(&mut self, buf: &[u8]) {
        self.crc = crc32_update(self.crc, buf);
        self.amount += buf.len() as u64;
    }

    pub fn finalize(self) -> u32 {
        self.crc
    }

    pub fn reset(&mut self) {
        self.crc = 0;
        self.amount = 0;
    }

    /// Fold `other` (the CRC of the bytes that *follow* this hasher's) into
    /// `self`, as if `self.update` had seen those bytes too. O(log len) via
    /// GF(2) matrix squaring (zlib's `crc32_combine`).
    pub fn combine(&mut self, other: &Hasher) {
        self.crc = crc32_combine(self.crc, other.crc, other.amount);
        self.amount += other.amount;
    }
}

fn gf2_matrix_times(mat: &[u32; 32], mut vec: u32) -> u32 {
    let mut sum = 0u32;
    let mut i = 0;
    while vec != 0 {
        if vec & 1 != 0 {
            sum ^= mat[i];
        }
        vec >>= 1;
        i += 1;
    }
    sum
}

fn gf2_matrix_square(square: &mut [u32; 32], mat: &[u32; 32]) {
    for n in 0..32 {
        square[n] = gf2_matrix_times(mat, mat[n]);
    }
}

fn crc32_combine(mut crc1: u32, crc2: u32, mut len2: u64) -> u32 {
    if len2 == 0 {
        return crc1; // a zero-length suffix contributes nothing
    }
    let mut even = [0u32; 32]; // even-power-of-two zero operators
    let mut odd = [0u32; 32]; // odd-power-of-two zero operators

    // operator for one zero bit
    odd[0] = POLY;
    let mut row = 1u32;
    for item in odd.iter_mut().skip(1) {
        *item = row;
        row <<= 1;
    }
    // operator for two zero bits, then four
    gf2_matrix_square(&mut even, &odd);
    gf2_matrix_square(&mut odd, &even);

    // apply len2 zero *bytes* to crc1, squaring the operator each round
    loop {
        gf2_matrix_square(&mut even, &odd);
        if len2 & 1 != 0 {
            crc1 = gf2_matrix_times(&even, crc1);
        }
        len2 >>= 1;
        if len2 == 0 {
            break;
        }
        gf2_matrix_square(&mut odd, &even);
        if len2 & 1 != 0 {
            crc1 = gf2_matrix_times(&odd, crc1);
        }
        len2 >>= 1;
    }
    crc1 ^ crc2
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Bytewise reference implementation.
    fn crc32_ref(buf: &[u8]) -> u32 {
        let mut crc = 0xFFFF_FFFFu32;
        for &b in buf {
            crc ^= b as u32;
            for _ in 0..8 {
                crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            }
        }
        !crc
    }

    #[test]
    fn known_vectors() {
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
        assert_eq!(hash(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn slice_by_8_matches_bytewise_reference() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 31 + 7) as u8).collect();
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000, 9999, 10_000] {
            assert_eq!(hash(&data[..n]), crc32_ref(&data[..n]), "n={n}");
        }
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..5000u32).map(|i| (i * 131) as u8).collect();
        for split in [0usize, 1, 7, 2500, 4999, 5000] {
            let mut h = Hasher::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), hash(&data), "split={split}");
        }
    }

    #[test]
    fn combine_equals_concatenation() {
        let a: Vec<u8> = (0..777u32).map(|i| (i * 3) as u8).collect();
        let b: Vec<u8> = (0..4096u32).map(|i| (i ^ 0x5A) as u8).collect();
        let mut ha = Hasher::new();
        ha.update(&a);
        let mut hb = Hasher::new();
        hb.update(&b);
        ha.combine(&hb);
        let mut whole = a.clone();
        whole.extend_from_slice(&b);
        assert_eq!(ha.finalize(), hash(&whole));
        // empty suffix is the identity
        let mut hc = Hasher::new();
        hc.update(&a);
        hc.combine(&Hasher::new());
        assert_eq!(hc.finalize(), hash(&a));
        // empty prefix too
        let mut hd = Hasher::new();
        let mut he = Hasher::new();
        he.update(&b);
        hd.combine(&he);
        assert_eq!(hd.finalize(), hash(&b));
    }

    #[test]
    fn reset_and_resume() {
        let mut h = Hasher::new();
        h.update(b"junk");
        h.reset();
        h.update(b"123456789");
        let crc = h.finalize();
        assert_eq!(crc, 0xCBF4_3926);
        let h2 = Hasher::new_with_initial_len(crc, 9);
        assert_eq!(h2.finalize(), crc);
    }
}
