//! Offline shim for the subset of `anyhow` this workspace uses.
//!
//! The build environment has no crates.io access, so the real `anyhow` is
//! replaced by this API-compatible vendored crate. Covered surface:
//!
//! * [`Error`] / [`Result`] with `?` conversion from any
//!   `std::error::Error + Send + Sync + 'static`
//! * [`anyhow!`], [`bail!`], [`ensure!`] (with and without a message)
//! * [`Context::context`] / [`Context::with_context`] on `Result` (both
//!   std-error and `anyhow::Error` payloads, via `Into<Error>`) and `Option`
//!
//! Context frames are joined outermost-first, so `{e}` and `{e:#}` both
//! render the full cause chain ("outer: inner"), which is what the CLI's
//! error reporting and the test-suite `contains` assertions rely on.
//! Deliberately not covered (unused in this tree): downcasting, backtraces,
//! `source()` chains as distinct objects.

use std::fmt;

/// A string-chained error value. Like the real `anyhow::Error`, this type
/// intentionally does NOT implement `std::error::Error`, which is what makes
/// the blanket `From<E: std::error::Error>` conversion coherent.
pub struct Error {
    /// cause chain, outermost context first
    chain: Vec<String>,
}

impl Error {
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (the full chain is in `Display`).
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // include the source chain inline, matching anyhow's `{:#}` shape
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failure values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<u8> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e.into())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: gone");
        // context on an already-anyhow Result also works
        let e2: Result<u8> = Err(e);
        let e2 = e2.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(e2.to_string(), "step 2: reading config: gone");
        assert_eq!(format!("{e2:#}"), "step 2: reading config: gone");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x {x} too big");
            ensure!(x != 7);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(7).unwrap_err().to_string().contains("x != 7"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("x = {}", 5);
        assert_eq!(e.to_string_outer(), "x = 5");
    }
}
