//! Offline stub of the `xla` PJRT binding used by `reft::runtime`.
//!
//! The real crate links libxla / the PJRT C API and executes the AOT HLO
//! artifacts exported by `python/compile/aot.py`. This container has no
//! PJRT runtime, so the binding is replaced by an API-compatible stub:
//!
//! * [`Literal`] is fully functional host-side (typed storage + reshape +
//!   readback) — the literal-conversion helpers in `reft::runtime` and their
//!   tests run for real against it;
//! * [`PjRtClient::cpu`] succeeds (trainers construct an engine before
//!   loading any artifact), but [`HloModuleProto::from_text_file`] and
//!   [`PjRtClient::compile`] return `Err(Error::Unavailable)`, so every
//!   artifact-driven path reports a clean "PJRT runtime unavailable" error
//!   and the artifact-gated tests/benches skip exactly as they do on a
//!   checkout without `make artifacts`.
//!
//! Swap this path dependency for the real binding in `rust/Cargo.toml` to
//! run the Layer-1/Layer-2 compute; nothing in `reft` changes.

use std::fmt;
use std::path::Path;

/// Binding-level error.
#[derive(Debug, Clone)]
pub enum Error {
    /// the stub cannot provide a PJRT runtime
    Unavailable(String),
    /// shape/type misuse of a literal
    Shape(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "PJRT runtime unavailable in offline build: {what}")
            }
            Error::Shape(what) => write!(f, "literal error: {what}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------------------
// literals (functional)
// ---------------------------------------------------------------------------

/// Element types a [`Literal`] can carry. Sealed to f32/i32 — the only types
/// the artifact interchange uses.
pub trait ArrayElement: Copy + 'static {
    fn wrap(data: Vec<Self>, dims: Vec<i64>) -> Literal;
    fn unwrap(lit: &Literal) -> Result<&[Self]>;
}

#[derive(Debug, Clone)]
enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side typed nd-array (or tuple of them).
#[derive(Debug, Clone)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl ArrayElement for f32 {
    fn wrap(data: Vec<f32>, dims: Vec<i64>) -> Literal {
        Literal { storage: Storage::F32(data), dims }
    }

    fn unwrap(lit: &Literal) -> Result<&[f32]> {
        match &lit.storage {
            Storage::F32(v) => Ok(v),
            _ => Err(Error::Shape("literal is not f32".into())),
        }
    }
}

impl ArrayElement for i32 {
    fn wrap(data: Vec<i32>, dims: Vec<i64>) -> Literal {
        Literal { storage: Storage::I32(data), dims }
    }

    fn unwrap(lit: &Literal) -> Result<&[i32]> {
        match &lit.storage {
            Storage::I32(v) => Ok(v),
            _ => Err(Error::Shape("literal is not i32".into())),
        }
    }
}

impl Literal {
    /// 1-D literal over a host slice.
    pub fn vec1<T: ArrayElement>(data: &[T]) -> Literal {
        T::wrap(data.to_vec(), vec![data.len() as i64])
    }

    /// Tuple literal (what `return_tuple=True` computations produce).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { storage: Storage::Tuple(elems), dims: Vec::new() }
    }

    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(t) => t.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Same data, new shape (element counts must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.storage, Storage::Tuple(_)) {
            return Err(Error::Shape("cannot reshape a tuple literal".into()));
        }
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error::Shape(format!(
                "reshape {:?} -> {dims:?} changes element count",
                self.dims
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        T::unwrap(self).map(|s| s.to_vec())
    }

    pub fn get_first_element<T: ArrayElement>(&self) -> Result<T> {
        T::unwrap(self)?
            .first()
            .copied()
            .ok_or_else(|| Error::Shape("empty literal".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(t) => Ok(t),
            _ => Err(Error::Shape("literal is not a tuple".into())),
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT surface (stubbed)
// ---------------------------------------------------------------------------

/// Parsed HLO module. The stub never parses: artifact loading is the gate
/// where offline builds bail out.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::Unavailable(format!(
            "cannot parse HLO artifact {}",
            path.as_ref().display()
        )))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("no device buffers in stub".into()))
    }
}

/// Input kinds accepted by [`PjRtLoadedExecutable::execute`] /
/// [`PjRtLoadedExecutable::execute_b`].
pub trait ExecuteInput {}
impl ExecuteInput for Literal {}
impl ExecuteInput for PjRtBuffer {}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: ExecuteInput>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("execute".into()))
    }

    pub fn execute_b<T: ExecuteInput>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("execute_b".into()))
    }
}

/// A PJRT client. Construction succeeds so hosts can build an engine eagerly;
/// compilation is where the stub reports unavailability.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu-stub" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("compile".into()))
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("buffer_from_host_buffer".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_literals() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }

    #[test]
    fn stub_gates_artifact_paths() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let e = client
            .compile(&XlaComputation { _private: () })
            .err()
            .unwrap();
        assert!(e.to_string().contains("unavailable"));
    }
}
