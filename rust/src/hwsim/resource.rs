//! Timeline resources: bandwidth/latency cost model with FIFO queuing and
//! max-min fair sharing for concurrent transfers.

/// A bandwidth-limited, latency-bearing resource (a PCIe link, a NIC, a disk,
/// a host memory engine). Times are in seconds on the simulation timeline;
/// sizes in bytes.
#[derive(Debug, Clone)]
pub struct Resource {
    pub name: String,
    /// sustained bandwidth, bytes/second
    pub bw: f64,
    /// fixed per-operation latency, seconds
    pub latency: f64,
    /// timeline horizon: the resource is busy until this instant
    pub busy_until: f64,
    /// total bytes ever transferred (metrics)
    pub total_bytes: u64,
    /// total busy seconds (utilization metrics)
    pub busy_secs: f64,
}

impl Resource {
    pub fn new(name: impl Into<String>, bw: f64, latency: f64) -> Self {
        Resource {
            name: name.into(),
            bw,
            latency,
            busy_until: 0.0,
            total_bytes: 0,
            busy_secs: 0.0,
        }
    }

    /// Schedule a transfer of `bytes` requested at time `t`. FIFO semantics:
    /// the transfer begins when the resource frees up. Returns (start, end).
    pub fn transfer(&mut self, t: f64, bytes: u64) -> (f64, f64) {
        let start = t.max(self.busy_until);
        let dur = self.latency + bytes as f64 / self.bw;
        let end = start + dur;
        self.busy_until = end;
        self.total_bytes += bytes;
        self.busy_secs += dur;
        (start, end)
    }

    /// Max-min fair completion times for `sizes` transfers that all start at
    /// time `t` on this shared resource (progressive filling: while k flows
    /// remain, each gets bw/k). Returns per-flow end times, preserving order.
    ///
    /// This is how e.g. four concurrent snapshot streams through one host
    /// root complex are costed: the aggregate never exceeds `bw`, small flows
    /// finish early and release their share to the rest.
    pub fn fair_share(&mut self, t: f64, sizes: &[u64]) -> Vec<f64> {
        if sizes.is_empty() {
            return Vec::new();
        }
        let start = t.max(self.busy_until);
        // sort by remaining size, fill progressively
        let mut idx: Vec<usize> = (0..sizes.len()).collect();
        idx.sort_by_key(|&i| sizes[i]);
        let mut ends = vec![0.0f64; sizes.len()];
        let mut now = start + self.latency;
        let mut done_bytes = 0.0f64; // bytes completed per *remaining* flow baseline
        let mut remaining = sizes.len();
        for (ord, &i) in idx.iter().enumerate() {
            let my = sizes[i] as f64;
            // bytes still to move for this flow beyond what every remaining
            // flow has already moved in lock-step:
            let extra = my - done_bytes;
            debug_assert!(extra >= -1e-6);
            let share = self.bw / remaining as f64;
            let dt = extra.max(0.0) / share;
            now += dt;
            done_bytes = my;
            ends[i] = now;
            remaining -= 1;
            let _ = ord;
        }
        let end_max = ends.iter().cloned().fold(start, f64::max);
        self.busy_until = end_max;
        self.total_bytes += sizes.iter().sum::<u64>();
        self.busy_secs += end_max - start;
        ends
    }

    /// Utilization over [0, horizon].
    pub fn utilization(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            (self.busy_secs / horizon).min(1.0)
        }
    }
}

/// A per-entity simulation timeline: tracks "my local time" for a rank/node
/// executing a sequence of operations, with barrier helpers.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub now: f64,
}

impl Timeline {
    pub fn new() -> Self {
        Timeline { now: 0.0 }
    }

    /// Spend `dt` seconds of local work.
    pub fn advance(&mut self, dt: f64) -> f64 {
        self.now += dt;
        self.now
    }

    /// Wait until at least `t`.
    pub fn wait_until(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }

    /// Synchronize a group of timelines at a barrier (all jump to the max).
    pub fn barrier(group: &mut [&mut Timeline]) -> f64 {
        let t = group.iter().map(|tl| tl.now).fold(0.0, f64::max);
        for tl in group.iter_mut() {
            tl.now = t;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_transfer_accounts_latency_and_bw() {
        let mut r = Resource::new("pcie", 10.0, 0.5); // 10 B/s, 0.5 s latency
        let (s1, e1) = r.transfer(0.0, 20);
        assert_eq!((s1, e1), (0.0, 2.5));
        // second transfer queues behind the first
        let (s2, e2) = r.transfer(1.0, 10);
        assert_eq!((s2, e2), (2.5, 4.0));
        assert_eq!(r.total_bytes, 30);
    }

    #[test]
    fn fair_share_equal_flows() {
        let mut r = Resource::new("link", 100.0, 0.0);
        let ends = r.fair_share(0.0, &[100, 100]);
        // two equal flows at 50 B/s each -> both end at t=2
        assert!((ends[0] - 2.0).abs() < 1e-9);
        assert!((ends[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fair_share_small_flow_finishes_early() {
        let mut r = Resource::new("link", 100.0, 0.0);
        let ends = r.fair_share(0.0, &[50, 150]);
        // phase 1: both at 50 B/s until small one (50B) done at t=1
        // phase 2: big one has 100 B left at 100 B/s -> done at t=2
        assert!((ends[0] - 1.0).abs() < 1e-9, "{ends:?}");
        assert!((ends[1] - 2.0).abs() < 1e-9, "{ends:?}");
    }

    #[test]
    fn fair_share_aggregate_respects_capacity() {
        let mut r = Resource::new("link", 1e9, 0.0);
        let sizes = vec![1_000_000_000u64; 8];
        let ends = r.fair_share(0.0, &sizes);
        let total: u64 = sizes.iter().sum();
        let expected = total as f64 / 1e9;
        for e in ends {
            assert!((e - expected).abs() < 1e-6); // equal flows all end together
        }
    }

    #[test]
    fn fair_share_respects_prior_busy() {
        let mut r = Resource::new("link", 10.0, 0.0);
        r.transfer(0.0, 100); // busy until 10
        let ends = r.fair_share(0.0, &[10]);
        assert!((ends[0] - 11.0).abs() < 1e-9);
    }

    #[test]
    fn timeline_barrier_takes_max() {
        let mut a = Timeline::new();
        let mut b = Timeline::new();
        a.advance(3.0);
        b.advance(5.0);
        let t = Timeline::barrier(&mut [&mut a, &mut b]);
        assert_eq!(t, 5.0);
        assert_eq!(a.now, 5.0);
    }

    #[test]
    fn utilization_bounded() {
        let mut r = Resource::new("x", 10.0, 0.0);
        r.transfer(0.0, 100);
        assert!((r.utilization(20.0) - 0.5).abs() < 1e-9);
        assert_eq!(r.utilization(0.0), 0.0);
    }
}
