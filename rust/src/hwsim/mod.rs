//! Hardware simulator: the substrate standing in for the paper's six-node
//! 4×V100 testbed (Table 1).
//!
//! Design: a *timeline* cost model, not a wall-clock throttle. Every modeled
//! resource (a PCIe link, a node's host-memory engine, a NIC, the cloud
//! object store) is a [`Resource`] with a bandwidth, a latency and a
//! `busy_until` horizon. Data-path operations *actually move the bytes*
//! (real memcpy / XOR / serialization on real buffers — so correctness and
//! hot-path optimization are real), while the *time* each device-class
//! transfer takes is charged to the virtual timeline. Deterministic,
//! single-threaded, and fast enough to sweep the paper's full parameter grid.
//!
//! Fairness: transfers that overlap on a shared resource are resolved with a
//! progressive-filling (max-min fair share) model — see
//! [`Resource::fair_share`], which is what makes e.g. four concurrent d2h
//! copies on one host root complex land at the paper's observed aggregates.
//!
//! Failure injection follows the paper's Assumption 1: per-node
//! Time-To-Failure drawn from a Weibull distribution, independent across
//! nodes, split into *software* failures (kill the training process, SMP
//! survives) and *hardware* failures (node offline, memory lost).

pub mod churn;
pub mod cluster;
pub mod failure;
pub mod resource;

pub use churn::{ChurnReport, SkewedChurn, SkewedChurnSpec};
pub use cluster::{ClusterHw, HwSpec, NodeHw};
pub use failure::{FailureEvent, FailureKind, FailureModel, FailureSchedule};
pub use resource::{Resource, Timeline};
