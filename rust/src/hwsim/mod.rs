//! Hardware simulator: the substrate standing in for the paper's six-node
//! 4×V100 testbed (Table 1).
//!
//! Design: a *timeline* cost model, not a wall-clock throttle. Every modeled
//! resource (a PCIe link, a node's host-memory engine, a NIC, the cloud
//! object store) is a [`Resource`] with a bandwidth, a latency and a
//! `busy_until` horizon. Data-path operations *actually move the bytes*
//! (real memcpy / XOR / serialization on real buffers — so correctness and
//! hot-path optimization are real), while the *time* each device-class
//! transfer takes is charged to the virtual timeline. Deterministic,
//! single-threaded, and fast enough to sweep the paper's full parameter grid.
//!
//! Fairness: transfers that overlap on a shared resource are resolved with a
//! progressive-filling (max-min fair share) model — see
//! [`Resource::fair_share`], which is what makes e.g. four concurrent d2h
//! copies on one host root complex land at the paper's observed aggregates.
//!
//! Failure injection follows the paper's Assumption 1: per-node
//! Time-To-Failure drawn from a Weibull distribution, independent across
//! nodes, split into *software* failures (kill the training process, SMP
//! survives) and *hardware* failures (node offline, memory lost). The
//! [`correlated`] module layers the modes Assumption 1 cannot express —
//! rack/switch bursts, flapping nodes, storage brownouts — on top of that
//! base process.
//!
//! **Determinism.** Every stochastic hwsim process draws from an explicit
//! [`Rng`](crate::util::rng::Rng). Harnesses derive all their streams from
//! ONE master seed via [`seed::stream`], so printing that single seed is
//! enough to replay an entire run — failure schedules, churn, payloads and
//! all — bit for bit.

pub mod churn;
pub mod cluster;
pub mod correlated;
pub mod failure;
pub mod resource;

pub use churn::{ChurnReport, SkewedChurn, SkewedChurnSpec};
pub use cluster::{ClusterHw, HwSpec, NodeHw};
pub use correlated::{Brownout, CorrelatedSpec, CorrelatedTrace, FailureClass, TaggedEvent};
pub use failure::{FailureEvent, FailureKind, FailureModel, FailureSchedule};
pub use resource::{Resource, Timeline};

/// One-master-seed stream derivation: every stochastic domain of a harness
/// forks its own independent generator from the single printed seed, so
/// adding draws to one domain never perturbs another (schedule stability
/// under harness evolution) and one `--seed` value replays everything.
pub mod seed {
    use crate::util::rng::Rng;

    /// independent per-node Weibull TTF sampling
    pub const FAILURES: u64 = 0xFA11;
    /// correlated modes (rack bursts, flaps, storage brownouts)
    pub const CORRELATED: u64 = 0xC0FA;
    /// skewed-churn payload mutation
    pub const CHURN: u64 = 0xC4E1;
    /// payload initialization
    pub const PAYLOAD: u64 = 0xDA7A;

    /// Derive the deterministic stream for `domain` from one master seed.
    pub fn stream(master: u64, domain: u64) -> Rng {
        let mut root = Rng::seed_from(master);
        root.fork(domain)
    }
}
