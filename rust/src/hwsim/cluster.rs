//! Cluster hardware model: nodes, their GPUs and the interconnect resources,
//! built from an [`HwSpec`] (defaults = the paper's Table 1 testbed).

use super::resource::Resource;

/// Hardware specification (paper Table 1 + §6.1 defaults).
#[derive(Debug, Clone)]
pub struct HwSpec {
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// per-GPU PCIe link bandwidth, bytes/s (paper: 15.7 GB/s)
    pub pcie_bw: f64,
    /// per-node aggregate host root-complex / memory-bus budget for d2h, bytes/s
    pub host_bus_bw: f64,
    /// host shared-memory copy bandwidth (SMP flush path), bytes/s
    pub shamem_bw: f64,
    /// per-node NIC to the cloud store, bytes/s (paper: 10 Gbps)
    pub nic_bw: f64,
    /// cloud object-store aggregate ingest, bytes/s
    pub cloud_bw: f64,
    /// local disk write bandwidth, bytes/s
    pub disk_bw: f64,
    /// CPU-side serialization throughput (tensor -> byte stream), bytes/s
    pub serialize_bw: f64,
    /// CPU-side XOR parity throughput (RAIM5 encode), bytes/s
    pub xor_bw: f64,
    /// CPU memory per node, bytes (paper: 512 GB)
    pub cpu_mem: u64,
    /// GPU memory per device, bytes (paper: 32 GB V100)
    pub gpu_mem: u64,
    /// intra-node GPU-GPU interconnect (PCIe P2P; NVLink on DGX), bytes/s
    pub p2p_bw: f64,
    /// inter-node training-traffic bandwidth (for PP/DP comm), bytes/s
    pub internode_bw: f64,
}

impl Default for HwSpec {
    fn default() -> Self {
        Self::paper_testbed()
    }
}

impl HwSpec {
    /// Paper Table 1: 6 nodes x 4 V100 (32 GB), Xeon 4114, 512 GB host RAM,
    /// PCIe 15.7 GB/s, 10 Gbps network.
    pub fn paper_testbed() -> Self {
        const GB: f64 = 1e9;
        HwSpec {
            nodes: 6,
            gpus_per_node: 4,
            pcie_bw: 15.7 * GB,
            host_bus_bw: 60.0 * GB,     // 2-socket Xeon 4114 class memory bus budget
                                        // (keeps 4 parallel d2h flows link-bound,
                                        // matching Fig. 9's >3x parallel speedup)
            shamem_bw: 12.0 * GB,       // host memcpy into SMP shared memory
            nic_bw: 1.25 * GB,          // 10 Gbps
            cloud_bw: 3.0 * GB,         // object store aggregate ingest
            disk_bw: 1.0 * GB,          // local SATA/NVMe class
            serialize_bw: 1.6 * GB,     // pickle-style tensor serialization
            xor_bw: 8.0 * GB,           // single-core-ish XOR parity stream
            cpu_mem: 512 * 1024u64.pow(3),
            gpu_mem: 32 * 1024u64.pow(3),
            p2p_bw: 12.0 * GB,
            internode_bw: 1.25 * GB,
        }
    }

    /// Scale the testbed to `nodes` x `gpus_per_node` keeping link classes.
    pub fn scaled(nodes: usize, gpus_per_node: usize) -> Self {
        HwSpec { nodes, gpus_per_node, ..Self::paper_testbed() }
    }
}

/// Per-node resource set.
#[derive(Debug, Clone)]
pub struct NodeHw {
    pub id: usize,
    /// one PCIe link per GPU (d2h + h2d share it)
    pub pcie: Vec<Resource>,
    /// aggregate host root complex: all concurrent d2h flows share this too
    pub host_bus: Resource,
    /// shared-memory copy engine (training proc -> SMP buffers)
    pub shamem: Resource,
    /// NIC toward cloud storage
    pub nic: Resource,
    /// local disk
    pub disk: Resource,
    /// serialization "engine" (a CPU core's worth of pickle throughput)
    pub serialize: Resource,
    /// XOR parity engine (RAIM5 encode/decode on CPU)
    pub xor: Resource,
    /// intra-node GPU p2p fabric
    pub p2p: Resource,
}

impl NodeHw {
    fn new(id: usize, spec: &HwSpec) -> Self {
        let mk = |n: String, bw: f64, lat: f64| Resource::new(n, bw, lat);
        NodeHw {
            id,
            pcie: (0..spec.gpus_per_node)
                .map(|g| mk(format!("n{id}.pcie{g}"), spec.pcie_bw, 20e-6))
                .collect(),
            host_bus: mk(format!("n{id}.hostbus"), spec.host_bus_bw, 0.0),
            shamem: mk(format!("n{id}.shamem"), spec.shamem_bw, 5e-6),
            nic: mk(format!("n{id}.nic"), spec.nic_bw, 100e-6),
            disk: mk(format!("n{id}.disk"), spec.disk_bw, 200e-6),
            serialize: mk(format!("n{id}.ser"), spec.serialize_bw, 10e-6),
            xor: mk(format!("n{id}.xor"), spec.xor_bw, 2e-6),
            p2p: mk(format!("n{id}.p2p"), spec.p2p_bw, 10e-6),
        }
    }

    /// Cost a parallel device->host copy of `per_gpu_bytes[g]` from each GPU
    /// starting at `t`: each flow is limited by its own PCIe link, and all
    /// flows share the host bus. Returns per-GPU end times.
    pub fn d2h_parallel(&mut self, t: f64, per_gpu_bytes: &[u64]) -> Vec<f64> {
        assert!(per_gpu_bytes.len() <= self.pcie.len());
        // per-link lower bound
        let link_ends: Vec<f64> = per_gpu_bytes
            .iter()
            .zip(self.pcie.iter_mut())
            .map(|(&b, link)| link.transfer(t, b).1)
            .collect();
        // shared-bus bound
        let bus_ends = self.host_bus.fair_share(t, per_gpu_bytes);
        link_ends
            .into_iter()
            .zip(bus_ends)
            .map(|(a, b)| a.max(b))
            .collect()
    }
}

/// The whole simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterHw {
    pub spec: HwSpec,
    pub nodes: Vec<NodeHw>,
    /// cloud object store: aggregate ingest shared by all nodes
    pub cloud: Resource,
}

impl ClusterHw {
    pub fn new(spec: HwSpec) -> Self {
        let nodes = (0..spec.nodes).map(|i| NodeHw::new(i, &spec)).collect();
        let cloud = Resource::new("cloud", spec.cloud_bw, 2e-3);
        ClusterHw { spec, nodes, cloud }
    }

    pub fn total_gpus(&self) -> usize {
        self.spec.nodes * self.spec.gpus_per_node
    }

    /// Cost a persist of `per_node_bytes[n]` from every node to cloud storage
    /// starting at `t` (each node's flow is NIC-bound, all share the store).
    pub fn persist_to_cloud(&mut self, t: f64, per_node_bytes: &[u64]) -> Vec<f64> {
        let nic_ends: Vec<f64> = per_node_bytes
            .iter()
            .zip(self.nodes.iter_mut())
            .map(|(&b, n)| n.nic.transfer(t, b).1)
            .collect();
        let cloud_ends = self.cloud.fair_share(t, per_node_bytes);
        nic_ends
            .into_iter()
            .zip(cloud_ends)
            .map(|(a, b)| a.max(b))
            .collect()
    }

    /// Reset all timeline horizons (fresh experiment on the same topology).
    pub fn reset(&mut self) {
        *self = ClusterHw::new(self.spec.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let hw = ClusterHw::new(HwSpec::paper_testbed());
        assert_eq!(hw.nodes.len(), 6);
        assert_eq!(hw.total_gpus(), 24);
        assert_eq!(hw.nodes[0].pcie.len(), 4);
    }

    #[test]
    fn d2h_parallel_beats_serial_single_link() {
        // 4 GPUs x 5 GB sharded copy vs 20 GB through one link: the paper's
        // Fig. 9 claim that sharded d2h is >3x faster than CheckFreq's.
        let spec = HwSpec::paper_testbed();
        let mut node = NodeHw::new(0, &spec);
        let sharded = node
            .d2h_parallel(0.0, &[5_000_000_000; 4])
            .into_iter()
            .fold(0.0, f64::max);
        let mut node2 = NodeHw::new(0, &spec);
        let (_, serial) = node2.pcie[0].transfer(0.0, 20_000_000_000);
        assert!(
            serial / sharded > 3.0,
            "serial {serial:.3} s vs sharded {sharded:.3} s"
        );
    }

    #[test]
    fn host_bus_caps_aggregate_d2h() {
        let mut spec = HwSpec::paper_testbed();
        spec.host_bus_bw = 20e9; // tighter than 4 x 15.7
        let mut node = NodeHw::new(0, &spec);
        let ends = node.d2h_parallel(0.0, &[10_000_000_000; 4]);
        let t = ends.into_iter().fold(0.0, f64::max);
        // 40 GB over a 20 GB/s shared bus: can't beat 2 s even with 4 links
        assert!(t >= 2.0 - 1e-6, "{t}");
    }

    #[test]
    fn cloud_persist_shares_store() {
        let mut hw = ClusterHw::new(HwSpec::scaled(6, 4));
        // 6 nodes x 10 GB: NIC-bound at 1.25 GB/s -> 8 s each if store keeps up
        let ends = hw.persist_to_cloud(0.0, &[10_000_000_000; 6]);
        let t = ends.into_iter().fold(0.0, f64::max);
        // store ingest 3 GB/s < 6 x 1.25 GB/s aggregate -> store-bound: 60/3 = 20 s
        assert!((t - 20.0).abs() < 0.5, "{t}");
    }
}
