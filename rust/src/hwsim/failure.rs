//! Failure injection: Weibull time-to-failure per the paper's Assumption 1.
//!
//! Two failure classes with distinct recovery semantics (§2.1 "Failure
//! Types", §4.2 "Elastic Functionality"):
//!
//! * **Software** (CUDA fault, data-loader crash, MPI error): the training
//!   process dies; the node — and its SMP with the clean snapshot — survives.
//! * **Hardware** (overheating, power, ECC): the node goes OFFLINE; all its
//!   memory (GPU *and* the SMP's CPU buffers) is lost; recovery needs RAIM5
//!   parity from SG peers or a checkpoint.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// training process dies; SMP survives (UNHEALTHY signal)
    Software,
    /// node offline; all volatile state on it is lost (OFFLINE signal)
    Hardware,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureEvent {
    pub at: f64,
    pub node: usize,
    pub kind: FailureKind,
}

/// Weibull failure model with independent per-node TTF (Assumption 1):
/// survival S(t) = exp(-lambda * t^c), i.e. scale = lambda^(-1/c).
#[derive(Debug, Clone)]
pub struct FailureModel {
    /// hardware failure rate (per unit time, before the Weibull shaping)
    pub lambda_hw: f64,
    /// software failure rate
    pub lambda_sw: f64,
    /// Weibull shape parameter c (paper sweeps 1.0 / 1.3 / 1.5 / 2.0)
    pub shape_c: f64,
}

impl FailureModel {
    pub fn new(lambda_hw: f64, lambda_sw: f64, shape_c: f64) -> Self {
        FailureModel { lambda_hw, lambda_sw, shape_c }
    }

    /// Single-node survival probability at time t: exp(-lambda t^c) — Eq. (1).
    pub fn survival(lambda: f64, shape_c: f64, t: f64) -> f64 {
        (-lambda * t.powf(shape_c)).exp()
    }

    /// Sample one TTF with S(t) = exp(-lambda t^c): t = (-ln U / lambda)^(1/c).
    pub fn sample_ttf(&self, rng: &mut Rng, lambda: f64) -> f64 {
        let u = rng.f64_open();
        (-u.ln() / lambda).powf(1.0 / self.shape_c)
    }

    /// Build a failure schedule for `nodes` nodes over [0, horizon]:
    /// each node draws independent hardware & software TTF processes
    /// (renewed after each event — i.e. a failure "repairs" and the clock
    /// restarts, matching elastic restart semantics).
    pub fn schedule(&self, rng: &mut Rng, nodes: usize, horizon: f64) -> FailureSchedule {
        let mut events = Vec::new();
        for node in 0..nodes {
            for (lambda, kind) in [
                (self.lambda_hw, FailureKind::Hardware),
                (self.lambda_sw, FailureKind::Software),
            ] {
                if lambda <= 0.0 {
                    continue;
                }
                let mut t = 0.0;
                loop {
                    t += self.sample_ttf(rng, lambda);
                    if t > horizon {
                        break;
                    }
                    events.push(FailureEvent { at: t, node, kind });
                }
            }
        }
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        FailureSchedule { events }
    }
}

/// A pre-drawn, time-ordered list of failure events.
#[derive(Debug, Clone, Default)]
pub struct FailureSchedule {
    pub events: Vec<FailureEvent>,
}

impl FailureSchedule {
    pub fn empty() -> Self {
        FailureSchedule { events: Vec::new() }
    }

    /// Deterministic single event (targeted kill for experiments, §6.2).
    pub fn single(at: f64, node: usize, kind: FailureKind) -> Self {
        FailureSchedule { events: vec![FailureEvent { at, node, kind }] }
    }

    /// Next event strictly after `t`, if any.
    pub fn next_after(&self, t: f64) -> Option<&FailureEvent> {
        self.events.iter().find(|e| e.at > t)
    }

    /// All events within (t0, t1].
    pub fn in_window(&self, t0: f64, t1: f64) -> impl Iterator<Item = &FailureEvent> {
        self.events.iter().filter(move |e| e.at > t0 && e.at <= t1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn survival_eq1_shape() {
        // S is 1 at t=0, decreasing, and matches exp(-lambda t^c)
        let s = |l, c, t| FailureModel::survival(l, c, t);
        assert_eq!(s(0.1, 1.3, 0.0), 1.0);
        assert!(s(0.1, 1.3, 1.0) > s(0.1, 1.3, 5.0));
        let t: f64 = 2.0;
        assert!((s(0.2, 1.5, t) - (-0.2 * t.powf(1.5)).exp()).abs() < 1e-12);
    }

    #[test]
    fn sampled_ttf_matches_survival_curve() {
        let m = FailureModel::new(0.05, 0.0, 1.3);
        let mut rng = Rng::seed_from(17);
        let n = 50_000;
        let t_probe = 5.0;
        let analytic = FailureModel::survival(0.05, 1.3, t_probe);
        let surv = (0..n)
            .filter(|_| m.sample_ttf(&mut rng, m.lambda_hw) > t_probe)
            .count() as f64
            / n as f64;
        assert!((surv - analytic).abs() < 0.01, "{surv} vs {analytic}");
    }

    #[test]
    fn schedule_sorted_and_bounded() {
        let m = FailureModel::new(0.01, 0.02, 1.0);
        let mut rng = Rng::seed_from(3);
        let sched = m.schedule(&mut rng, 8, 1000.0);
        assert!(!sched.events.is_empty());
        for w in sched.events.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(sched.events.iter().all(|e| e.at <= 1000.0 && e.node < 8));
        // both kinds appear over a long horizon
        assert!(sched.events.iter().any(|e| e.kind == FailureKind::Software));
        assert!(sched.events.iter().any(|e| e.kind == FailureKind::Hardware));
    }

    #[test]
    fn schedule_rate_sanity() {
        // lambda_sw = 0.02/h over 1000 h on 8 nodes -> ~ 0.02*1000*8 = 160 sw events
        let m = FailureModel::new(0.0, 0.02, 1.0);
        let mut rng = Rng::seed_from(5);
        let sched = m.schedule(&mut rng, 8, 1000.0);
        let n = sched.events.len() as f64;
        assert!((n - 160.0).abs() < 40.0, "{n}");
    }

    #[test]
    fn window_queries() {
        let sched = FailureSchedule {
            events: vec![
                FailureEvent { at: 1.0, node: 0, kind: FailureKind::Software },
                FailureEvent { at: 2.0, node: 1, kind: FailureKind::Hardware },
                FailureEvent { at: 3.0, node: 2, kind: FailureKind::Software },
            ],
        };
        assert_eq!(sched.next_after(1.0).unwrap().at, 2.0);
        assert_eq!(sched.in_window(0.5, 2.5).count(), 2);
    }
}
