//! Skewed-churn workload model for expert-parallel (MoE) payloads.
//!
//! Between two snapshot rounds of an MoE run, the router concentrates
//! updates on a small *hot* expert set: hot expert slabs churn almost
//! entirely while cold slabs see only trickle updates (optimizer moments,
//! the occasional routed token). The sparse-delta layer exists for exactly
//! this shape — persisted bytes should track the hot fraction, not the
//! model size — so this model generates it deterministically for tests and
//! benches: the payload is split into equal contiguous expert slabs, each
//! step mutates the hot slabs densely and the cold slabs sparsely, and the
//! hot set rotates on a fixed cadence to mimic router drift.
//!
//! The model mutates real bytes in place (no timeline costing): callers
//! re-wrap the buffer as a [`crate::snapshot::SharedPayload`] and drive the
//! ordinary snapshot/persist path, so the delta layer under test sees
//! exactly the churn pattern an expert-parallel trainer would produce.

use crate::util::rng::Rng;

/// Shape of the skewed churn: how many experts, how many are hot, and how
/// densely each class mutates per step.
#[derive(Debug, Clone, Copy)]
pub struct SkewedChurnSpec {
    /// contiguous equal slabs the payload is divided into (remainder bytes
    /// join the last slab)
    pub experts: usize,
    /// size of the hot set (<= experts)
    pub hot_experts: usize,
    /// percent of each hot slab's bytes mutated per step (0..=100)
    pub hot_churn_pct: u8,
    /// percent of each cold slab's bytes mutated per step (0..=100)
    pub cold_churn_pct: u8,
    /// rotate the hot set forward by one expert every N steps (0 = static)
    pub rotate_every: u64,
}

impl Default for SkewedChurnSpec {
    /// A 16-expert layer with 2 hot experts churning near-fully and cold
    /// experts at a 1% trickle — the skew regime where delta shipping wins.
    fn default() -> Self {
        SkewedChurnSpec {
            experts: 16,
            hot_experts: 2,
            hot_churn_pct: 90,
            cold_churn_pct: 1,
            rotate_every: 4,
        }
    }
}

/// One mutation pass's accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChurnReport {
    /// bytes actually flipped this step
    pub bytes_touched: u64,
    /// first expert of the hot window this step
    pub hot_start: usize,
}

/// Deterministic skewed-churn generator over an opaque byte payload.
pub struct SkewedChurn {
    spec: SkewedChurnSpec,
    rng: Rng,
    step: u64,
}

impl SkewedChurn {
    pub fn new(spec: SkewedChurnSpec, seed: u64) -> Self {
        assert!(spec.experts > 0, "at least one expert");
        assert!(spec.hot_experts <= spec.experts, "hot set within expert count");
        SkewedChurn { spec, rng: Rng::seed_from(seed), step: 0 }
    }

    /// The hot window's first expert at internal step `step`.
    fn hot_start_at(&self, step: u64) -> usize {
        match self.spec.rotate_every {
            0 => 0,
            n => ((step / n) as usize) % self.spec.experts,
        }
    }

    /// Mutate one step of skewed churn into `payload` in place. Each slab
    /// gets ONE contiguous mutated run at a random offset — expert updates
    /// rewrite whole parameter tensors, so dirtiness is spatially
    /// clustered, which is what keeps a fixed-extent delta table effective
    /// (uniform single-byte flips would dirty nearly every extent even at
    /// 1% churn). XORs with an odd byte so every touched byte *changes*.
    pub fn mutate(&mut self, payload: &mut [u8]) -> ChurnReport {
        let hot_start = self.hot_start_at(self.step);
        self.step += 1;
        if payload.is_empty() {
            return ChurnReport { bytes_touched: 0, hot_start };
        }
        let slab = (payload.len() / self.spec.experts).max(1);
        let mut touched = 0u64;
        for e in 0..self.spec.experts {
            let lo = e * slab;
            if lo >= payload.len() {
                break;
            }
            // the last slab absorbs the division remainder
            let hi = if e == self.spec.experts - 1 { payload.len() } else { (lo + slab).min(payload.len()) };
            let hot = (0..self.spec.hot_experts)
                .any(|k| (hot_start + k) % self.spec.experts == e);
            let pct = if hot { self.spec.hot_churn_pct } else { self.spec.cold_churn_pct } as usize;
            let n = (hi - lo) * pct / 100;
            if n == 0 {
                continue;
            }
            let start = lo + self.rng.below(hi - lo - n + 1);
            for b in &mut payload[start..start + n] {
                *b ^= (self.rng.next_u64() as u8) | 1;
            }
            touched += n as u64;
        }
        ChurnReport { bytes_touched: touched, hot_start }
    }

    /// Exact churned fraction of the payload per step (contiguous runs
    /// never overlap within a slab, so there are no collision losses).
    pub fn expected_churn_fraction(&self) -> f64 {
        let s = &self.spec;
        let hot = s.hot_experts as f64 * s.hot_churn_pct as f64;
        let cold = (s.experts - s.hot_experts) as f64 * s.cold_churn_pct as f64;
        (hot + cold) / (s.experts as f64 * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_is_deterministic_and_skewed() {
        let spec = SkewedChurnSpec::default();
        let mut a = SkewedChurn::new(spec, 0xC0DE);
        let mut b = SkewedChurn::new(spec, 0xC0DE);
        let mut pa = vec![7u8; 64 * 1024];
        let mut pb = pa.clone();
        let ra = a.mutate(&mut pa);
        let rb = b.mutate(&mut pb);
        assert_eq!(pa, pb, "same seed, same bytes");
        assert_eq!(ra, rb);

        // skew: the hot window is far *denser* in dirty bytes than the cold
        // remainder (regions differ in size, so compare densities)
        let slab = pa.len() / spec.experts;
        let baseline = vec![7u8; 64 * 1024];
        let dirty = |lo: usize, hi: usize| {
            pa[lo..hi].iter().zip(&baseline[lo..hi]).filter(|(x, y)| x != y).count()
        };
        let hot_density = dirty(0, 2 * slab) as f64 / (2 * slab) as f64;
        let cold_density = dirty(2 * slab, pa.len()) as f64 / (pa.len() - 2 * slab) as f64;
        assert!(
            hot_density > 10.0 * cold_density,
            "hot {hot_density} vs cold {cold_density}"
        );
        // every mutated byte really changed (XOR with an odd value)
        assert!(ra.bytes_touched > 0);
    }

    #[test]
    fn hot_set_rotates_on_cadence() {
        let spec = SkewedChurnSpec { rotate_every: 2, ..SkewedChurnSpec::default() };
        let mut c = SkewedChurn::new(spec, 1);
        let mut buf = vec![0u8; 4096];
        let starts: Vec<usize> = (0..6).map(|_| c.mutate(&mut buf).hot_start).collect();
        assert_eq!(starts, vec![0, 0, 1, 1, 2, 2]);
    }

    #[test]
    fn expected_fraction_tracks_spec() {
        let c = SkewedChurn::new(SkewedChurnSpec::default(), 0);
        // 2/16 experts at 90% + 14/16 at 1% = 0.1212...
        let f = c.expected_churn_fraction();
        assert!((f - 0.121_25).abs() < 1e-9, "{f}");
    }
}
