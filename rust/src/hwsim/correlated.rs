//! Correlated failure-trace generation: the modes the independent
//! per-node Weibull model (Assumption 1) cannot express.
//!
//! Real clusters fail in bursts, not just as independent renewals
//! (PAPERS.md: Gemini's checkpoint-placement study and the MegaScale
//! production postmortems both report rack- and switch-scoped outages as
//! the recovery-critical tail):
//!
//! * **Rack/switch burst** — a ToR switch or rack PDU dies and every node
//!   behind it goes OFFLINE in the *same tick*. When the rack hosts a whole
//!   sharding group this exceeds RAIM5's one-loss-per-SG budget by
//!   construction, so every burst is a forced durable-tier recovery — the
//!   case Eq. 7's independence assumption prices as negligibly rare.
//! * **Flapping node** — marginal hardware (ECC, thermals, a bad link)
//!   producing a rapid train of *software*-class failures on one node:
//!   each kill is individually benign (SMP survives), but the burst keeps
//!   re-triggering recovery and starves goodput.
//! * **Storage brownout** — the durable backend (object store, PFS) goes
//!   unavailable or degraded for a window. No node dies; instead persists
//!   stall and — the dangerous overlap — a protection-exceeding loss
//!   *during* the window finds the durable tier unreachable and must wait
//!   it out.
//!
//! The generator layers these processes over the base Weibull schedule
//! from ONE forked [`Rng`] stream, tags every event with its
//! [`FailureClass`] so the soak harness can account goodput per class
//! (paper fig. 8 style), and flattens to the plain [`FailureSchedule`]
//! the cadence trackers ingest.

use super::failure::{FailureEvent, FailureKind, FailureModel, FailureSchedule};
use crate::util::rng::Rng;

/// Which injection process produced an event — the soak's per-class
/// goodput split keys on this.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FailureClass {
    /// independent per-node Weibull TTF (the Assumption 1 base process)
    Independent,
    /// rack/switch burst: every node of one rack OFFLINE in the same tick
    RackBurst,
    /// flapping node: a rapid train of software kills on one node
    Flap,
}

impl FailureClass {
    /// Stable lowercase name (report keys, trace dumps).
    pub fn name(&self) -> &'static str {
        match self {
            FailureClass::Independent => "independent",
            FailureClass::RackBurst => "rack_burst",
            FailureClass::Flap => "flap",
        }
    }
}

/// One event of a correlated trace: the base failure event plus the
/// process that produced it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaggedEvent {
    pub event: FailureEvent,
    pub class: FailureClass,
}

/// A transient storage-backend brownout: durable-tier operations stall
/// (or fail) throughout `[at, at + duration)`. Not a node failure — it is
/// injected at the `Storage` layer, which is why it lives beside the node
/// events rather than among them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Brownout {
    pub at: f64,
    pub duration: f64,
}

impl Brownout {
    /// End of the window (first instant the backend is healthy again).
    pub fn end(&self) -> f64 {
        self.at + self.duration
    }

    /// Whether the backend is browned out at time `t`.
    pub fn covers(&self, t: f64) -> bool {
        t >= self.at && t < self.end()
    }
}

/// Rates for the correlated modes layered over the independent Weibull
/// base process. All rates are cluster-wide Poisson arrival rates per unit
/// time (the correlated processes scope to racks / single marginal nodes /
/// the shared storage backend, so they do not scale per-node the way
/// Assumption 1 does).
#[derive(Debug, Clone, Copy)]
pub struct CorrelatedSpec {
    /// rack/switch bursts per unit time (0 disables the mode)
    pub rack_burst_rate: f64,
    /// flap episodes per unit time (0 disables)
    pub flap_rate: f64,
    /// software kills per flap episode
    pub flap_burst: usize,
    /// spacing between kills within one episode
    pub flap_spacing: f64,
    /// storage brownouts per unit time (0 disables)
    pub brownout_rate: f64,
    /// length of each brownout window
    pub brownout_duration: f64,
}

impl Default for CorrelatedSpec {
    fn default() -> Self {
        CorrelatedSpec {
            rack_burst_rate: 0.0,
            flap_rate: 0.0,
            flap_burst: 4,
            flap_spacing: 5.0,
            brownout_rate: 0.0,
            brownout_duration: 120.0,
        }
    }
}

/// A pre-drawn correlated trace: time-ordered tagged node events plus the
/// storage brownout windows.
#[derive(Debug, Clone, Default)]
pub struct CorrelatedTrace {
    /// tagged node failures, sorted by `event.at`
    pub events: Vec<TaggedEvent>,
    /// brownout windows, sorted and non-overlapping
    pub brownouts: Vec<Brownout>,
}

impl CorrelatedTrace {
    /// Flatten to the plain schedule the λ trackers and legacy harness
    /// paths ingest (the class tags are a soak-side refinement).
    pub fn schedule(&self) -> FailureSchedule {
        FailureSchedule { events: self.events.iter().map(|t| t.event).collect() }
    }

    /// All tagged events within `(t0, t1]`.
    pub fn in_window(&self, t0: f64, t1: f64) -> impl Iterator<Item = &TaggedEvent> {
        self.events.iter().filter(move |t| t.event.at > t0 && t.event.at <= t1)
    }

    /// The brownout window covering time `t`, if the backend is dark then.
    pub fn brownout_at(&self, t: f64) -> Option<&Brownout> {
        self.brownouts.iter().find(|b| b.covers(t))
    }
}

impl CorrelatedSpec {
    /// Draw a correlated trace over `[0, horizon]`: the independent
    /// Weibull base from `model`, plus rack bursts / flaps / brownouts at
    /// this spec's rates. `racks` lists the physical blast domains (the
    /// soak passes the topology's sharding groups — one rack per SG, the
    /// worst case for RAIM5); every arrival of the burst process kills
    /// EVERY node of one uniformly chosen rack at the same instant.
    ///
    /// One `rng` stream drives all four processes, so a single seed
    /// reproduces the whole trace.
    pub fn trace(
        &self,
        model: &FailureModel,
        rng: &mut Rng,
        racks: &[Vec<usize>],
        horizon: f64,
    ) -> CorrelatedTrace {
        let nodes: usize = racks.iter().map(|r| r.len()).sum();
        let mut events: Vec<TaggedEvent> = model
            .schedule(rng, nodes, horizon)
            .events
            .into_iter()
            .map(|event| TaggedEvent { event, class: FailureClass::Independent })
            .collect();

        // rack/switch bursts: Poisson arrivals, whole-rack OFFLINE per hit
        if self.rack_burst_rate > 0.0 && !racks.is_empty() {
            let mut t = 0.0;
            loop {
                t += rng.exponential(self.rack_burst_rate);
                if t > horizon {
                    break;
                }
                let rack = &racks[rng.below(racks.len())];
                for &node in rack {
                    events.push(TaggedEvent {
                        event: FailureEvent { at: t, node, kind: FailureKind::Hardware },
                        class: FailureClass::RackBurst,
                    });
                }
            }
        }

        // flap episodes: one marginal node, a train of software kills
        if self.flap_rate > 0.0 && nodes > 0 {
            let mut t = 0.0;
            loop {
                t += rng.exponential(self.flap_rate);
                if t > horizon {
                    break;
                }
                let node = rng.below(nodes);
                for k in 0..self.flap_burst.max(1) {
                    let at = t + k as f64 * self.flap_spacing;
                    if at > horizon {
                        break;
                    }
                    events.push(TaggedEvent {
                        event: FailureEvent { at, node, kind: FailureKind::Software },
                        class: FailureClass::Flap,
                    });
                }
            }
        }

        events.sort_by(|a, b| a.event.at.total_cmp(&b.event.at));

        // storage brownouts: Poisson gaps BETWEEN windows, so windows
        // never overlap and the trace stays a clean alternation
        let mut brownouts = Vec::new();
        if self.brownout_rate > 0.0 && self.brownout_duration > 0.0 {
            let mut t = 0.0;
            loop {
                t += rng.exponential(self.brownout_rate);
                if t > horizon {
                    break;
                }
                brownouts.push(Brownout { at: t, duration: self.brownout_duration });
                t += self.brownout_duration;
            }
        }

        CorrelatedTrace { events, brownouts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn racks(n_racks: usize, width: usize) -> Vec<Vec<usize>> {
        (0..n_racks)
            .map(|r| (r * width..(r + 1) * width).collect())
            .collect()
    }

    fn spec_all() -> CorrelatedSpec {
        CorrelatedSpec {
            rack_burst_rate: 2e-3,
            flap_rate: 1e-3,
            flap_burst: 4,
            flap_spacing: 5.0,
            brownout_rate: 1e-3,
            brownout_duration: 120.0,
        }
    }

    #[test]
    fn same_seed_reproduces_the_whole_trace() {
        let m = FailureModel::new(1e-5, 2e-5, 1.0);
        let rk = racks(8, 4);
        let a = spec_all().trace(&m, &mut Rng::seed_from(42), &rk, 20_000.0);
        let b = spec_all().trace(&m, &mut Rng::seed_from(42), &rk, 20_000.0);
        assert_eq!(a.events, b.events);
        assert_eq!(a.brownouts, b.brownouts);
        let c = spec_all().trace(&m, &mut Rng::seed_from(43), &rk, 20_000.0);
        assert_ne!(a.events, c.events, "a different seed must change the trace");
    }

    #[test]
    fn rack_burst_kills_every_node_of_one_rack_same_tick() {
        let m = FailureModel::new(0.0, 0.0, 1.0); // isolate the burst process
        let rk = racks(16, 4);
        let spec = CorrelatedSpec { rack_burst_rate: 1e-3, ..CorrelatedSpec::default() };
        let trace = spec.trace(&m, &mut Rng::seed_from(7), &rk, 50_000.0);
        assert!(!trace.events.is_empty(), "rate 1e-3 over 50k must yield bursts");
        // group by timestamp: every burst is exactly one rack, hardware-kind
        let mut i = 0;
        while i < trace.events.len() {
            let t = trace.events[i].event.at;
            let burst: Vec<_> = trace
                .events
                .iter()
                .filter(|e| e.event.at == t)
                .collect();
            let mut nodes: Vec<usize> = burst.iter().map(|e| e.event.node).collect();
            nodes.sort_unstable();
            let rack = rk
                .iter()
                .find(|r| r.contains(&nodes[0]))
                .expect("burst node belongs to a rack");
            assert_eq!(&nodes, rack, "a burst covers its whole rack, exactly");
            for e in &burst {
                assert_eq!(e.class, FailureClass::RackBurst);
                assert_eq!(e.event.kind, FailureKind::Hardware);
            }
            i += burst.len();
        }
    }

    #[test]
    fn flap_is_a_software_train_on_one_node() {
        let m = FailureModel::new(0.0, 0.0, 1.0);
        let rk = racks(4, 4);
        let spec = CorrelatedSpec {
            flap_rate: 5e-4,
            flap_burst: 4,
            flap_spacing: 5.0,
            ..CorrelatedSpec::default()
        };
        let trace = spec.trace(&m, &mut Rng::seed_from(11), &rk, 100_000.0);
        assert!(!trace.events.is_empty());
        for e in &trace.events {
            assert_eq!(e.class, FailureClass::Flap);
            assert_eq!(e.event.kind, FailureKind::Software, "flaps never kill the node");
            assert!(e.event.node < 16);
        }
        // within one episode: same node, fixed spacing
        let first = trace.events[0];
        let episode: Vec<_> = trace
            .events
            .iter()
            .filter(|e| e.event.node == first.event.node && e.event.at < first.event.at + 20.0)
            .collect();
        for w in episode.windows(2) {
            assert!((w[1].event.at - w[0].event.at - 5.0).abs() < 1e-9);
        }
    }

    #[test]
    fn brownouts_are_sorted_and_disjoint() {
        let m = FailureModel::new(0.0, 0.0, 1.0);
        let spec = CorrelatedSpec {
            brownout_rate: 2e-3,
            brownout_duration: 120.0,
            ..CorrelatedSpec::default()
        };
        let trace = spec.trace(&m, &mut Rng::seed_from(13), &racks(2, 2), 100_000.0);
        assert!(trace.brownouts.len() >= 2, "rate 2e-3 over 100k must yield windows");
        for w in trace.brownouts.windows(2) {
            assert!(w[0].end() <= w[1].at, "brownout windows must not overlap");
        }
        let b = trace.brownouts[0];
        assert!(b.covers(b.at) && b.covers(b.end() - 1e-9));
        assert!(!b.covers(b.end()) && !b.covers(b.at - 1e-9));
        assert_eq!(trace.brownout_at(b.at).map(|x| x.at), Some(b.at));
    }

    #[test]
    fn flatten_preserves_order_and_count() {
        let m = FailureModel::new(1e-5, 2e-5, 1.3);
        let trace = spec_all().trace(&m, &mut Rng::seed_from(3), &racks(8, 4), 30_000.0);
        let flat = trace.schedule();
        assert_eq!(flat.events.len(), trace.events.len());
        for w in flat.events.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        // the tags partition the events
        let tagged: usize = [FailureClass::Independent, FailureClass::RackBurst, FailureClass::Flap]
            .iter()
            .map(|c| trace.events.iter().filter(|e| e.class == *c).count())
            .sum();
        assert_eq!(tagged, trace.events.len());
    }
}
