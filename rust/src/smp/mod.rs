//! Snapshot Management Process (paper §4.2): a per-node process whose
//! lifetime is decoupled from the training processes, holding the in-memory
//! snapshots that survive software failures.
//!
//! Here each SMP is an OS thread with its own heap buffers and a message
//! inbox (the stand-in for POSIX shared memory + the multiprocessing channel
//! of the PyTorch implementation — same survivability semantics: a training
//! task can die mid-snapshot and the SMP keeps serving its last *clean*
//! snapshot; only simulated node loss tears the SMP down).
//!
//! Consistency protocol (paper Fig. 6 "Multi Snapshots"):
//! * the **dirty** snapshot absorbs incoming buckets for version `v`;
//! * on `EndSnapshot(v)` — all tensors flushed — dirty is *promoted* to the
//!   clean ring (bounded by `clean_copies` to cap CPU memory);
//! * readers only ever see promoted (CLEAN) versions, so a crash mid-flush
//!   can never serve a torn snapshot;
//! * a stale `EndSnapshot` for a superseded version is ignored.
//!
//! The SMP also stores the RAIM5 parity blocks it hosts for its SG peers and
//! answers elastic status queries (HEALTHY / UNHEALTHY / OFFLINE protocol).

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::obs;
use crate::snapshot::payload::PayloadView;

/// Elastic signals (paper §4.2 "Elastic Functionality").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Signal {
    /// rendezvous complete, buffers may be allocated
    Healthy,
    /// begin accepting snapshot buckets
    Snap,
    /// training process failed (software); snapshots stay valid
    Unhealthy,
    /// node lost (hardware); the SMP itself is going away
    Offline,
}

/// Messages into an SMP.
pub enum SmpMsg {
    Signal(Signal),
    /// open the dirty buffer for a new snapshot version of one stage shard
    BeginSnapshot { version: u64, stage: usize, total_len: usize },
    /// open a *sparse* dirty buffer: seed it from a copy of the latest clean
    /// snapshot (which must be `total_len` bytes) and expect only
    /// `delta_len` bytes of buckets — the changed extents, patched in place
    /// at their sparse offsets. Promotion on `EndSnapshot` requires
    /// `delta_len` coverage, so a partially-patched buffer can never be
    /// served. Without a matching-size clean base the message is ignored
    /// and the round's `EndSnapshot` lands as a stale end (no promotion) —
    /// the coordinator's planner resets to a full base round on any
    /// membership change, which is the only way a base can be missing.
    BeginDeltaSnapshot { version: u64, stage: usize, total_len: usize, delta_len: usize },
    /// one tiny bucket of snapshot bytes. `data` is a view into the writer's
    /// shared payload: the channel transfers an `Arc`-backed `PayloadView`
    /// (zero-copy, like mapping the same shm page), the SMP then copies the
    /// bucket into its own dirty buffer — the Fig. 6 "flush" step and the
    /// *only* payload copy on the whole save path (§Perf copy budget).
    Bucket { version: u64, stage: usize, offset: usize, data: BucketRef },
    /// all buckets for (version, stage) sent — promote dirty -> clean
    EndSnapshot { version: u64, stage: usize },
    /// the coordinator superseded or failed (version, stage) mid-flight —
    /// drop the dirty buffer (recycling it) without promotion
    AbortSnapshot { version: u64, stage: usize },
    /// store a RAIM5 parity block this node hosts
    StoreParity { version: u64, stage: usize, data: Vec<u8> },
    /// sparse-round parity update: patch `(offset, bytes)` spans into the
    /// hosted parity block in place and stamp it with the new version.
    /// Parity is XOR-linear, so outside the changed contributors' stripes
    /// the old block already equals the new one. Without a hosted block of
    /// sufficient size the patch is dropped — the stale version stamp then
    /// makes any decode attempt fail loudly instead of mixing rounds.
    StoreParityDelta { version: u64, stage: usize, patches: Vec<(usize, Vec<u8>)> },
    /// fetch the latest clean snapshot of a stage shard
    GetClean { stage: usize, reply: Sender<Option<(u64, Vec<u8>)>> },
    /// fetch a hosted parity block
    GetParity { stage: usize, reply: Sender<Option<(u64, Vec<u8>)>> },
    /// introspection
    Stats { reply: Sender<SmpStats> },
    Shutdown,
}

/// A bucket's bytes: either an owned vector or a zero-copy view into a
/// [`SharedPayload`](crate::snapshot::SharedPayload) (the common,
/// allocation-free path).
pub enum BucketRef {
    Owned(Vec<u8>),
    Shared(PayloadView),
}

impl BucketRef {
    pub fn as_slice(&self) -> &[u8] {
        match self {
            BucketRef::Owned(v) => v,
            BucketRef::Shared(view) => view.as_slice(),
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl From<Vec<u8>> for BucketRef {
    fn from(v: Vec<u8>) -> Self {
        BucketRef::Owned(v)
    }
}

/// Observable SMP state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SmpStats {
    pub status: Option<&'static str>,
    pub clean_versions: BTreeMap<usize, u64>,
    pub dirty_versions: BTreeMap<usize, u64>,
    pub bytes_resident: usize,
    pub buckets_received: u64,
    pub promotions: u64,
    pub stale_end_snapshots: u64,
    pub aborted_in_flight: u64,
}

struct DirtyBuf {
    version: u64,
    data: Vec<u8>,
    filled: usize,
    /// bytes that must arrive before promotion: `data.len()` for a full
    /// snapshot, the sparse delta length for a patch round
    expect: usize,
}

struct SmpState {
    node: usize,
    status: Signal,
    /// per stage: in-flight dirty snapshot
    dirty: BTreeMap<usize, DirtyBuf>,
    /// per stage: ring of promoted clean snapshots (newest at back)
    clean: BTreeMap<usize, VecDeque<(u64, Vec<u8>)>>,
    /// per stage: hosted parity blocks
    parity: BTreeMap<usize, (u64, Vec<u8>)>,
    /// recycled buffers (retired clean snapshots) reused as dirty buffers —
    /// avoids a zero-fill + page-fault storm on every snapshot round
    free: BTreeMap<usize, Vec<Vec<u8>>>,
    clean_copies: usize,
    accepting: bool,
    buckets_received: u64,
    promotions: u64,
    stale_end_snapshots: u64,
    aborted_in_flight: u64,
}

impl SmpState {
    fn bytes_resident(&self) -> usize {
        let d: usize = self.dirty.values().map(|b| b.data.len()).sum();
        let c: usize = self
            .clean
            .values()
            .flat_map(|q| q.iter().map(|(_, v)| v.len()))
            .sum();
        let p: usize = self.parity.values().map(|(_, v)| v.len()).sum();
        // the recycle pool is real resident memory (the paper's
        // "snapshotting buffer" share of the <= 3x budget)
        let f: usize = self
            .free
            .values()
            .flat_map(|q| q.iter().map(Vec::len))
            .sum();
        d + c + p + f
    }

    fn handle(&mut self, msg: SmpMsg) -> bool {
        match msg {
            SmpMsg::Signal(s) => {
                self.status = s;
                match s {
                    Signal::Snap => self.accepting = true,
                    Signal::Unhealthy => self.accepting = false, // training gone; keep clean
                    Signal::Offline => return false,             // node loss: die with buffers
                    Signal::Healthy => {}
                }
            }
            SmpMsg::BeginSnapshot { version, stage, total_len } => {
                if self.accepting {
                    obs::instant(obs::cat::SMP, "begin", version, self.node as u64);
                    // recycle a retired buffer of the right size if we have
                    // one: buckets are disjoint and promotion requires full
                    // coverage, so stale content can never leak out
                    let data = match self.free.get_mut(&stage).and_then(Vec::pop) {
                        Some(buf) if buf.len() == total_len => buf,
                        _ => vec![0; total_len],
                    };
                    self.dirty
                        .insert(stage, DirtyBuf { version, data, filled: 0, expect: total_len });
                }
            }
            SmpMsg::BeginDeltaSnapshot { version, stage, total_len, delta_len } => {
                if self.accepting {
                    obs::instant(obs::cat::SMP, "begin_delta", version, self.node as u64);
                    let seed = self
                        .clean
                        .get(&stage)
                        .and_then(|q| q.back())
                        .filter(|(_, d)| d.len() == total_len);
                    if let Some((_, base)) = seed {
                        let mut data = match self.free.get_mut(&stage).and_then(Vec::pop) {
                            Some(buf) if buf.len() == total_len => buf,
                            _ => vec![0; total_len],
                        };
                        data.copy_from_slice(base);
                        self.dirty
                            .insert(stage, DirtyBuf { version, data, filled: 0, expect: delta_len });
                    }
                    // no clean base of the right size: ignore — the round's
                    // EndSnapshot becomes a stale end and nothing promotes
                }
            }
            SmpMsg::Bucket { version, stage, offset, data } => {
                self.buckets_received += 1;
                if let Some(buf) = self.dirty.get_mut(&stage) {
                    let bytes = data.as_slice();
                    if buf.version == version && offset + bytes.len() <= buf.data.len() {
                        buf.data[offset..offset + bytes.len()].copy_from_slice(bytes);
                        buf.filled += bytes.len();
                    }
                }
            }
            SmpMsg::EndSnapshot { version, stage } => {
                let complete = matches!(
                    self.dirty.get(&stage),
                    Some(b) if b.version == version && b.filled >= b.expect
                );
                if complete {
                    let buf = self.dirty.remove(&stage).unwrap();
                    let ring = self.clean.entry(stage).or_default();
                    ring.push_back((buf.version, buf.data));
                    while ring.len() > self.clean_copies {
                        if let Some((_, retired)) = ring.pop_front() {
                            let pool = self.free.entry(stage).or_default();
                            if pool.is_empty() {
                                pool.push(retired);
                            }
                        }
                    }
                    self.promotions += 1;
                    obs::instant(obs::cat::SMP, "promote", version, self.node as u64);
                } else {
                    self.stale_end_snapshots += 1;
                    obs::instant(obs::cat::SMP, "stale_end", version, self.node as u64);
                }
            }
            SmpMsg::AbortSnapshot { version, stage } => {
                // only the matching in-flight version is dropped: an abort
                // for a superseded version must not tear down its successor
                let matches = matches!(
                    self.dirty.get(&stage),
                    Some(b) if b.version == version
                );
                if matches {
                    let buf = self.dirty.remove(&stage).unwrap();
                    let pool = self.free.entry(stage).or_default();
                    if pool.is_empty() {
                        pool.push(buf.data);
                    }
                    self.aborted_in_flight += 1;
                    obs::instant(obs::cat::SMP, "abort", version, self.node as u64);
                }
            }
            SmpMsg::StoreParity { version, stage, data } => {
                self.parity.insert(stage, (version, data));
            }
            SmpMsg::StoreParityDelta { version, stage, patches } => {
                if let Some((v, data)) = self.parity.get_mut(&stage) {
                    if patches.iter().all(|(off, b)| off + b.len() <= data.len()) {
                        for (off, b) in &patches {
                            data[*off..*off + b.len()].copy_from_slice(b);
                        }
                        *v = version;
                    }
                }
            }
            SmpMsg::GetClean { stage, reply } => {
                let out = self
                    .clean
                    .get(&stage)
                    .and_then(|q| q.back())
                    .map(|(v, d)| (*v, d.clone()));
                let _ = reply.send(out);
            }
            SmpMsg::GetParity { stage, reply } => {
                let out = self.parity.get(&stage).map(|(v, d)| (*v, d.clone()));
                let _ = reply.send(out);
            }
            SmpMsg::Stats { reply } => {
                let _ = reply.send(SmpStats {
                    status: Some(match self.status {
                        Signal::Healthy => "healthy",
                        Signal::Snap => "snap",
                        Signal::Unhealthy => "unhealthy",
                        Signal::Offline => "offline",
                    }),
                    clean_versions: self
                        .clean
                        .iter()
                        .filter_map(|(s, q)| q.back().map(|(v, _)| (*s, *v)))
                        .collect(),
                    dirty_versions: self.dirty.iter().map(|(s, b)| (*s, b.version)).collect(),
                    bytes_resident: self.bytes_resident(),
                    buckets_received: self.buckets_received,
                    promotions: self.promotions,
                    stale_end_snapshots: self.stale_end_snapshots,
                    aborted_in_flight: self.aborted_in_flight,
                });
            }
            SmpMsg::Shutdown => return false,
        }
        true
    }
}

/// Handle to a running SMP thread.
pub struct Smp {
    pub node: usize,
    tx: Sender<SmpMsg>,
    handle: Option<JoinHandle<()>>,
}

impl Smp {
    /// Spawn the SMP for `node` with the given clean-ring depth.
    pub fn spawn(node: usize, clean_copies: usize) -> Smp {
        let (tx, rx): (Sender<SmpMsg>, Receiver<SmpMsg>) = channel();
        let handle = std::thread::Builder::new()
            .name(format!("smp-{node}"))
            .spawn(move || {
                let mut st = SmpState {
                    node,
                    status: Signal::Healthy,
                    dirty: BTreeMap::new(),
                    clean: BTreeMap::new(),
                    parity: BTreeMap::new(),
                    free: BTreeMap::new(),
                    clean_copies: clean_copies.max(1),
                    accepting: false,
                    buckets_received: 0,
                    promotions: 0,
                    stale_end_snapshots: 0,
                    aborted_in_flight: 0,
                };
                while let Ok(msg) = rx.recv() {
                    if !st.handle(msg) {
                        break;
                    }
                }
            })
            .expect("spawning SMP thread");
        Smp { node, tx, handle: Some(handle) }
    }

    pub fn send(&self, msg: SmpMsg) -> Result<()> {
        self.tx
            .send(msg)
            .map_err(|_| anyhow::anyhow!("SMP {} is gone", self.node))
    }

    /// Clone of this SMP's inbox handle, for background services that fetch
    /// clean shards concurrently with training traffic (the persistence
    /// engine's writer workers). Sends fail once the SMP dies — exactly the
    /// signal a persist job uses to abort.
    pub fn sender(&self) -> Sender<SmpMsg> {
        self.tx.clone()
    }

    /// Synchronous clean-snapshot fetch.
    pub fn get_clean(&self, stage: usize) -> Result<Option<(u64, Vec<u8>)>> {
        get_clean_via(&self.tx, stage)
            .map_err(|e| anyhow::anyhow!("SMP {}: {e}", self.node))
    }

    /// Synchronous parity fetch.
    pub fn get_parity(&self, stage: usize) -> Result<Option<(u64, Vec<u8>)>> {
        let (tx, rx) = channel();
        self.send(SmpMsg::GetParity { stage, reply: tx })?;
        Ok(rx.recv()?)
    }

    pub fn stats(&self) -> Result<SmpStats> {
        let (tx, rx) = channel();
        self.send(SmpMsg::Stats { reply: tx })?;
        Ok(rx.recv()?)
    }

    /// Simulate node loss: the SMP dies and its buffers are freed. Any
    /// subsequent `send` fails — exactly what peers observe on a real
    /// hardware failure.
    pub fn kill(&mut self) {
        let _ = self.tx.send(SmpMsg::Signal(Signal::Offline));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    pub fn is_alive(&self) -> bool {
        self.handle.is_some() && self.stats().is_ok()
    }
}

impl Drop for Smp {
    fn drop(&mut self) {
        let _ = self.tx.send(SmpMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Issue a clean-shard fetch without blocking on the reply: the request is
/// posted to the SMP's inbox and the reply channel returned, so the caller
/// can overlap the SMP's clone+ship with its own work. The persistence
/// engine's writer workers use this to prefetch the next shard while the
/// current one uploads (fetch/upload pipelining within one node).
pub fn request_clean_via(
    tx: &Sender<SmpMsg>,
    stage: usize,
) -> Result<Receiver<Option<(u64, Vec<u8>)>>> {
    let (reply, rx) = channel();
    tx.send(SmpMsg::GetClean { stage, reply })
        .map_err(|_| anyhow::anyhow!("SMP is gone"))?;
    Ok(rx)
}

/// The clean-fetch wire protocol over a bare inbox handle — the one
/// implementation both [`Smp::get_clean`] and services that only hold a
/// cloned [`Smp::sender`] (the persistence engine's writer workers) use.
pub fn get_clean_via(
    tx: &Sender<SmpMsg>,
    stage: usize,
) -> Result<Option<(u64, Vec<u8>)>> {
    request_clean_via(tx, stage)?
        .recv()
        .map_err(|_| anyhow::anyhow!("SMP died mid-fetch"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snapshot_roundtrip(smp: &Smp, stage: usize, version: u64, data: &[u8], bucket: usize) {
        smp.send(SmpMsg::BeginSnapshot { version, stage, total_len: data.len() })
            .unwrap();
        let mut off = 0;
        while off < data.len() {
            let end = (off + bucket).min(data.len());
            smp.send(SmpMsg::Bucket {
                version,
                stage,
                offset: off,
                data: data[off..end].to_vec().into(),
            })
            .unwrap();
            off = end;
        }
        smp.send(SmpMsg::EndSnapshot { version, stage }).unwrap();
    }

    #[test]
    fn clean_promote_and_fetch() {
        let smp = Smp::spawn(0, 1);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        let payload: Vec<u8> = (0..1000u32).map(|i| i as u8).collect();
        snapshot_roundtrip(&smp, 0, 1, &payload, 128);
        let (v, data) = smp.get_clean(0).unwrap().expect("clean exists");
        assert_eq!(v, 1);
        assert_eq!(data, payload);
    }

    #[test]
    fn incomplete_snapshot_never_served() {
        let smp = Smp::spawn(0, 1);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        smp.send(SmpMsg::BeginSnapshot { version: 1, stage: 0, total_len: 100 })
            .unwrap();
        smp.send(SmpMsg::Bucket { version: 1, stage: 0, offset: 0, data: vec![1; 50].into() })
            .unwrap();
        // training "crashes" here — EndSnapshot never arrives
        assert!(smp.get_clean(0).unwrap().is_none());
        // a premature EndSnapshot is also rejected (filled < total)
        smp.send(SmpMsg::EndSnapshot { version: 1, stage: 0 }).unwrap();
        assert!(smp.get_clean(0).unwrap().is_none());
        assert_eq!(smp.stats().unwrap().stale_end_snapshots, 1);
    }

    #[test]
    fn clean_survives_training_failure_and_new_dirty() {
        let smp = Smp::spawn(0, 1);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        snapshot_roundtrip(&smp, 0, 1, &[7u8; 64], 16);
        // next snapshot starts, then the training process dies mid-flight
        smp.send(SmpMsg::BeginSnapshot { version: 2, stage: 0, total_len: 64 })
            .unwrap();
        smp.send(SmpMsg::Bucket { version: 2, stage: 0, offset: 0, data: vec![9; 16].into() })
            .unwrap();
        smp.send(SmpMsg::Signal(Signal::Unhealthy)).unwrap();
        // version 1 still served, untouched
        let (v, data) = smp.get_clean(0).unwrap().unwrap();
        assert_eq!(v, 1);
        assert_eq!(data, vec![7u8; 64]);
    }

    #[test]
    fn clean_ring_bounded() {
        let smp = Smp::spawn(0, 2);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        for v in 1..=5u64 {
            snapshot_roundtrip(&smp, 0, v, &[v as u8; 32], 32);
        }
        let stats = smp.stats().unwrap();
        assert_eq!(stats.clean_versions[&0], 5);
        assert_eq!(stats.promotions, 5);
        // 2 clean copies + 1 recycled buffer (the snapshotting-buffer share
        // of the paper's memory budget): 96 bytes, bounded regardless of
        // how many rounds ran
        assert_eq!(stats.bytes_resident, 96);
    }

    #[test]
    fn multi_stage_independent() {
        let smp = Smp::spawn(0, 1);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        snapshot_roundtrip(&smp, 0, 1, &[1u8; 10], 4);
        snapshot_roundtrip(&smp, 2, 1, &[2u8; 20], 4);
        assert_eq!(smp.get_clean(0).unwrap().unwrap().1, vec![1u8; 10]);
        assert_eq!(smp.get_clean(2).unwrap().unwrap().1, vec![2u8; 20]);
        assert!(smp.get_clean(1).unwrap().is_none());
    }

    #[test]
    fn parity_store_fetch() {
        let smp = Smp::spawn(3, 1);
        smp.send(SmpMsg::StoreParity { version: 4, stage: 1, data: vec![0xAB; 16].into() })
            .unwrap();
        let (v, p) = smp.get_parity(1).unwrap().unwrap();
        assert_eq!((v, p), (4, vec![0xAB; 16]));
        assert!(smp.get_parity(0).unwrap().is_none());
    }

    #[test]
    fn kill_simulates_node_loss() {
        let mut smp = Smp::spawn(0, 1);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        snapshot_roundtrip(&smp, 0, 1, &[1u8; 8], 8);
        smp.kill();
        assert!(!smp.is_alive());
        assert!(smp.get_clean(0).is_err(), "buffers gone with the node");
    }

    #[test]
    fn abort_drops_only_matching_dirty_version() {
        let smp = Smp::spawn(0, 1);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        snapshot_roundtrip(&smp, 0, 1, &[5u8; 64], 16);
        // v2 in flight...
        smp.send(SmpMsg::BeginSnapshot { version: 2, stage: 0, total_len: 64 })
            .unwrap();
        smp.send(SmpMsg::Bucket { version: 2, stage: 0, offset: 0, data: vec![9; 16].into() })
            .unwrap();
        // ...a stale abort for v1 is a no-op...
        smp.send(SmpMsg::AbortSnapshot { version: 1, stage: 0 }).unwrap();
        assert_eq!(smp.stats().unwrap().dirty_versions[&0], 2);
        // ...the matching abort drops v2 without touching clean v1
        smp.send(SmpMsg::AbortSnapshot { version: 2, stage: 0 }).unwrap();
        let stats = smp.stats().unwrap();
        assert!(stats.dirty_versions.is_empty());
        assert_eq!(stats.aborted_in_flight, 1);
        let (v, data) = smp.get_clean(0).unwrap().unwrap();
        assert_eq!((v, data), (1, vec![5u8; 64]));
        // an EndSnapshot arriving after the abort is stale, not a promotion
        smp.send(SmpMsg::EndSnapshot { version: 2, stage: 0 }).unwrap();
        let stats = smp.stats().unwrap();
        assert_eq!(stats.stale_end_snapshots, 1);
        assert_eq!(stats.clean_versions[&0], 1);
    }

    #[test]
    fn delta_snapshot_patches_clean_in_place() {
        let smp = Smp::spawn(0, 1);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        let base: Vec<u8> = (0..200u32).map(|i| i as u8).collect();
        snapshot_roundtrip(&smp, 0, 1, &base, 64);
        // sparse round: only bytes 50..80 changed
        smp.send(SmpMsg::BeginDeltaSnapshot {
            version: 2,
            stage: 0,
            total_len: 200,
            delta_len: 30,
        })
        .unwrap();
        smp.send(SmpMsg::Bucket { version: 2, stage: 0, offset: 50, data: vec![0xEE; 30].into() })
            .unwrap();
        smp.send(SmpMsg::EndSnapshot { version: 2, stage: 0 }).unwrap();
        let (v, data) = smp.get_clean(0).unwrap().unwrap();
        assert_eq!(v, 2);
        let mut want = base.clone();
        want[50..80].fill(0xEE);
        assert_eq!(data, want, "unchanged bytes come from the seeded base");
        // a partially-patched delta never promotes
        smp.send(SmpMsg::BeginDeltaSnapshot {
            version: 3,
            stage: 0,
            total_len: 200,
            delta_len: 30,
        })
        .unwrap();
        smp.send(SmpMsg::Bucket { version: 3, stage: 0, offset: 50, data: vec![1; 10].into() })
            .unwrap();
        smp.send(SmpMsg::EndSnapshot { version: 3, stage: 0 }).unwrap();
        let stats = smp.stats().unwrap();
        assert_eq!(stats.clean_versions[&0], 2);
        assert_eq!(stats.stale_end_snapshots, 1);
    }

    #[test]
    fn delta_snapshot_without_base_never_promotes() {
        let smp = Smp::spawn(0, 1);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        // no clean snapshot exists: the delta begin is ignored
        smp.send(SmpMsg::BeginDeltaSnapshot {
            version: 1,
            stage: 0,
            total_len: 100,
            delta_len: 0,
        })
        .unwrap();
        smp.send(SmpMsg::EndSnapshot { version: 1, stage: 0 }).unwrap();
        assert!(smp.get_clean(0).unwrap().is_none());
        assert_eq!(smp.stats().unwrap().stale_end_snapshots, 1);
        // wrong-size base is equally rejected
        snapshot_roundtrip(&smp, 0, 2, &[3u8; 64], 64);
        smp.send(SmpMsg::BeginDeltaSnapshot {
            version: 3,
            stage: 0,
            total_len: 100,
            delta_len: 0,
        })
        .unwrap();
        smp.send(SmpMsg::EndSnapshot { version: 3, stage: 0 }).unwrap();
        assert_eq!(smp.stats().unwrap().clean_versions[&0], 2);
    }

    #[test]
    fn empty_delta_promotes_base_at_new_version() {
        // nothing changed this round: the seeded copy itself promotes, so
        // versions advance cluster-wide even on a zero-churn round
        let smp = Smp::spawn(0, 1);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        snapshot_roundtrip(&smp, 0, 1, &[7u8; 32], 32);
        smp.send(SmpMsg::BeginDeltaSnapshot {
            version: 2,
            stage: 0,
            total_len: 32,
            delta_len: 0,
        })
        .unwrap();
        smp.send(SmpMsg::EndSnapshot { version: 2, stage: 0 }).unwrap();
        let (v, data) = smp.get_clean(0).unwrap().unwrap();
        assert_eq!((v, data), (2, vec![7u8; 32]));
    }

    #[test]
    fn parity_delta_patches_in_place_or_fails_loudly() {
        let smp = Smp::spawn(3, 1);
        // no hosted parity yet: the patch is dropped entirely
        smp.send(SmpMsg::StoreParityDelta { version: 2, stage: 1, patches: vec![(0, vec![1; 4])] })
            .unwrap();
        assert!(smp.get_parity(1).unwrap().is_none());
        smp.send(SmpMsg::StoreParity { version: 4, stage: 1, data: vec![0xAB; 16] })
            .unwrap();
        // in-bounds patches apply and restamp the version
        smp.send(SmpMsg::StoreParityDelta {
            version: 5,
            stage: 1,
            patches: vec![(2, vec![0x11; 3]), (10, vec![0x22; 2])],
        })
        .unwrap();
        let (v, p) = smp.get_parity(1).unwrap().unwrap();
        assert_eq!(v, 5);
        let mut want = vec![0xAB; 16];
        want[2..5].fill(0x11);
        want[10..12].fill(0x22);
        assert_eq!(p, want);
        // an out-of-bounds patch is rejected wholesale: bytes AND version
        // stay put, so a later decode sees the version skew and errors
        smp.send(SmpMsg::StoreParityDelta { version: 6, stage: 1, patches: vec![(15, vec![0; 2])] })
            .unwrap();
        let (v, p) = smp.get_parity(1).unwrap().unwrap();
        assert_eq!((v, p), (5, want));
        // an empty patch list still restamps (zero-churn round)
        smp.send(SmpMsg::StoreParityDelta { version: 7, stage: 1, patches: vec![] })
            .unwrap();
        assert_eq!(smp.get_parity(1).unwrap().unwrap().0, 7);
    }

    #[test]
    fn outstanding_clean_requests_resolve_independently() {
        // the persist writer's prefetch pattern: several GetClean requests
        // posted before any reply is drained; each reply channel resolves
        // with its own stage's bytes regardless of drain order
        let smp = Smp::spawn(0, 1);
        smp.send(SmpMsg::Signal(Signal::Snap)).unwrap();
        snapshot_roundtrip(&smp, 0, 1, &[1u8; 16], 8);
        snapshot_roundtrip(&smp, 1, 1, &[2u8; 16], 8);
        let tx = smp.sender();
        let rx0 = request_clean_via(&tx, 0).unwrap();
        let rx1 = request_clean_via(&tx, 1).unwrap();
        assert_eq!(rx1.recv().unwrap().unwrap().1, vec![2u8; 16]);
        assert_eq!(rx0.recv().unwrap().unwrap().1, vec![1u8; 16]);
    }

    #[test]
    fn buckets_before_snap_signal_dropped() {
        let smp = Smp::spawn(0, 1);
        // no Snap signal yet: BeginSnapshot ignored
        smp.send(SmpMsg::BeginSnapshot { version: 1, stage: 0, total_len: 8 })
            .unwrap();
        smp.send(SmpMsg::Bucket { version: 1, stage: 0, offset: 0, data: vec![1; 8].into() })
            .unwrap();
        smp.send(SmpMsg::EndSnapshot { version: 1, stage: 0 }).unwrap();
        assert!(smp.get_clean(0).unwrap().is_none());
    }
}
