//! RAIM5 — Redundant Array of Independent Memory 5 (paper §4.3).
//!
//! RAID5's rotating-parity scheme applied to the CPU memory of a sharding
//! group: each node in an SG of `n` nodes holds its own snapshot shard *and*
//! one XOR parity block protecting its peers, so any **single node** loss per
//! SG is recoverable by the subtraction decoder (`b2 = p_b ^ b0 ^ b1` in the
//! paper's Fig. 7 example) without touching storage.
//!
//! Layout: every node's shard is split into `n-1` sub-blocks. Sub-block `b`
//! of node `j` is protected by the parity hosted on node `(j + 1 + b) mod n`
//! — a rotation that (a) never places a node's parity on itself and (b)
//! spreads parity bytes evenly, RAID5-style, so decode traffic is balanced.
//! Shards may have unequal lengths (the paper's "heuristic" uneven sharding
//! for awkward group sizes); shorter blocks are treated as zero-padded.

pub mod xor;

use anyhow::{bail, Result};

pub use xor::{
    parity_into, parity_of, xor_into, xor_into_parallel, xor_into_scalar, xor_into_striped,
};

/// The RAIM5 layout for one sharding group.
#[derive(Debug, Clone)]
pub struct Raim5Group {
    /// number of nodes in the SG
    pub n: usize,
    /// per-node shard lengths in bytes (may be uneven)
    pub shard_lens: Vec<usize>,
    /// sub-block length = ceil(max_shard / (n-1))
    pub block_len: usize,
}

impl Raim5Group {
    /// Plan a group over the given shard lengths. Requires `n >= 2` (a
    /// single-node SG has no peer to hold parity — the paper falls back to
    /// checkpointing there).
    pub fn plan(shard_lens: &[usize]) -> Result<Raim5Group> {
        let n = shard_lens.len();
        if n < 2 {
            bail!("RAIM5 needs at least 2 nodes per sharding group, got {n}");
        }
        let max = shard_lens.iter().copied().max().unwrap_or(0);
        let block_len = max.div_ceil(n - 1).max(1);
        Ok(Raim5Group { n, shard_lens: shard_lens.to_vec(), block_len })
    }

    /// Which node hosts the parity of node `j`'s sub-block `b`.
    pub fn parity_node(&self, j: usize, b: usize) -> usize {
        (j + 1 + b) % self.n
    }

    /// Sub-block `b` of node `j` as a byte range into its shard (clamped to
    /// the shard's real length; empty if fully in the padding).
    pub fn block_range(&self, j: usize, b: usize) -> std::ops::Range<usize> {
        let start = (b * self.block_len).min(self.shard_lens[j]);
        let end = ((b + 1) * self.block_len).min(self.shard_lens[j]);
        start..end
    }

    /// Parity buffer size on each node (one block per protected peer).
    pub fn parity_len(&self) -> usize {
        self.block_len
    }

    /// Encode: compute the parity block hosted on node `host` by XOR-ing the
    /// mapped sub-block of every other node's shard. `shards[j]` is node j's
    /// data. Returns a `block_len` buffer.
    ///
    /// Hot path: the striped [`parity_of`] fold — the first contributor is
    /// copied instead of XORed into a zeroed pass, and large blocks run the
    /// chain across worker threads (completion-time parity encode, §Perf).
    pub fn encode_parity(&self, host: usize, shards: &[&[u8]]) -> Vec<u8> {
        assert_eq!(shards.len(), self.n);
        let mut views: Vec<&[u8]> = Vec::with_capacity(self.n - 1);
        for j in 0..self.n {
            if j == host {
                continue;
            }
            let b = self.block_index_for(host, j);
            let r = self.block_range(j, b);
            if !r.is_empty() {
                views.push(&shards[j][r]);
            }
        }
        parity_of(&views, self.block_len)
    }

    /// The sub-block index of node `j` that maps to parity hosted on `host`.
    /// Public so the sparse-snapshot coordinator can map a contributor's
    /// changed byte ranges into parity-local patch ranges (parity is
    /// XOR-linear: only stripes overlapping a changed extent differ).
    pub fn block_index_for(&self, host: usize, j: usize) -> usize {
        debug_assert_ne!(host, j);
        (host + self.n - j - 1) % self.n
    }

    /// Encode every node's parity in one pass: `parities[i]` belongs on node i.
    pub fn encode_all(&self, shards: &[&[u8]]) -> Vec<Vec<u8>> {
        (0..self.n).map(|h| self.encode_parity(h, shards)).collect()
    }

    /// Decode the shard of `lost` from the surviving shards + parities.
    /// `shards[j]` may be empty for `j == lost`; `parities[i]` is node i's
    /// parity block. This is the paper's subtraction decoder.
    pub fn decode(&self, lost: usize, shards: &[&[u8]], parities: &[&[u8]]) -> Result<Vec<u8>> {
        if lost >= self.n {
            bail!("lost node {lost} out of range");
        }
        let mut out = vec![0u8; self.shard_lens[lost]];
        self.decode_into(lost, shards, parities, &mut out)?;
        Ok(out)
    }

    /// Subtraction-decode the lost shard **directly into `out`** — the
    /// restore path hands the lost shard's slice of the pre-allocated
    /// stitched payload here, so there is no decode-then-stitch copy. Each
    /// stripe block is a striped fold: the hosting parity is copied in,
    /// then every surviving contributor is XORed away (multi-threaded for
    /// large blocks).
    pub fn decode_into(
        &self,
        lost: usize,
        shards: &[&[u8]],
        parities: &[&[u8]],
        out: &mut [u8],
    ) -> Result<()> {
        if lost >= self.n {
            bail!("lost node {lost} out of range");
        }
        anyhow::ensure!(
            out.len() == self.shard_lens[lost],
            "decode buffer {} bytes != lost shard {}",
            out.len(),
            self.shard_lens[lost]
        );
        for b in 0..self.n - 1 {
            let host = self.parity_node(lost, b);
            let r_lost = self.block_range(lost, b);
            if r_lost.is_empty() {
                continue;
            }
            let width = r_lost.len();
            anyhow::ensure!(
                parities[host].len() >= width,
                "parity on node {host} has {} bytes, need {width}",
                parities[host].len()
            );
            // fold: parity first (copied), then XOR away every other
            // contributor to that parity; bytes past `width` belong to the
            // zero padding and cancel out, so clamping to `width` is exact
            let mut srcs: Vec<&[u8]> = Vec::with_capacity(self.n - 1);
            srcs.push(&parities[host][..width]);
            for j in 0..self.n {
                if j == host || j == lost {
                    continue;
                }
                let bj = self.block_index_for(host, j);
                let rj = self.block_range(j, bj);
                if !rj.is_empty() {
                    srcs.push(&shards[j][rj]);
                }
            }
            parity_into(&mut out[r_lost], &srcs);
        }
        Ok(())
    }

    /// Bytes of parity traffic a decode of `lost` must move across the SG
    /// (for recovery-time costing): every surviving node ships the blocks the
    /// decoder needs.
    pub fn decode_traffic_bytes(&self, lost: usize) -> u64 {
        let mut total = 0u64;
        for b in 0..self.n - 1 {
            if self.block_range(lost, b).is_empty() {
                continue;
            }
            // one parity block + (n-2) data blocks cross the network
            total += (self.block_len as u64) * (self.n as u64 - 1);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_shards(lens: &[usize], seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::seed_from(seed);
        lens.iter()
            .map(|&l| (0..l).map(|_| rng.next_u64() as u8).collect())
            .collect()
    }

    fn roundtrip(lens: &[usize], seed: u64) {
        let g = Raim5Group::plan(lens).unwrap();
        let shards = random_shards(lens, seed);
        let views: Vec<&[u8]> = shards.iter().map(Vec::as_slice).collect();
        let parities = g.encode_all(&views);
        let pviews: Vec<&[u8]> = parities.iter().map(Vec::as_slice).collect();
        for lost in 0..lens.len() {
            // survivors only: blank out the lost shard
            let mut surv: Vec<&[u8]> = views.clone();
            let empty: &[u8] = &[];
            surv[lost] = empty;
            let rec = g.decode(lost, &surv, &pviews).unwrap();
            assert_eq!(rec, shards[lost], "lens {lens:?} lost {lost}");
        }
    }

    #[test]
    fn parity_placement_never_self() {
        let g = Raim5Group::plan(&[100, 100, 100, 100]).unwrap();
        for j in 0..4 {
            for b in 0..3 {
                assert_ne!(g.parity_node(j, b), j);
            }
        }
    }

    #[test]
    fn parity_spread_is_balanced() {
        // every node hosts exactly one block from each peer
        let g = Raim5Group::plan(&[90, 90, 90]).unwrap();
        for host in 0..3 {
            let mut contributors = vec![];
            for j in 0..3 {
                if j != host {
                    contributors.push(g.block_index_for(host, j));
                }
            }
            contributors.sort();
            contributors.dedup();
            assert_eq!(contributors.len(), 2);
        }
    }

    #[test]
    fn roundtrip_equal_shards() {
        roundtrip(&[1024, 1024, 1024, 1024], 1);
        roundtrip(&[300, 300, 300], 2);
        roundtrip(&[64, 64], 3); // n=2 degenerates to mirroring
    }

    #[test]
    fn roundtrip_uneven_shards() {
        roundtrip(&[1000, 999, 500], 4);
        roundtrip(&[1, 7, 1024, 77], 5);
        roundtrip(&[0, 100, 100], 6); // an empty shard is legal
    }

    #[test]
    fn roundtrip_paper_fig7_shape() {
        // Fig. 7: four nodes, shards a/b/c/d, one parity each
        roundtrip(&[4096, 4096, 4096, 4096], 7);
    }

    #[test]
    fn rejects_single_node_group() {
        assert!(Raim5Group::plan(&[100]).is_err());
    }

    #[test]
    fn decode_into_writes_in_place_even_on_dirty_buffer() {
        let lens = [500usize, 400, 500, 499];
        let g = Raim5Group::plan(&lens).unwrap();
        let shards = random_shards(&lens, 77);
        let views: Vec<&[u8]> = shards.iter().map(Vec::as_slice).collect();
        let parities = g.encode_all(&views);
        let pviews: Vec<&[u8]> = parities.iter().map(Vec::as_slice).collect();
        for lost in 0..lens.len() {
            let mut surv = views.clone();
            let empty: &[u8] = &[];
            surv[lost] = empty;
            // dirty destination: every byte must be overwritten by the fold
            let mut out = vec![0xA5u8; lens[lost]];
            g.decode_into(lost, &surv, &pviews, &mut out).unwrap();
            assert_eq!(out, shards[lost], "lost {lost}");
        }
        let mut wrong = vec![0u8; lens[0] - 1];
        let mut surv = views.clone();
        surv[0] = &[];
        assert!(g.decode_into(0, &surv, &pviews, &mut wrong).is_err());
    }

    #[test]
    fn decode_traffic_positive() {
        let g = Raim5Group::plan(&[1 << 20; 4]).unwrap();
        let t = g.decode_traffic_bytes(2);
        // 3 blocks per stripe x 3 stripes of ~349527 B
        assert!(t > 3 * (1 << 20) as u64 / 2);
    }

    #[test]
    fn corrupted_parity_detected_by_mismatch() {
        // not a self-healing code: decode with a corrupted parity yields a
        // different shard (callers guard with checksums at the checkpoint
        // layer) — this documents the failure mode.
        let lens = [256usize, 256, 256];
        let g = Raim5Group::plan(&lens).unwrap();
        let shards = random_shards(&lens, 8);
        let views: Vec<&[u8]> = shards.iter().map(Vec::as_slice).collect();
        let mut parities = g.encode_all(&views);
        parities[0][3] ^= 0xFF;
        let pviews: Vec<&[u8]> = parities.iter().map(Vec::as_slice).collect();
        let mut surv = views.clone();
        let empty: &[u8] = &[];
        surv[1] = empty;
        let rec = g.decode(1, &surv, &pviews).unwrap();
        assert_ne!(rec, shards[1]);
    }
}
