//! The XOR hot path: RAIM5 encode/decode is pure `dst ^= src` streaming over
//! multi-GB buffers, so this is one of the three §Perf targets (DESIGN.md).
//!
//! Strategy: process the unaligned head byte-wise, then the body as u64 words
//! in 4-word unrolled chunks (ILP: four independent xor chains), then the
//! tail byte-wise. On x86-64 the auto-vectorizer turns the word loop into
//! SSE2/AVX2 loads/xors/stores; the unroll exists to defeat the
//! one-chain-per-iteration serialization, not to hand-roll SIMD.
//! `benches/hotpath.rs` tracks throughput vs `memcpy` (RAID5's write penalty
//! bound: parity XOR should run at >= 1/2 memcpy speed).

/// `dst[i] ^= src[i]` for the overlapping length, optimized.
#[inline]
pub fn xor_into(dst: &mut [u8], src: &[u8]) {
    let n = dst.len().min(src.len());
    let (dst, src) = (&mut dst[..n], &src[..n]);

    // head: align dst to 8 bytes
    let head = dst.as_ptr().align_offset(8).min(n);
    for i in 0..head {
        dst[i] ^= src[i];
    }
    let dst = &mut dst[head..];
    let src = &src[head..];

    let words = dst.len() / 8;
    let chunks = words / 4;
    unsafe {
        let d = dst.as_mut_ptr() as *mut u64;
        let s = src.as_ptr() as *const u64;
        // NOTE: src may be unaligned; use read_unaligned for it.
        for c in 0..chunks {
            let i = c * 4;
            let s0 = (s.add(i)).read_unaligned();
            let s1 = (s.add(i + 1)).read_unaligned();
            let s2 = (s.add(i + 2)).read_unaligned();
            let s3 = (s.add(i + 3)).read_unaligned();
            *d.add(i) ^= s0;
            *d.add(i + 1) ^= s1;
            *d.add(i + 2) ^= s2;
            *d.add(i + 3) ^= s3;
        }
        for i in chunks * 4..words {
            *d.add(i) ^= (s.add(i)).read_unaligned();
        }
    }
    // tail
    for i in words * 8..dst.len() {
        dst[i] ^= src[i];
    }
}

/// Byte-wise reference implementation (correctness oracle + perf baseline).
#[inline]
pub fn xor_into_scalar(dst: &mut [u8], src: &[u8]) {
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d ^= *s;
    }
}

/// XOR-fold many sources into one fresh parity buffer of length `len`.
pub fn parity_of(sources: &[&[u8]], len: usize) -> Vec<u8> {
    let mut out = vec![0u8; len];
    for s in sources {
        xor_into(&mut out, s);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.next_u64() as u8).collect()
    }

    #[test]
    fn matches_scalar_on_many_shapes() {
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000, 4096, 100_003] {
            let src = rand_bytes(n, n as u64);
            let mut a = rand_bytes(n, n as u64 + 1);
            let mut b = a.clone();
            xor_into(&mut a, &src);
            xor_into_scalar(&mut b, &src);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn unaligned_offsets() {
        let src = rand_bytes(4096, 10);
        let base = rand_bytes(4200, 11);
        for off in 0..16 {
            let mut a = base.clone();
            let mut b = base.clone();
            xor_into(&mut a[off..off + 4096], &src);
            xor_into_scalar(&mut b[off..off + 4096], &src);
            assert_eq!(a, b, "off={off}");
        }
    }

    #[test]
    fn mismatched_lengths_use_overlap() {
        let mut d = vec![0xFFu8; 10];
        xor_into(&mut d, &[0x0F; 4]);
        assert_eq!(&d[..4], &[0xF0; 4]);
        assert_eq!(&d[4..], &[0xFF; 6]);
    }

    #[test]
    fn xor_is_involution() {
        let src = rand_bytes(10_000, 42);
        let orig = rand_bytes(10_000, 43);
        let mut d = orig.clone();
        xor_into(&mut d, &src);
        xor_into(&mut d, &src);
        assert_eq!(d, orig);
    }

    #[test]
    fn parity_reconstructs_any_member() {
        let a = rand_bytes(1000, 1);
        let b = rand_bytes(1000, 2);
        let c = rand_bytes(1000, 3);
        let p = parity_of(&[&a, &b, &c], 1000);
        let rec_b = parity_of(&[&p, &a, &c], 1000);
        assert_eq!(rec_b, b);
    }
}
