//! The XOR hot path: RAIM5 encode/decode is pure `dst ^= src` streaming over
//! multi-GB buffers, so this is one of the three §Perf targets (DESIGN.md).
//!
//! Two layers:
//!
//! * **Word-unrolled serial kernel** ([`xor_into`]): unaligned head
//!   byte-wise, body as u64 words in 4-word unrolled chunks (ILP: four
//!   independent xor chains), tail byte-wise. On x86-64 the auto-vectorizer
//!   turns the word loop into SSE2/AVX2 loads/xors/stores.
//! * **Striped multi-threaded fold** ([`xor_into_parallel`],
//!   [`parity_into`]): for buffers at or above [`PARALLEL_MIN_BYTES`] the
//!   destination is carved into cache-line-aligned stripes and each worker
//!   thread runs the *whole* XOR chain over its stripe (every source in
//!   turn, stripe-resident in cache), falling back to the serial kernel
//!   below the threshold. This is what RAIM5 completion-time parity encode
//!   and restore decode run on.
//!
//! `benches/hotpath.rs` tracks throughput vs `memcpy` (RAID5's write penalty
//! bound: parity XOR should run at >= 1/2 memcpy speed) and the striped
//! fold vs the single-thread kernel.

/// Destinations smaller than this stay on the single-thread kernel — thread
/// spawn + join costs more than the XOR below ~1 MiB.
pub const PARALLEL_MIN_BYTES: usize = 1 << 20;

/// Minimum *chain work* (destination bytes x sources) per spawned worker:
/// spawn/join overhead must amortize against the whole chain, so a lone
/// just-over-threshold `dst ^= src` gets few (or zero) extra threads while
/// a multi-source parity fold of the same width fans out fully.
const MIN_WORK_PER_THREAD: usize = 512 * 1024;

/// Smallest stripe handed to a worker (keeps per-thread work meaningful).
const STRIPE_FLOOR: usize = 128 * 1024;

/// Cap on worker threads (memory-bound work stops scaling well past this).
const MAX_THREADS: usize = 8;

/// Default worker count for the striped paths.
pub fn default_xor_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_THREADS)
}

/// `dst[i] ^= src[i]` for the overlapping length, optimized (single thread).
#[inline]
pub fn xor_into(dst: &mut [u8], src: &[u8]) {
    let n = dst.len().min(src.len());
    let (dst, src) = (&mut dst[..n], &src[..n]);

    // head: align dst to 8 bytes
    let head = dst.as_ptr().align_offset(8).min(n);
    for i in 0..head {
        dst[i] ^= src[i];
    }
    let dst = &mut dst[head..];
    let src = &src[head..];

    let words = dst.len() / 8;
    let chunks = words / 4;
    unsafe {
        let d = dst.as_mut_ptr() as *mut u64;
        let s = src.as_ptr() as *const u64;
        // NOTE: src may be unaligned; use read_unaligned for it.
        for c in 0..chunks {
            let i = c * 4;
            let s0 = (s.add(i)).read_unaligned();
            let s1 = (s.add(i + 1)).read_unaligned();
            let s2 = (s.add(i + 2)).read_unaligned();
            let s3 = (s.add(i + 3)).read_unaligned();
            *d.add(i) ^= s0;
            *d.add(i + 1) ^= s1;
            *d.add(i + 2) ^= s2;
            *d.add(i + 3) ^= s3;
        }
        for i in chunks * 4..words {
            *d.add(i) ^= (s.add(i)).read_unaligned();
        }
    }
    // tail
    for i in words * 8..dst.len() {
        dst[i] ^= src[i];
    }
}

/// Byte-wise reference implementation (correctness oracle + perf baseline).
#[inline]
pub fn xor_into_scalar(dst: &mut [u8], src: &[u8]) {
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d ^= *s;
    }
}

/// `dst ^= src` striped across the default worker count. Falls back to the
/// serial kernel below [`PARALLEL_MIN_BYTES`].
pub fn xor_into_parallel(dst: &mut [u8], src: &[u8]) {
    xor_into_striped(dst, src, default_xor_threads());
}

/// `dst ^= src` (overlapping length) with an explicit worker count — the
/// property tests sweep this across thread counts and offsets.
pub fn xor_into_striped(dst: &mut [u8], src: &[u8], threads: usize) {
    let n = dst.len().min(src.len());
    xor_fold_striped(&mut dst[..n], &[&src[..n]], false, threads);
}

/// Fill `dst` with the XOR fold of `sources`, each source zero-padded (or
/// truncated) to `dst.len()`: the first source is **copied** into place —
/// not XORed into a zeroed pass, which would cost one extra full sweep of a
/// multi-MB buffer — and the rest are XORed in. Striped across threads for
/// large buffers. With no sources, `dst` is zero-filled.
pub fn parity_into(dst: &mut [u8], sources: &[&[u8]]) {
    xor_fold_striped(dst, sources, true, default_xor_threads());
}

/// XOR-fold many sources into one fresh parity buffer of length `len`.
pub fn parity_of(sources: &[&[u8]], len: usize) -> Vec<u8> {
    let mut out = vec![0u8; len];
    parity_into(&mut out, sources);
    out
}

/// The striped chain driver. `copy_first` selects fold semantics (`dst` is
/// *assigned* the fold) vs accumulate semantics (`dst ^=` every source).
/// Each worker owns one disjoint stripe of `dst` and runs the entire source
/// chain over it, so the stripe stays hot in cache across the chain.
pub fn xor_fold_striped(dst: &mut [u8], sources: &[&[u8]], copy_first: bool, threads: usize) {
    let len = dst.len();
    let work = len.saturating_mul(sources.len().max(1));
    let threads = threads.min((work / MIN_WORK_PER_THREAD).max(1));
    if len < PARALLEL_MIN_BYTES || threads <= 1 {
        fold_segment(dst, 0, sources, copy_first);
        return;
    }
    let stripe = stripe_len(len, threads);
    std::thread::scope(|scope| {
        let mut rest = dst;
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = stripe.min(rest.len());
            let (seg, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let seg_base = base;
            base += take;
            scope.spawn(move || fold_segment(seg, seg_base, sources, copy_first));
        }
    });
}

/// Per-worker chain over one stripe. `seg` covers absolute bytes
/// `[base, base + seg.len())` of the logical destination buffer.
fn fold_segment(seg: &mut [u8], base: usize, sources: &[&[u8]], copy_first: bool) {
    let mut sources = sources;
    if copy_first {
        match sources.split_first() {
            Some((first, rest)) => {
                let n = first.len().saturating_sub(base).min(seg.len());
                if n > 0 {
                    seg[..n].copy_from_slice(&first[base..base + n]);
                }
                seg[n..].fill(0);
                sources = rest;
            }
            None => {
                seg.fill(0);
                return;
            }
        }
    }
    for s in sources {
        let n = s.len().saturating_sub(base).min(seg.len());
        if n > 0 {
            xor_into(&mut seg[..n], &s[base..base + n]);
        }
    }
}

/// Stripe size: even split rounded up to a 64-byte cache line, floored so
/// tiny stripes never fan out across threads.
fn stripe_len(n: usize, threads: usize) -> usize {
    let per = n.div_ceil(threads.max(1));
    per.div_ceil(64).saturating_mul(64).max(STRIPE_FLOOR)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.next_u64() as u8).collect()
    }

    #[test]
    fn matches_scalar_on_many_shapes() {
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000, 4096, 100_003] {
            let src = rand_bytes(n, n as u64);
            let mut a = rand_bytes(n, n as u64 + 1);
            let mut b = a.clone();
            xor_into(&mut a, &src);
            xor_into_scalar(&mut b, &src);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn unaligned_offsets() {
        let src = rand_bytes(4096, 10);
        let base = rand_bytes(4200, 11);
        for off in 0..16 {
            let mut a = base.clone();
            let mut b = base.clone();
            xor_into(&mut a[off..off + 4096], &src);
            xor_into_scalar(&mut b[off..off + 4096], &src);
            assert_eq!(a, b, "off={off}");
        }
    }

    #[test]
    fn mismatched_lengths_use_overlap() {
        let mut d = vec![0xFFu8; 10];
        xor_into(&mut d, &[0x0F; 4]);
        assert_eq!(&d[..4], &[0xF0; 4]);
        assert_eq!(&d[4..], &[0xFF; 6]);
    }

    #[test]
    fn xor_is_involution() {
        let src = rand_bytes(10_000, 42);
        let orig = rand_bytes(10_000, 43);
        let mut d = orig.clone();
        xor_into(&mut d, &src);
        xor_into(&mut d, &src);
        assert_eq!(d, orig);
    }

    #[test]
    fn parity_reconstructs_any_member() {
        let a = rand_bytes(1000, 1);
        let b = rand_bytes(1000, 2);
        let c = rand_bytes(1000, 3);
        let p = parity_of(&[&a, &b, &c], 1000);
        let rec_b = parity_of(&[&p, &a, &c], 1000);
        assert_eq!(rec_b, b);
    }

    #[test]
    fn parity_of_copies_first_source_then_folds() {
        // fold semantics: out = s0 ^ s1 ^ ..., zero-padded to len
        let s0 = rand_bytes(100, 20);
        let s1 = rand_bytes(60, 21);
        let out = parity_of(&[&s0, &s1], 120);
        let mut expect = vec![0u8; 120];
        for (i, &b) in s0.iter().enumerate() {
            expect[i] ^= b;
        }
        for (i, &b) in s1.iter().enumerate() {
            expect[i] ^= b;
        }
        assert_eq!(out, expect);
        // no sources -> zeroes; one source -> a plain copy
        assert_eq!(parity_of(&[], 8), vec![0u8; 8]);
        assert_eq!(parity_of(&[&s0[..]], 100), s0);
    }

    #[test]
    fn striped_matches_serial_across_threshold_and_threads() {
        for n in [
            0usize,
            1,
            4096,
            PARALLEL_MIN_BYTES - 1,
            PARALLEL_MIN_BYTES,
            PARALLEL_MIN_BYTES + 13,
            3 * PARALLEL_MIN_BYTES + 777,
        ] {
            let src = rand_bytes(n, 7 ^ n as u64);
            let base = rand_bytes(n, 8 ^ n as u64);
            let mut want = base.clone();
            xor_into_scalar(&mut want, &src);
            for threads in [1usize, 2, 3, 8] {
                let mut got = base.clone();
                xor_into_striped(&mut got, &src, threads);
                assert_eq!(got, want, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn striped_fold_matches_serial_fold_over_threshold() {
        let len = 2 * PARALLEL_MIN_BYTES + 999;
        let srcs: Vec<Vec<u8>> = (0..4)
            .map(|i| rand_bytes(len - i * 100_000, 30 + i as u64))
            .collect();
        let views: Vec<&[u8]> = srcs.iter().map(Vec::as_slice).collect();
        // serial oracle
        let mut want = vec![0u8; len];
        for v in &views {
            xor_into_scalar(&mut want, v);
        }
        let got = parity_of(&views, len);
        assert_eq!(got, want);
        // accumulate semantics too (copy_first = false on dirty dst)
        let base = rand_bytes(len, 99);
        let mut want2 = base.clone();
        for v in &views {
            xor_into_scalar(&mut want2, v);
        }
        let mut got2 = base.clone();
        xor_fold_striped(&mut got2, &views, false, 4);
        assert_eq!(got2, want2);
    }

    #[test]
    fn parity_into_overwrites_stale_destination() {
        // fold semantics must not depend on prior dst contents
        let len = PARALLEL_MIN_BYTES + 17;
        let s = rand_bytes(len / 2, 55);
        let mut dst = rand_bytes(len, 56); // garbage
        parity_into(&mut dst, &[&s]);
        assert_eq!(&dst[..s.len()], &s[..]);
        assert!(dst[s.len()..].iter().all(|&b| b == 0), "padding zeroed");
    }
}
