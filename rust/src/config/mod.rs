//! Configuration system: model zoo, parallelism plans, fault-tolerance
//! policies and run configs (JSON files or CLI overrides).
//!
//! Two kinds of "model" exist on purpose:
//! * **Artifact models** (`tiny`, `e2e-25m`, ...) — exported by `aot.py` with
//!   real HLO + manifest; the trainer executes them via PJRT.
//! * **Zoo models** (`opt-125m` ... `opt-2.7b`) — the paper's evaluation
//!   subjects. Their *parameter sizes* drive the data-path benches (saving
//!   speed, overheads), which move real bytes but do not need real compute.

pub mod zoo;

use std::path::Path;

use anyhow::{Context, Result};

use crate::topology::ParallelPlan;
use crate::util::json::Json;

pub use zoo::{ModelSpec, OPT_ZOO};

/// Which fault-tolerance method a run uses (paper §6.1 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FtMethod {
    /// no fault tolerance (upper-bound throughput)
    None,
    /// CheckFreq-style fully asynchronous checkpointing (unsharded d2h +
    /// serialize + storage I/O pipeline)
    CheckFreq,
    /// TorchSnapshot-style DP-sharded asynchronous checkpointing
    TorchSnapshot,
    /// REFT in-memory snapshotting (SMP + optional RAIM5), cloud persist
    /// only as a rare backstop
    ReftSn,
    /// REFT's sharded checkpointing path (snapshot -> SMP -> storage)
    ReftCkpt,
}

impl FtMethod {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" => FtMethod::None,
            "checkfreq" => FtMethod::CheckFreq,
            "torchsnapshot" => FtMethod::TorchSnapshot,
            "reft-sn" | "reftsn" | "reft_sn" => FtMethod::ReftSn,
            "reft-ckpt" | "reftckpt" | "reft_ckpt" => FtMethod::ReftCkpt,
            other => anyhow::bail!("unknown fault-tolerance method `{other}`"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FtMethod::None => "none",
            FtMethod::CheckFreq => "checkfreq",
            FtMethod::TorchSnapshot => "torchsnapshot",
            FtMethod::ReftSn => "reft-sn",
            FtMethod::ReftCkpt => "reft-ckpt",
        }
    }
}

/// Durable-tier persistence knobs (the REFT-Ckpt background drain —
/// `rust/src/persist/`).
#[derive(Debug, Clone)]
pub struct PersistConfig {
    /// route REFT-Ckpt persists through the background engine instead of
    /// the inline trainer-thread `storage.put`
    pub enabled: bool,
    /// cluster-wide upload pacing budget in bytes/sec (0 = unthrottled):
    /// persist I/O must not starve training bandwidth
    pub throttle_bytes_per_sec: u64,
    /// streaming chunk granularity the throttle meters
    pub chunk_bytes: usize,
    /// retention: always keep the newest K manifests (floors at 1)
    pub keep_last: usize,
    /// retention: additionally keep every step divisible by N (0 = off)
    pub keep_every: u64,
    /// derive the persist cadence live from the Appendix-A interval math
    /// instead of the static `persist_every` knob
    pub auto_interval: bool,
    /// per-node failure rate (per second) fed to the interval scheduler —
    /// the hwsim λ_node (superseded by the rolling empirical rate once
    /// enough live failure events accrue)
    pub lambda_node: f64,
    /// engine pipeline depth: how many persist jobs may run their
    /// fetch/upload phase concurrently (manifest commits stay in enqueue
    /// order; 1 = the strictly sequential pre-pipeline engine)
    pub pipeline_jobs: usize,
    /// multipart threshold *and* part size in bytes: shards larger than
    /// this land as `part-{k}` objects with per-part CRCs, so a crashed
    /// upload resumes from the last durable part (0 disables multipart)
    pub multipart_part_bytes: usize,
    /// bounded in-node worker pool for multipart part uploads: how many
    /// `part-{k}` puts one writer keeps in flight concurrently (the node's
    /// throttle lane still enforces its bytes/sec budget; 1 = the serial
    /// pre-parallel loop, floors at 1)
    pub multipart_streams: usize,
    /// let the engine tune its own pipeline depth between 1 and
    /// `pipeline_jobs` from the EWMA of observed storage RTT vs SMP fetch
    /// time (off = the static `pipeline_jobs` depth, the baseline)
    pub adaptive_depth: bool,
    /// sparse delta persists: extent granularity in bytes for the engine's
    /// content-hash diff against the previously committed round (0 = every
    /// persist uploads full shards). Mirrored from `ft.delta_extent_bytes`
    /// by the persist driver; benches may set it directly.
    pub delta_extent_bytes: usize,
    /// force a full (base) upload once a delta chain reaches this depth,
    /// bounding restore chain length and GC liveness (mirrored from
    /// `ft.delta_chain_max`)
    pub delta_chain_max: u64,
}

impl Default for PersistConfig {
    fn default() -> Self {
        PersistConfig {
            enabled: false,
            throttle_bytes_per_sec: 256 * 1024 * 1024,
            chunk_bytes: 8 * 1024 * 1024,
            keep_last: 2,
            keep_every: 0,
            auto_interval: false,
            lambda_node: 1e-4,
            pipeline_jobs: 2,
            multipart_part_bytes: 8 * 1024 * 1024,
            multipart_streams: 4,
            adaptive_depth: false,
            delta_extent_bytes: 0,
            delta_chain_max: 8,
        }
    }
}

/// Fault-tolerance policy knobs.
#[derive(Debug, Clone)]
pub struct FtConfig {
    pub method: FtMethod,
    /// snapshot every k iterations (REFT-Sn) / checkpoint interval for baselines
    pub snapshot_interval: usize,
    /// persist to storage every k snapshots (REFT-Ckpt backstop)
    pub persist_every: usize,
    /// tiny-bucket size in bytes for d2h snapshot copies (§4.1)
    pub bucket_bytes: usize,
    /// enable RAIM5 parity protection (§4.3)
    pub raim5: bool,
    /// number of clean snapshot copies kept on each SMP (>= 1)
    pub clean_copies: usize,
    /// drive saves through the hierarchical asynchronous snapshot
    /// coordinator (§4.1 L1-L3): `snapshot()` enqueues and returns, buckets
    /// drain across subsequent iteration ticks. Off by default so the
    /// classic blocking semantics (snapshot complete on return) hold unless
    /// a run opts in; the e2e driver and the async benches turn it on.
    pub async_snapshot: bool,
    /// L2 interference bound: max buckets each node drains per `tick()`.
    /// `drain_buckets_per_tick * bucket_bytes` is the per-node PCIe budget
    /// one training iteration donates to snapshot traffic.
    pub drain_buckets_per_tick: usize,
    /// derive the in-memory snapshot cadence live from Eq. 9 (measured
    /// snapshot cost x rolling empirical λ) instead of the static
    /// `snapshot_interval` knob; below the empirical event floor the
    /// static interval still holds
    pub auto_snapshot_interval: bool,
    /// sparse delta snapshots: extent granularity in bytes for the
    /// content-hash diff of each round's payload against the previous
    /// *completed* round. 0 (the default) disables the delta layer and
    /// every round ships full shards — the pre-PR-7 behavior. Non-zero
    /// values floor at 1 KiB so a typo cannot explode the extent tables.
    pub delta_extent_bytes: usize,
    /// periodic full-round fallback: after this many consecutive sparse
    /// rounds a full base round is forced, bounding delta-chain depth for
    /// both the in-memory patch path and durable chain reconstruction
    pub delta_chain_max: u64,
    /// reshape-on-restore: accept a committed manifest whose pipeline shape
    /// differs from the running topology and regather it through the atom
    /// index instead of aborting the recovery. Off by default — an elastic
    /// shrink/grow is an operator decision, not something a plain restart
    /// should do silently.
    pub reshape_on_restore: bool,
    /// durable-tier persistence engine (REFT-Ckpt background drain)
    pub persist: PersistConfig,
}

impl Default for FtConfig {
    fn default() -> Self {
        FtConfig {
            method: FtMethod::ReftSn,
            snapshot_interval: 1,
            persist_every: 50,
            bucket_bytes: 16 * 1024 * 1024,
            raim5: true,
            clean_copies: 1,
            async_snapshot: false,
            drain_buckets_per_tick: 8,
            auto_snapshot_interval: false,
            delta_extent_bytes: 0,
            delta_chain_max: 8,
            reshape_on_restore: false,
            persist: PersistConfig::default(),
        }
    }
}

/// A full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// artifact model name (must exist under `artifacts/`) or zoo name
    pub model: String,
    pub plan: ParallelPlan,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub steps: usize,
    /// microbatches per iteration (pipeline) / grad-accum factor (DP)
    pub microbatches: usize,
    pub ft: FtConfig,
    pub seed: u64,
    /// artifacts directory
    pub artifacts_dir: String,
    /// fp32 bytes per parameter element
    pub dtype_bytes: usize,
    /// Adam keeps 3 extra states per parameter (paper §6.1: "triple extra")
    pub opt_state_multiplier: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "tiny".into(),
            plan: ParallelPlan::dp_only(1),
            nodes: 1,
            gpus_per_node: 4,
            steps: 10,
            microbatches: 4,
            ft: FtConfig::default(),
            seed: 42,
            artifacts_dir: "artifacts".into(),
            dtype_bytes: 4,
            opt_state_multiplier: 3,
        }
    }
}

impl RunConfig {
    /// Parse a JSON config file; missing fields keep defaults.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("config: {e}"))?;
        let mut c = RunConfig::default();
        if let Some(s) = j.get("model").and_then(Json::as_str) {
            c.model = s.to_string();
        }
        if let Some(p) = j.get("parallel") {
            c.plan = ParallelPlan::new(
                p.get("dp").and_then(Json::as_usize).unwrap_or(1),
                p.get("tp").and_then(Json::as_usize).unwrap_or(1),
                p.get("pp").and_then(Json::as_usize).unwrap_or(1),
            );
        }
        if let Some(n) = j.get("nodes").and_then(Json::as_usize) {
            c.nodes = n;
        }
        if let Some(n) = j.get("gpus_per_node").and_then(Json::as_usize) {
            c.gpus_per_node = n;
        }
        if let Some(n) = j.get("steps").and_then(Json::as_usize) {
            c.steps = n;
        }
        if let Some(n) = j.get("microbatches").and_then(Json::as_usize) {
            c.microbatches = n;
        }
        if let Some(n) = j.get("seed").and_then(Json::as_f64) {
            c.seed = n as u64;
        }
        if let Some(s) = j.get("artifacts_dir").and_then(Json::as_str) {
            c.artifacts_dir = s.to_string();
        }
        if let Some(ft) = j.get("ft") {
            if let Some(s) = ft.get("method").and_then(Json::as_str) {
                c.ft.method = FtMethod::parse(s)?;
            }
            if let Some(n) = ft.get("snapshot_interval").and_then(Json::as_usize) {
                c.ft.snapshot_interval = n.max(1);
            }
            if let Some(n) = ft.get("persist_every").and_then(Json::as_usize) {
                c.ft.persist_every = n.max(1);
            }
            if let Some(n) = ft.get("bucket_bytes").and_then(Json::as_usize) {
                c.ft.bucket_bytes = n.max(4096);
            }
            if let Some(b) = ft.get("raim5").and_then(Json::as_bool) {
                c.ft.raim5 = b;
            }
            if let Some(n) = ft.get("clean_copies").and_then(Json::as_usize) {
                c.ft.clean_copies = n.max(1);
            }
            if let Some(b) = ft.get("async_snapshot").and_then(Json::as_bool) {
                c.ft.async_snapshot = b;
            }
            if let Some(n) = ft.get("drain_buckets_per_tick").and_then(Json::as_usize) {
                c.ft.drain_buckets_per_tick = n.max(1);
            }
            if let Some(b) = ft.get("auto_snapshot_interval").and_then(Json::as_bool) {
                c.ft.auto_snapshot_interval = b;
            }
            if let Some(n) = ft.get("delta_extent_bytes").and_then(Json::as_usize) {
                // 0 disables the delta layer; non-zero floors at 1 KiB
                c.ft.delta_extent_bytes = if n == 0 { 0 } else { n.max(1024) };
            }
            if let Some(n) = ft.get("delta_chain_max").and_then(Json::as_u64) {
                c.ft.delta_chain_max = n.max(1);
            }
            if let Some(b) = ft.get("reshape_on_restore").and_then(Json::as_bool) {
                c.ft.reshape_on_restore = b;
            }
            if let Some(p) = ft.get("persist") {
                if let Some(b) = p.get("enabled").and_then(Json::as_bool) {
                    c.ft.persist.enabled = b;
                }
                if let Some(n) = p.get("throttle_bytes_per_sec").and_then(Json::as_u64) {
                    c.ft.persist.throttle_bytes_per_sec = n;
                }
                if let Some(n) = p.get("chunk_bytes").and_then(Json::as_usize) {
                    c.ft.persist.chunk_bytes = n.max(4096);
                }
                if let Some(n) = p.get("keep_last").and_then(Json::as_usize) {
                    c.ft.persist.keep_last = n.max(1);
                }
                if let Some(n) = p.get("keep_every").and_then(Json::as_u64) {
                    c.ft.persist.keep_every = n;
                }
                if let Some(b) = p.get("auto_interval").and_then(Json::as_bool) {
                    c.ft.persist.auto_interval = b;
                }
                if let Some(l) = p.get("lambda_node").and_then(Json::as_f64) {
                    c.ft.persist.lambda_node = l;
                }
                if let Some(n) = p.get("pipeline_jobs").and_then(Json::as_usize) {
                    c.ft.persist.pipeline_jobs = n.max(1);
                }
                if let Some(n) = p.get("multipart_part_bytes").and_then(Json::as_usize) {
                    // 0 disables multipart; non-zero floors at 4 KiB so a
                    // typo cannot explode a shard into millions of parts
                    c.ft.persist.multipart_part_bytes =
                        if n == 0 { 0 } else { n.max(4096) };
                }
                if let Some(n) = p.get("multipart_streams").and_then(Json::as_usize) {
                    c.ft.persist.multipart_streams = n.max(1);
                }
                if let Some(b) = p.get("adaptive_depth").and_then(Json::as_bool) {
                    c.ft.persist.adaptive_depth = b;
                }
            }
        }
        Ok(c)
    }

    /// Bytes of FT payload per parameter (weights + Adam states).
    pub fn bytes_per_param(&self) -> u64 {
        (self.dtype_bytes * (1 + self.opt_state_multiplier)) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        let c = RunConfig::default();
        assert_eq!(c.model, "tiny");
        assert_eq!(c.bytes_per_param(), 16);
    }

    #[test]
    fn parse_full_config() {
        let text = r#"{
            "model": "opt-350m",
            "parallel": {"dp": 6, "tp": 4, "pp": 1},
            "nodes": 6, "gpus_per_node": 4,
            "steps": 100, "microbatches": 8, "seed": 7,
            "ft": {"method": "reft-sn", "snapshot_interval": 2,
                   "persist_every": 10, "bucket_bytes": 8388608,
                   "raim5": true, "clean_copies": 2}
        }"#;
        let c = RunConfig::from_json_text(text).unwrap();
        assert_eq!(c.model, "opt-350m");
        assert_eq!(c.plan, ParallelPlan::new(6, 4, 1));
        assert_eq!(c.ft.method, FtMethod::ReftSn);
        assert_eq!(c.ft.clean_copies, 2);
        assert_eq!(c.ft.bucket_bytes, 8 * 1024 * 1024);
    }

    #[test]
    fn parse_coordinator_knobs() {
        let text = r#"{
            "ft": {"async_snapshot": true, "drain_buckets_per_tick": 3}
        }"#;
        let c = RunConfig::from_json_text(text).unwrap();
        assert!(c.ft.async_snapshot);
        assert_eq!(c.ft.drain_buckets_per_tick, 3);
        // defaults: blocking semantics, budget floor of 1
        let d = RunConfig::default();
        assert!(!d.ft.async_snapshot);
        assert!(d.ft.drain_buckets_per_tick >= 1);
        let z = RunConfig::from_json_text(r#"{"ft": {"drain_buckets_per_tick": 0}}"#).unwrap();
        assert_eq!(z.ft.drain_buckets_per_tick, 1);
    }

    #[test]
    fn parse_persist_section() {
        let text = r#"{
            "ft": {"method": "reft-ckpt",
                   "persist": {"enabled": true,
                               "throttle_bytes_per_sec": 1048576,
                               "chunk_bytes": 65536,
                               "keep_last": 3, "keep_every": 100,
                               "auto_interval": true, "lambda_node": 0.001,
                               "pipeline_jobs": 3,
                               "multipart_part_bytes": 1048576,
                               "multipart_streams": 6,
                               "adaptive_depth": true},
                   "auto_snapshot_interval": true}
        }"#;
        let c = RunConfig::from_json_text(text).unwrap();
        assert!(c.ft.persist.enabled);
        assert!(c.ft.persist.adaptive_depth);
        assert!(c.ft.auto_snapshot_interval);
        assert_eq!(c.ft.persist.throttle_bytes_per_sec, 1 << 20);
        assert_eq!(c.ft.persist.chunk_bytes, 64 * 1024);
        assert_eq!(c.ft.persist.keep_last, 3);
        assert_eq!(c.ft.persist.keep_every, 100);
        assert!(c.ft.persist.auto_interval);
        assert!((c.ft.persist.lambda_node - 1e-3).abs() < 1e-12);
        assert_eq!(c.ft.persist.pipeline_jobs, 3);
        assert_eq!(c.ft.persist.multipart_part_bytes, 1 << 20);
        assert_eq!(c.ft.persist.multipart_streams, 6);
        // defaults: engine off, retention floors, control plane static
        let d = RunConfig::default();
        assert!(!d.ft.persist.enabled);
        assert!(d.ft.persist.keep_last >= 1);
        assert!(d.ft.persist.pipeline_jobs >= 1);
        assert!(d.ft.persist.multipart_streams >= 1);
        assert!(!d.ft.persist.adaptive_depth);
        assert!(!d.ft.auto_snapshot_interval);
        let z = RunConfig::from_json_text(r#"{"ft": {"persist": {"keep_last": 0}}}"#).unwrap();
        assert_eq!(z.ft.persist.keep_last, 1);
        // pipeline depth floors at 1 (sequential); multipart 0 = disabled,
        // non-zero floors at 4 KiB
        let z = RunConfig::from_json_text(
            r#"{"ft": {"persist": {"pipeline_jobs": 0, "multipart_part_bytes": 7}}}"#,
        )
        .unwrap();
        assert_eq!(z.ft.persist.pipeline_jobs, 1);
        assert_eq!(z.ft.persist.multipart_part_bytes, 4096);
        let z = RunConfig::from_json_text(
            r#"{"ft": {"persist": {"multipart_part_bytes": 0}}}"#,
        )
        .unwrap();
        assert_eq!(z.ft.persist.multipart_part_bytes, 0);
        // part-upload streams floor at 1 (serial)
        let z = RunConfig::from_json_text(
            r#"{"ft": {"persist": {"multipart_streams": 0}}}"#,
        )
        .unwrap();
        assert_eq!(z.ft.persist.multipart_streams, 1);
    }

    #[test]
    fn parse_delta_knobs() {
        let text = r#"{
            "ft": {"delta_extent_bytes": 65536, "delta_chain_max": 4}
        }"#;
        let c = RunConfig::from_json_text(text).unwrap();
        assert_eq!(c.ft.delta_extent_bytes, 64 * 1024);
        assert_eq!(c.ft.delta_chain_max, 4);
        // defaults: delta layer off, chain bound sane
        let d = RunConfig::default();
        assert_eq!(d.ft.delta_extent_bytes, 0);
        assert!(d.ft.delta_chain_max >= 1);
        assert_eq!(d.ft.persist.delta_extent_bytes, 0);
        // 0 keeps the layer disabled; tiny values floor at 1 KiB; the
        // chain bound floors at 1 (every round a base)
        let z = RunConfig::from_json_text(
            r#"{"ft": {"delta_extent_bytes": 0, "delta_chain_max": 0}}"#,
        )
        .unwrap();
        assert_eq!(z.ft.delta_extent_bytes, 0);
        assert_eq!(z.ft.delta_chain_max, 1);
        let z = RunConfig::from_json_text(r#"{"ft": {"delta_extent_bytes": 7}}"#).unwrap();
        assert_eq!(z.ft.delta_extent_bytes, 1024);
    }

    #[test]
    fn parse_reshape_on_restore() {
        // off by default, and untouched by unrelated ft keys
        assert!(!RunConfig::default().ft.reshape_on_restore);
        let c = RunConfig::from_json_text(r#"{"ft": {"delta_chain_max": 4}}"#).unwrap();
        assert!(!c.ft.reshape_on_restore);
        let c = RunConfig::from_json_text(r#"{"ft": {"reshape_on_restore": true}}"#).unwrap();
        assert!(c.ft.reshape_on_restore);
        let c = RunConfig::from_json_text(r#"{"ft": {"reshape_on_restore": false}}"#).unwrap();
        assert!(!c.ft.reshape_on_restore);
    }

    #[test]
    fn parse_partial_keeps_defaults() {
        let c = RunConfig::from_json_text(r#"{"model": "tiny"}"#).unwrap();
        assert_eq!(c.steps, RunConfig::default().steps);
        assert!(c.ft.raim5);
    }

    #[test]
    fn ft_method_names_roundtrip() {
        for m in [
            FtMethod::None,
            FtMethod::CheckFreq,
            FtMethod::TorchSnapshot,
            FtMethod::ReftSn,
            FtMethod::ReftCkpt,
        ] {
            assert_eq!(FtMethod::parse(m.name()).unwrap(), m);
        }
        assert!(FtMethod::parse("bogus").is_err());
    }

    #[test]
    fn rejects_bad_json() {
        assert!(RunConfig::from_json_text("{").is_err());
        assert!(RunConfig::from_json_text(r#"{"ft": {"method": "nope"}}"#).is_err());
    }
}
