//! OPT model zoo — the paper's evaluation subjects (§6.1 "Models and
//! Datasets": OPT-125M, OPT-350M, OPT-1.3B, OPT-2.7B).
//!
//! Parameter counts are computed from the published architectures
//! (vocab 50272, learned positions 2048, pre-LN decoder) so the data-path
//! benches shard/copy/encode *exactly* the byte volumes the paper's
//! experiments moved.

/// Architecture + derived sizes of one zoo model.
#[derive(Debug, Clone, Copy)]
pub struct ModelSpec {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl ModelSpec {
    /// Parameters of one pre-LN decoder block (matches `model.py::block_specs`).
    pub fn block_params(&self) -> u64 {
        let d = self.d_model as u64;
        let f = self.d_ff as u64;
        // ln1 (2d) + qkv (3d^2 + 3d) + out (d^2 + d) + ln2 (2d) + fc (df + f)
        // + proj (fd + d)
        2 * d + (3 * d * d + 3 * d) + (d * d + d) + 2 * d + (d * f + f) + (f * d + d)
    }

    /// Total trainable parameters (token emb + pos emb + blocks + final LN +
    /// untied LM head).
    pub fn total_params(&self) -> u64 {
        let d = self.d_model as u64;
        let v = self.vocab as u64;
        let t = self.max_seq as u64;
        v * d + t * d + self.n_layers as u64 * self.block_params() + 2 * d + d * v
    }

    /// fp32 bytes of the raw weights.
    pub fn param_bytes(&self) -> u64 {
        self.total_params() * 4
    }

    /// Bytes of one complete FT payload: weights + Adam's triple states
    /// (paper §6.1: Adam "introduces triple extra parameters to save").
    pub fn save_bytes(&self) -> u64 {
        self.param_bytes() * 4
    }

    /// Parameters in one contiguous PP stage out of `pp` (balanced layer
    /// split; first stage carries embeddings, last carries LN + head).
    pub fn stage_params(&self, stage: usize, pp: usize) -> u64 {
        assert!(stage < pp && pp <= self.n_layers);
        let base = self.n_layers / pp;
        let rem = self.n_layers % pp;
        let layers = base + usize::from(stage < rem);
        let d = self.d_model as u64;
        let v = self.vocab as u64;
        let mut p = layers as u64 * self.block_params();
        if stage == 0 {
            p += v * d + self.max_seq as u64 * d;
        }
        if stage == pp - 1 {
            p += 2 * d + d * v;
        }
        p
    }
}

/// The paper's four OPT configurations.
pub const OPT_ZOO: &[ModelSpec] = &[
    ModelSpec {
        name: "opt-125m",
        vocab: 50272,
        d_model: 768,
        n_layers: 12,
        n_heads: 12,
        d_ff: 3072,
        max_seq: 2048,
    },
    ModelSpec {
        name: "opt-350m",
        vocab: 50272,
        d_model: 1024,
        n_layers: 24,
        n_heads: 16,
        d_ff: 4096,
        max_seq: 2048,
    },
    ModelSpec {
        name: "opt-1.3b",
        vocab: 50272,
        d_model: 2048,
        n_layers: 24,
        n_heads: 32,
        d_ff: 8192,
        max_seq: 2048,
    },
    ModelSpec {
        name: "opt-2.7b",
        vocab: 50272,
        d_model: 2560,
        n_layers: 32,
        n_heads: 32,
        d_ff: 10240,
        max_seq: 2048,
    },
];

/// Look up a zoo model by name.
pub fn zoo_model(name: &str) -> Option<&'static ModelSpec> {
    OPT_ZOO.iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_sizes_match_published_scale() {
        // published sizes are for tied embeddings; our untied-head layout adds
        // ~vocab*d. Check each model lands within 15% of its nameplate.
        let expect = [
            ("opt-125m", 125e6),
            ("opt-350m", 350e6),
            ("opt-1.3b", 1.3e9),
            ("opt-2.7b", 2.7e9),
        ];
        for (name, nominal) in expect {
            let m = zoo_model(name).unwrap();
            let p = m.total_params() as f64;
            let ratio = p / nominal;
            assert!(
                (0.85..1.45).contains(&ratio),
                "{name}: {p:.3e} params vs nominal {nominal:.3e} (ratio {ratio:.2})"
            );
        }
    }

    #[test]
    fn stage_split_covers_total() {
        for m in OPT_ZOO {
            for pp in [1usize, 2, 4, 6] {
                let sum: u64 = (0..pp).map(|s| m.stage_params(s, pp)).sum();
                assert_eq!(sum, m.total_params(), "{} pp={pp}", m.name);
            }
        }
    }

    #[test]
    fn save_bytes_is_4x_params() {
        let m = zoo_model("opt-2.7b").unwrap();
        assert_eq!(m.save_bytes(), m.param_bytes() * 4);
        // OPT-2.7B FT payload lands in the tens-of-GB range the paper discusses
        let gb = m.save_bytes() as f64 / 1e9;
        assert!((40.0..60.0).contains(&gb), "{gb} GB");
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(zoo_model("gpt-5").is_none());
    }
}
