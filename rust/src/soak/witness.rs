//! The soak's witness plane: a bounded run of the REAL fabric replaying
//! the scale plane's incident shapes, so the byte-level guarantees the
//! simulation takes as axioms are re-proven end to end each run.
//!
//! The witness drives ReftCluster (SMPs + RAIM5), the background
//! PersistEngine, the RecoveryPlan decision tree, and the retention GC on
//! a [`BrownoutStorage`]-wrapped store, through one scripted correlated
//! schedule:
//!
//! 1. software failure → SMP resume, bit-exact;
//! 2. flap (a train of software kills) → every resume bit-exact;
//! 3. single hardware loss → RAIM5 decode, bit-exact, substitute joins;
//! 4. correlated rack loss (every node of one SG, same tick) **during a
//!    storage brownout** → the in-memory gather refuses, the probe sees no
//!    durable tier while the window lasts, and once it passes the newest
//!    manifest serves, bit-exact;
//! 5. final retention GC → the superseded round's keys are gone, nothing
//!    referenced is touched, and a second pass deletes zero objects (the
//!    zero-leaked-keys invariant).

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::checkpoint::{MemStorage, Storage};
use crate::config::{FtConfig, PersistConfig};
use crate::elastic::{DurableTier, RecoveryDecision, RecoveryPath, RecoveryPlan, ReftCluster};
use crate::hwsim::seed;
use crate::persist::{self, run_gc, PersistEngine, RetentionPolicy};
use crate::snapshot::SharedPayload;
use crate::topology::{ParallelPlan, Topology};
use crate::util::rng::Rng;

use super::BrownoutStorage;

/// What the witness run observed; every field is also asserted inline, so
/// a constructed report is already a passing one — the struct exists for
/// the BENCH record.
#[derive(Debug, Clone, Default)]
pub struct WitnessReport {
    pub seed: u64,
    /// scripted incidents replayed
    pub incidents: u64,
    pub smp_restores: u64,
    pub raim5_restores: u64,
    pub durable_restores: u64,
    /// storage operations refused inside the brownout window
    pub brownout_refusals: u64,
    /// payload bytes verified bit-exact across all restores
    pub bytes_verified: u64,
    /// keys of the GC'd round still present after the final GC (must be 0)
    pub leaked_keys: usize,
    /// objects deleted by a second GC pass (must be 0: pass one left no
    /// retirable debris behind)
    pub gc_second_pass_deletes: usize,
}

fn payloads(stage_bytes: &[u64], rng: &mut Rng) -> Vec<SharedPayload> {
    stage_bytes
        .iter()
        .map(|&b| SharedPayload::new((0..b).map(|_| rng.next_u64() as u8).collect()))
        .collect()
}

fn as_bytes(p: &[SharedPayload]) -> Vec<Vec<u8>> {
    p.iter().map(|x| x.as_slice().to_vec()).collect()
}

/// One durable round through a fresh engine (a fresh engine has no cached
/// base, so each round commits a full manifest — keeps the GC leg's chain
/// reasoning trivial).
fn persist_round(
    model: &str,
    storage: Arc<dyn Storage>,
    cluster: &ReftCluster,
    step: u64,
) -> Result<()> {
    let engine = PersistEngine::start(
        model,
        storage,
        cluster.plan.clone(),
        PersistConfig {
            enabled: true,
            throttle_bytes_per_sec: 0,
            chunk_bytes: 4096,
            keep_last: 8,
            ..PersistConfig::default()
        },
    );
    engine.enqueue(step, cluster.persist_sources(), vec![])?;
    engine.flush()?;
    let st = engine.stats();
    ensure!(
        st.manifests_committed == 1,
        "step-{step} persist round failed: {:?}",
        st.last_error
    );
    Ok(())
}

/// Replay the scripted correlated schedule on the real fabric. Paper
/// Fig. 3 shape (2 DP x 4 TP x 3 PP on 6 nodes), ~72 kB of state —
/// bounded to well under a second, deterministic in `master_seed`.
pub fn run_witness(master_seed: u64) -> Result<WitnessReport> {
    let topo = Topology::build(ParallelPlan::new(2, 4, 3), 6, 4)?;
    let stage_bytes = vec![24_000u64, 24_000, 24_000];
    let ft = FtConfig { raim5: true, ..FtConfig::default() };
    let mut cluster = ReftCluster::start(topo.clone(), &stage_bytes, ft)?;
    let model = "soak";
    let storage = Arc::new(BrownoutStorage::wrap(Arc::new(MemStorage::new())));
    let mut rng = seed::stream(master_seed, seed::PAYLOAD);
    let mut rep = WitnessReport { seed: master_seed, ..WitnessReport::default() };

    let verify = |got: &[Vec<u8>], want: &[SharedPayload]| -> Result<u64> {
        let want = as_bytes(want);
        ensure!(got == want.as_slice(), "restored bytes differ from the protected round");
        Ok(want.iter().map(|v| v.len() as u64).sum())
    };

    // round 1 protected in memory and durably committed at step 10
    let v1 = payloads(&stage_bytes, &mut rng);
    cluster.snapshot_all(&v1)?;
    persist_round(model, storage.clone() as Arc<dyn Storage>, &cluster, 10)?;

    // incident 1: software failure — SMP resume, bit-exact
    let plan = RecoveryPlan::probe(&topo, &[], true, storage.as_ref(), model);
    ensure!(plan.decision == RecoveryDecision::ResumeFromSmp, "{:?}", plan.decision);
    rep.bytes_verified += verify(&cluster.restore_all(&[])?, &v1)?;
    rep.smp_restores += 1;
    rep.incidents += 1;

    // incident 2: flap — three rapid software kills, every resume exact
    for _ in 0..3 {
        rep.bytes_verified += verify(&cluster.restore_all(&[])?, &v1)?;
        rep.smp_restores += 1;
    }
    rep.incidents += 1;

    // incident 3: single hardware loss — RAIM5 decode + substitute joins
    let victim = topo.sharding_group(0).nodes[0];
    cluster.kill_node(victim);
    let plan = RecoveryPlan::probe(&topo, &[victim], true, storage.as_ref(), model);
    ensure!(
        plan.predicted() == Some(RecoveryPath::InMemory),
        "single loss must stay in memory: {:?}",
        plan.decision
    );
    rep.bytes_verified += verify(&cluster.restore_all(&[victim])?, &v1)?;
    rep.raim5_restores += 1;
    rep.incidents += 1;
    cluster.replace_node(victim)?;

    // round 2 protected + committed at step 30 (the round the rack-loss
    // recovery must land on)
    let v2 = payloads(&stage_bytes, &mut rng);
    cluster.snapshot_all(&v2)?;
    persist_round(model, storage.clone() as Arc<dyn Storage>, &cluster, 30)?;

    // incident 4: correlated rack loss — the whole SG dies in one tick,
    // with the durable backend browned out when recovery first probes
    let rack = topo.sharding_group(0).nodes;
    ensure!(rack.len() >= 2, "witness shape must have multi-node SGs");
    for &n in &rack {
        cluster.kill_node(n);
    }
    storage.set_dark(true);
    let dark_plan = RecoveryPlan::probe(&topo, &rack, true, storage.as_ref(), model);
    ensure!(
        dark_plan.predicted().is_none(),
        "mid-brownout the probe must see no durable tier: {:?}",
        dark_plan.decision
    );
    ensure!(
        cluster.restore_all(&rack).is_err(),
        "an in-memory gather with a whole SG gone must refuse, not fabricate state"
    );
    // the brownout window passes; the controller re-probes instead of
    // declaring the state unrecoverable
    storage.set_dark(false);
    rep.brownout_refusals = storage.refusals();
    ensure!(rep.brownout_refusals > 0, "the dark probe must have been refused");
    // the brownout also tore a manifest upload mid-write: a truncated
    // step-35 blob now shadows the committed step-30 round. The resolver
    // must skip it (newest *decodable* wins) and say so on the torn-skip
    // counter — a silent skip here would hide real storage corruption.
    let torn_key = persist::manifest_key(model, 35);
    storage.put(&torn_key, b"{\"model\": \"soak\"")?;
    let torn_before = persist::manifest_torn_count();
    let plan = RecoveryPlan::probe(&topo, &rack, true, storage.as_ref(), model);
    ensure!(
        plan.predicted() == Some(RecoveryPath::Durable(DurableTier::Manifest)),
        "rack loss must route to the durable manifest tier: {:?}",
        plan.decision
    );
    let (man, data) =
        persist::resolve_for_recovery(storage.as_ref(), model, stage_bytes.len(), None)
            .context("no durable round resolvable after the brownout lifted")?;
    ensure!(
        man.snapshot_step == 30,
        "recovery must land on the newest round, got {}",
        man.snapshot_step
    );
    ensure!(
        persist::manifest_torn_count() > torn_before,
        "skipping the torn step-35 manifest must be counted, not silent"
    );
    // the operator replaces the torn blob (here: removes it) before the
    // retention leg, so GC's keep-last accounting sees only real rounds
    storage.delete(&torn_key)?;
    rep.bytes_verified += verify(&data, &v2)?;
    rep.durable_restores += 1;
    rep.incidents += 1;

    // final GC: retire the superseded step-10 round, leak nothing
    let policy = RetentionPolicy { keep_last: 1, keep_every: 0 };
    let gc1 = run_gc(storage.as_ref(), model, &policy, None)?;
    ensure!(gc1.manifests_deleted == 1, "exactly the step-10 manifest retires: {gc1:?}");
    let stale = format!("step-{:012}", 10u64);
    rep.leaked_keys = storage.list().iter().filter(|k| k.contains(&stale)).count();
    ensure!(rep.leaked_keys == 0, "{} step-10 keys leaked past GC", rep.leaked_keys);
    ensure!(
        persist::persisted_steps(storage.as_ref(), model) == vec![30],
        "only the newest round may remain manifested"
    );
    // and the surviving round still serves after GC
    let (post_gc_man, post_gc_data) =
        persist::resolve_for_recovery(storage.as_ref(), model, stage_bytes.len(), None)
            .context("GC broke the retained round")?;
    ensure!(post_gc_man.snapshot_step == 30 && post_gc_data == as_bytes(&v2));
    // a second pass finds zero retirable objects: pass one was complete
    let gc2 = run_gc(storage.as_ref(), model, &policy, None)?;
    rep.gc_second_pass_deletes = gc2.manifests_deleted + gc2.blobs_deleted;
    ensure!(
        rep.gc_second_pass_deletes == 0,
        "second GC pass still found debris: {gc2:?}"
    );

    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn witness_replays_clean_under_fixed_seed() {
        let rep = run_witness(0x50AC_2026).unwrap();
        assert_eq!(rep.incidents, 4);
        assert_eq!(rep.smp_restores, 4);
        assert_eq!(rep.raim5_restores, 1);
        assert_eq!(rep.durable_restores, 1);
        assert!(rep.brownout_refusals > 0);
        assert_eq!(rep.leaked_keys, 0);
        assert_eq!(rep.gc_second_pass_deletes, 0);
        assert_eq!(rep.bytes_verified, 72_000 * 6);
    }
}
