//! The soak's scale plane: a deterministic event-driven simulation of a
//! 10k-node (or 2k-node smoke) cluster driven through a correlated
//! failure trace, with the REAL decision tree and the REAL Gamma-posterior
//! cadence schedulers in the loop.
//!
//! **What is real.** The topology is a full-size
//! [`Topology::build`] (SG structure included), every incident runs the
//! shipping [`decide`] tree against a full per-node status vector, and the
//! cadences are live [`SnapshotScheduler`] / [`IntervalScheduler`]
//! instances fed the trace on the sim clock — the same code paths the
//! trainers drive, at a node count the trainers cannot reach in a test.
//!
//! **What is modeled.** The data plane collapses to per-path costs
//! (`restore_smp` / `restore_raim5` / `restore_durable` seconds) and
//! re-done work to the elapsed-time-since-last-save remainder against the
//! live cadence; the witness plane (`super::witness`) covers byte-level
//! correctness on the real fabric instead.
//!
//! **Durable cadence under correlated failures.** Eq. 11 prices the
//! durable tier against the *independence-assumption* exceedance rate
//! (Eq. 7, quadratic in λ_node) — at 10k nodes and realistic rates that
//! stretches the persist interval past any horizon, which is the paper's
//! headline effect. A rack burst breaks the assumption: it exceeds RAIM5
//! with probability 1, not λ². The scale plane therefore runs BOTH
//! trackers: the per-node Eq. 11 scheduler (reported, demonstrating the
//! stretch) and a cluster-level exceedance tracker (`sg_size = 1`, plain
//! Eq. 5 Young form) fed one event per durable-tier incident, whose
//! Gamma posterior learns the *observed* protection-exceeded rate. The
//! effective cadence is the shorter of the two, so correlated bursts pull
//! the durable tier back in while the no-burst path keeps the paper's
//! stretched interval.
//!
//! The epoch-reset hook ([`LambdaTracker::reset_epoch`]) is deliberately
//! NOT exercised here: the scale plane estimates the *population* failure
//! rate of a fixed fleet (replacing one failed node does not change the
//! fleet's rate), and resetting per incident would pin the posterior at
//! the prior forever. The trainers' restore path and the scheduler unit
//! tests own that hook.
//!
//! [`Topology::build`]: crate::topology::Topology::build
//! [`decide`]: crate::elastic::decide
//! [`LambdaTracker::reset_epoch`]: crate::persist::LambdaTracker::reset_epoch

use anyhow::{ensure, Result};

use crate::elastic::{decide, DurableAvailability, NodeStatus, RecoveryDecision};
use crate::hwsim::correlated::{CorrelatedSpec, FailureClass};
use crate::hwsim::failure::{FailureKind, FailureModel};
use crate::hwsim::seed;
use crate::persist::{IntervalScheduler, SnapshotScheduler};
use crate::topology::{ParallelPlan, Topology};

/// One soak configuration: cluster shape, failure processes, cost model,
/// gates. All rates are per sim-second; `shape_c` stays 1.0 in the stock
/// configs so the Weibull scale *is* a rate (the shape sweep lives in the
/// sampler proptests).
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// config name, embedded in the report
    pub name: &'static str,
    /// master seed — every stochastic stream forks from this
    pub seed: u64,
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// DP degree = sharding-group width (TP fills each node, PP spans the
    /// rest: `pp = nodes / dp`)
    pub dp: usize,
    /// sim horizon, seconds
    pub horizon: f64,
    /// one training iteration, seconds
    pub t_step: f64,
    /// independent Weibull rates (Assumption 1 base process)
    pub lambda_hw: f64,
    pub lambda_sw: f64,
    pub shape_c: f64,
    /// correlated modes layered on top
    pub correlated: CorrelatedSpec,
    /// operator's per-node λ guess (the Gamma prior mean)
    pub knob_lambda: f64,
    /// operator's cluster-level protection-exceedance guess (the burst
    /// tracker's prior mean)
    pub knob_burst: f64,
    /// in-memory snapshot cost (< t_step in the stock configs: the paper's
    /// fully-overlapped regime)
    pub t_snapshot: f64,
    /// durable save job duration
    pub t_persist: f64,
    /// static snapshot cadence (steps) until the first observed failure
    pub snapshot_every_steps: u64,
    /// static persist fallback cadence (steps)
    pub persist_fallback_steps: u64,
    /// recovery latencies per path, seconds
    pub restore_smp: f64,
    pub restore_raim5: f64,
    pub restore_durable: f64,
    /// asserted goodput floor at these reference rates
    pub goodput_floor: f64,
}

impl SoakConfig {
    /// The full-scale run: 10 000 nodes x 4 GPUs, SG width 8 (1250 stages),
    /// six sim-hours. Rates give ~130 independent events, a handful of
    /// rack bursts / flap episodes / brownouts — enough pressure that the
    /// burst tracker visibly re-tightens the durable cadence.
    pub fn paper_10k(master_seed: u64) -> SoakConfig {
        SoakConfig {
            name: "paper_10k",
            seed: master_seed,
            nodes: 10_000,
            gpus_per_node: 4,
            dp: 8,
            horizon: 21_600.0,
            t_step: 1.0,
            lambda_hw: 2e-7,
            lambda_sw: 4e-7,
            shape_c: 1.0,
            correlated: CorrelatedSpec {
                rack_burst_rate: 2e-4,
                flap_rate: 1e-4,
                flap_burst: 4,
                flap_spacing: 5.0,
                brownout_rate: 1e-4,
                brownout_duration: 120.0,
            },
            knob_lambda: 1e-6,
            knob_burst: 1e-4,
            t_snapshot: 0.5,
            t_persist: 30.0,
            snapshot_every_steps: 30,
            persist_fallback_steps: 900,
            restore_smp: 5.0,
            restore_raim5: 15.0,
            restore_durable: 90.0,
            goodput_floor: 0.55,
        }
    }

    /// The CI smoke budget: 2 000 nodes, two sim-hours, rates scaled so the
    /// run still sees every failure class. Seconds of wall time.
    pub fn smoke_2k(master_seed: u64) -> SoakConfig {
        SoakConfig {
            name: "smoke_2k",
            nodes: 2_000,
            horizon: 7_200.0,
            correlated: CorrelatedSpec {
                rack_burst_rate: 3e-4,
                flap_rate: 1.5e-4,
                flap_burst: 4,
                flap_spacing: 5.0,
                brownout_rate: 1.5e-4,
                brownout_duration: 120.0,
            },
            // a shorter horizon carries fewer incidents to average over, so
            // the smoke gate gets more headroom than the 10k run
            goodput_floor: 0.45,
            ..SoakConfig::paper_10k(master_seed)
        }
    }

    /// Pipeline depth implied by the shape (`nodes / dp` stages).
    pub fn pp(&self) -> usize {
        self.nodes / self.dp
    }
}

/// Per-failure-class account of the sim-time split.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClassStats {
    /// recovery incidents attributed to the class (a rack burst is ONE
    /// incident spanning many events)
    pub incidents: u64,
    /// raw failure events attributed
    pub events: u64,
    /// sim-seconds spent recovering (restore latency + brownout stalls)
    pub recovery_secs: f64,
    /// sim-seconds spent re-doing lost work
    pub redo_secs: f64,
}

impl ClassStats {
    fn add(&mut self, events: u64, recovery: f64, redo: f64) {
        self.incidents += 1;
        self.events += events;
        self.recovery_secs += recovery;
        self.redo_secs += redo;
    }
}

/// Everything the scale plane measured, plus the gates it asserts.
#[derive(Debug, Clone, Default)]
pub struct ScaleReport {
    pub name: &'static str,
    pub seed: u64,
    pub nodes: usize,
    pub horizon: f64,
    pub goodput_floor: f64,

    pub incidents_total: u64,
    pub events_total: u64,
    /// incidents landing while the cluster was already down (the outage
    /// extends; no fresh redo is charged)
    pub overlap_incidents: u64,

    pub independent: ClassStats,
    pub rack_burst: ClassStats,
    pub flap: ClassStats,

    pub smp_recoveries: u64,
    pub raim5_recoveries: u64,
    pub durable_recoveries: u64,
    pub fatal_decisions: u64,

    pub brownout_windows: u64,
    pub brownout_overlaps: u64,
    pub brownout_stall_secs: f64,

    pub productive_secs: f64,
    pub recovery_secs: f64,
    pub redo_secs: f64,
    pub goodput: f64,

    pub lambda_knob: f64,
    /// final Gamma-posterior mean of the per-node tracker
    pub lambda_posterior: f64,
    /// pure exposure MLE `k / (horizon * nodes)` over the same window
    pub lambda_mle: f64,
    /// (t, posterior) sampled after each incident — the convergence curve
    pub lambda_curve: Vec<(f64, f64)>,
    /// (t, cumulative goodput at t) sampled before each incident — the
    /// fig. 8-style survival/goodput curve
    pub goodput_curve: Vec<(f64, f64)>,

    pub snapshot_steps_final: u64,
    /// per-node Eq. 11 interval (the paper's stretched cadence)
    pub persist_steps_eq11: u64,
    /// effective interval after the cluster-level burst tracker
    pub persist_steps_effective: u64,
}

impl ScaleReport {
    /// The soak gates. Every bound is against the *fixed-seed* run, so a
    /// failure is a behavior change, not flake.
    pub fn check_invariants(&self) -> Result<()> {
        ensure!(
            self.fatal_decisions == 0,
            "{}: {} incidents reached the Fatal leaf — an injected schedule \
             produced unrecoverable state",
            self.name,
            self.fatal_decisions
        );
        ensure!(
            self.goodput >= self.goodput_floor,
            "{}: goodput {:.4} under the {:.2} floor at reference rates",
            self.name,
            self.goodput,
            self.goodput_floor
        );
        ensure!(
            self.events_total > 0,
            "{}: a soak with zero injected events proves nothing",
            self.name
        );
        // the Gamma posterior must have converged toward the observed rate
        // (enough evidence to dominate the knob prior) — the at-scale
        // counterpart of the scheduler unit tests
        if self.events_total >= 10 {
            let ratio = self.lambda_posterior / self.lambda_mle;
            ensure!(
                (ratio - 1.0).abs() <= 0.15,
                "{}: posterior {:.3e} strayed from the exposure MLE {:.3e} \
                 (ratio {ratio:.3}) despite {} events",
                self.name,
                self.lambda_posterior,
                self.lambda_mle,
                self.events_total
            );
        }
        ensure!(
            !self.lambda_curve.is_empty() && !self.goodput_curve.is_empty(),
            "{}: empty trajectory curves",
            self.name
        );
        Ok(())
    }
}

fn class_rank(c: FailureClass) -> u8 {
    match c {
        FailureClass::RackBurst => 2,
        FailureClass::Flap => 1,
        FailureClass::Independent => 0,
    }
}

/// Run the scale plane for one configuration. Deterministic in
/// `cfg.seed`; single-threaded; ~a second of wall time at 10k nodes.
pub fn run_scale(cfg: &SoakConfig) -> Result<ScaleReport> {
    ensure!(cfg.dp >= 2, "SG width must be >= 2 for RAIM5 to exist");
    ensure!(cfg.nodes % cfg.dp == 0, "nodes must tile into SGs of width dp");
    ensure!(cfg.t_step > 0.0 && cfg.horizon > 0.0);

    let plan = ParallelPlan::new(cfg.dp, cfg.gpus_per_node, cfg.pp());
    let topo = Topology::build(plan, cfg.nodes, cfg.gpus_per_node)?;
    let racks: Vec<Vec<usize>> =
        topo.sharding_groups().into_iter().map(|sg| sg.nodes).collect();

    let model = FailureModel::new(cfg.lambda_hw, cfg.lambda_sw, cfg.shape_c);
    let mut rng = seed::stream(cfg.seed, seed::CORRELATED);
    let trace = cfg.correlated.trace(&model, &mut rng, &racks, cfg.horizon);
    let flat = trace.schedule();

    // the live cadence control plane, on the sim clock
    let mut snap_sched =
        SnapshotScheduler::new(cfg.knob_lambda, cfg.nodes, cfg.snapshot_every_steps);
    let mut persist_sched = IntervalScheduler::new(
        cfg.knob_lambda,
        cfg.dp,
        cfg.nodes,
        cfg.persist_fallback_steps,
    );
    let mut burst_sched =
        IntervalScheduler::new(cfg.knob_burst, 1, 1, cfg.persist_fallback_steps);

    // loop-carried cadences: the interval in force when a failure lands is
    // the one derived BEFORE it (feeding first would let an incident
    // retroactively shrink its own redo)
    let mut snap_secs = cfg.snapshot_every_steps.max(1) as f64 * cfg.t_step;
    let mut persist_secs = cfg.persist_fallback_steps.max(1) as f64 * cfg.t_step;

    // an initial durable checkpoint exists at t = 0 (every trainer run
    // commits one before real steps), so the durable tier is never empty
    let avail = DurableAvailability {
        manifest: true,
        legacy: false,
        manifest_step: Some(0),
        legacy_step: None,
    };

    let mut status = vec![NodeStatus::Unhealthy; cfg.nodes];
    let mut r = ScaleReport {
        name: cfg.name,
        seed: cfg.seed,
        nodes: cfg.nodes,
        horizon: cfg.horizon,
        goodput_floor: cfg.goodput_floor,
        incidents_total: 0,
        events_total: 0,
        overlap_incidents: 0,
        independent: ClassStats::default(),
        rack_burst: ClassStats::default(),
        flap: ClassStats::default(),
        smp_recoveries: 0,
        raim5_recoveries: 0,
        durable_recoveries: 0,
        fatal_decisions: 0,
        brownout_windows: trace.brownouts.len() as u64,
        brownout_overlaps: 0,
        brownout_stall_secs: 0.0,
        productive_secs: 0.0,
        recovery_secs: 0.0,
        redo_secs: 0.0,
        goodput: 0.0,
        lambda_knob: cfg.knob_lambda,
        lambda_posterior: 0.0,
        lambda_mle: 0.0,
        lambda_curve: Vec::new(),
        goodput_curve: Vec::new(),
        snapshot_steps_final: cfg.snapshot_every_steps,
        persist_steps_eq11: cfg.persist_fallback_steps,
        persist_steps_effective: cfg.persist_fallback_steps,
    };

    // when the cluster last became fully caught up; the time before an
    // incident and past this point is productive training
    let mut t_ready = 0.0f64;
    // right edge of the trace window already fed to the λ trackers
    let mut fed_upto = 0.0f64;

    let events = &trace.events;
    let mut i = 0usize;
    while i < events.len() {
        let at = events[i].event.at;
        let mut j = i;
        while j < events.len() && events[j].event.at == at {
            j += 1;
        }
        let batch = &events[i..j];
        i = j;

        // classify the incident (the strongest class wins the attribution)
        // and mark the hardware losses OFFLINE
        let mut class = FailureClass::Independent;
        for e in batch {
            if class_rank(e.class) > class_rank(class) {
                class = e.class;
            }
            if e.event.kind == FailureKind::Hardware {
                status[e.event.node] = NodeStatus::Offline;
            }
        }

        let decision = decide(&topo, &status, true, avail);
        for e in batch {
            status[e.event.node] = NodeStatus::Unhealthy;
        }

        let overlap = at < t_ready;
        if overlap {
            r.overlap_incidents += 1;
        } else {
            r.goodput_curve.push((
                at,
                (r.productive_secs + (at - t_ready)) / at.max(cfg.t_step),
            ));
        }

        // recovery latency + which save the redo re-runs from
        let (restore, redo_cadence) = match &decision {
            RecoveryDecision::None | RecoveryDecision::ResumeFromSmp => {
                r.smp_recoveries += 1;
                (cfg.restore_smp, snap_secs)
            }
            RecoveryDecision::DecodeRaim5 { .. } => {
                r.raim5_recoveries += 1;
                (cfg.restore_raim5, snap_secs)
            }
            RecoveryDecision::LoadCheckpoint { .. } => {
                r.durable_recoveries += 1;
                (cfg.restore_durable, persist_secs)
            }
            RecoveryDecision::Fatal => {
                r.fatal_decisions += 1;
                (cfg.restore_durable, persist_secs)
            }
        };
        // a durable load during a storage brownout waits the window out
        let mut stall = 0.0;
        if matches!(decision, RecoveryDecision::LoadCheckpoint { .. }) {
            if let Some(b) = trace.brownout_at(at) {
                stall = (b.end() - at).max(0.0);
                r.brownout_overlaps += 1;
                r.brownout_stall_secs += stall;
            }
        }
        // work since the relevant save is lost and re-done; an overlapping
        // incident extends the outage but the saved state is unchanged
        let redo = if overlap { 0.0 } else { (at - t_ready) % redo_cadence };
        let recovery = restore + stall;

        if !overlap {
            r.productive_secs += at - t_ready;
        }
        r.recovery_secs += recovery;
        r.redo_secs += redo;
        t_ready = t_ready.max(at) + recovery + redo;

        let cs = match class {
            FailureClass::Independent => &mut r.independent,
            FailureClass::RackBurst => &mut r.rack_burst,
            FailureClass::Flap => &mut r.flap,
        };
        cs.add(batch.len() as u64, recovery, redo);
        r.incidents_total += 1;
        r.events_total += batch.len() as u64;

        // NOW feed the λ trackers (events through this batch, inclusive)
        // and re-derive the cadences for the next stretch
        snap_sched.ingest_failure_schedule(&flat, fed_upto, at);
        persist_sched.ingest_failure_schedule(&flat, fed_upto, at);
        if matches!(decision, RecoveryDecision::LoadCheckpoint { .. }) {
            burst_sched.note_failure_event(at);
        } else {
            burst_sched.advance(at);
        }
        fed_upto = at;

        let snap_steps = snap_sched.observe(cfg.t_snapshot, cfg.t_step);
        let eq11_steps = persist_sched.observe(cfg.t_persist, cfg.t_step);
        let burst_steps = burst_sched.observe(cfg.t_persist, cfg.t_step);
        snap_secs = snap_steps as f64 * cfg.t_step;
        persist_secs = eq11_steps.min(burst_steps) as f64 * cfg.t_step;
        r.snapshot_steps_final = snap_steps;
        r.persist_steps_eq11 = eq11_steps;
        r.persist_steps_effective = eq11_steps.min(burst_steps);

        r.lambda_curve.push((at, snap_sched.lambda_node()));
    }

    // trailing quiet stretch: exposure for the posterior, training for the
    // goodput account
    snap_sched.ingest_failure_schedule(&flat, fed_upto, cfg.horizon);
    persist_sched.ingest_failure_schedule(&flat, fed_upto, cfg.horizon);
    burst_sched.advance(cfg.horizon);
    r.productive_secs += (cfg.horizon - t_ready).max(0.0);

    r.goodput = r.productive_secs / cfg.horizon;
    r.lambda_posterior = snap_sched.lambda_node();
    r.lambda_mle = r.events_total as f64 / (cfg.horizon * cfg.nodes as f64);

    // feeding completeness: every drawn event reached the trackers once
    ensure!(
        snap_sched.empirical_events() as u64 == r.events_total
            && persist_sched.empirical_events() as u64 == r.events_total,
        "{}: tracker saw {} events, trace drew {}",
        cfg.name,
        snap_sched.empirical_events(),
        r.events_total
    );
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny fixed-seed run (200 nodes, 1 sim-hour at 10x rates): the
    /// full loop in milliseconds, asserting the same invariants the CI
    /// smoke gate does plus class coverage.
    #[test]
    fn tiny_scale_run_holds_all_invariants() {
        let mut cfg = SoakConfig::smoke_2k(7);
        cfg.name = "tiny_200";
        cfg.nodes = 200;
        cfg.horizon = 3_600.0;
        cfg.lambda_hw = 2e-6;
        cfg.lambda_sw = 4e-6;
        cfg.correlated.rack_burst_rate = 1e-3;
        cfg.correlated.flap_rate = 5e-4;
        cfg.correlated.brownout_rate = 5e-4;
        // 7.2e5 node-seconds of exposure: the knob must not out-weigh it
        // (beta_0 = 1/knob = 2.5e4 node-seconds << E), or the posterior
        // cannot clear the convergence gate at this scale
        cfg.knob_lambda = 4e-5;
        // 10x rates on a 10x smaller cluster: more of the horizon burns in
        // recovery than either stock config tolerates
        cfg.goodput_floor = 0.30;
        let r = run_scale(&cfg).unwrap();
        r.check_invariants().unwrap();
        // 200 nodes * 3600 s * 6e-6 ~ 4.3 independent events, ~3.6 bursts,
        // ~1.7 flap episodes: every class must appear under this seed
        assert!(r.independent.incidents > 0, "{r:?}");
        assert!(r.rack_burst.incidents > 0, "{r:?}");
        assert!(r.flap.incidents > 0, "{r:?}");
        // a whole-SG burst always exceeds protection: the durable tier must
        // serve at least once per burst, never the in-memory fabric alone
        assert!(r.durable_recoveries >= r.rack_burst.incidents, "{r:?}");
        assert_eq!(r.fatal_decisions, 0);
        // the split accounts the whole horizon (productive + lost <= horizon;
        // equality would need t_ready == horizon exactly)
        assert!(r.productive_secs <= r.horizon);
        assert!(r.goodput > 0.0 && r.goodput <= 1.0);
    }

    #[test]
    fn same_seed_same_report_different_seed_different_trace() {
        let mut cfg = SoakConfig::smoke_2k(21);
        cfg.nodes = 200;
        cfg.horizon = 1_800.0;
        cfg.lambda_hw = 2e-6;
        cfg.lambda_sw = 4e-6;
        let a = run_scale(&cfg).unwrap();
        let b = run_scale(&cfg).unwrap();
        assert_eq!(a.events_total, b.events_total);
        assert_eq!(a.goodput, b.goodput);
        assert_eq!(a.lambda_posterior, b.lambda_posterior);
        let mut cfg2 = cfg.clone();
        cfg2.seed = 22;
        let c = run_scale(&cfg2).unwrap();
        assert!(
            c.events_total != a.events_total || c.goodput != a.goodput,
            "a different master seed must change the run"
        );
    }

    #[test]
    fn burst_tracker_pulls_durable_cadence_back_in() {
        // bursts only: Eq. 11 alone would stretch the persist interval to
        // the clamp; the cluster-level tracker must shorten it
        let mut cfg = SoakConfig::smoke_2k(5);
        cfg.nodes = 200;
        cfg.horizon = 7_200.0;
        cfg.lambda_hw = 0.0;
        cfg.lambda_sw = 0.0;
        cfg.correlated.rack_burst_rate = 2e-3; // ~14 bursts
        cfg.correlated.flap_rate = 0.0;
        cfg.correlated.brownout_rate = 0.0;
        let r = run_scale(&cfg).unwrap();
        assert!(r.rack_burst.incidents >= 5, "{r:?}");
        assert_eq!(r.durable_recoveries + r.smp_recoveries + r.raim5_recoveries, r.incidents_total);
        assert!(
            r.persist_steps_effective < r.persist_steps_eq11,
            "observed exceedance must tighten the durable cadence: {} vs {}",
            r.persist_steps_effective,
            r.persist_steps_eq11
        );
        // Eq. 11's independence-assumption interval stays stretched (~112
        // events over 1.44e6 node-s -> lambda ~ 4.6e-5, exceedance ~ 6e-8,
        // interval ~ 3e4 steps) while the burst tracker lands near a few
        // hundred steps — an order of magnitude apart
        assert!(r.persist_steps_eq11 >= 10_000, "{}", r.persist_steps_eq11);
        assert!(r.persist_steps_effective <= 1_000, "{}", r.persist_steps_effective);
    }
}
