//! `BENCH_soak.json`: the soak's machine-readable artifact — one record
//! per scale-plane run (goodput/survival account, per-class split, λ
//! convergence and cadence picks) plus the witness plane's byte-level
//! evidence. The embedded seeds make every recorded schedule replayable
//! bit for bit.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::JsonWriter;

use super::{ClassStats, ScaleReport, WitnessReport};

fn class(w: &mut JsonWriter, name: &str, c: &ClassStats) {
    w.key(name);
    w.begin_obj();
    w.key("incidents");
    w.u64(c.incidents);
    w.key("events");
    w.u64(c.events);
    w.key("recovery_secs");
    w.num(c.recovery_secs);
    w.key("redo_secs");
    w.num(c.redo_secs);
    w.end_obj();
}

fn curve(w: &mut JsonWriter, name: &str, points: &[(f64, f64)]) {
    w.key(name);
    w.begin_arr();
    for &(t, v) in points {
        w.begin_arr();
        w.num(t);
        w.num(v);
        w.end_arr();
    }
    w.end_arr();
}

fn scale_run(w: &mut JsonWriter, r: &ScaleReport) {
    w.begin_obj();
    w.key("name");
    w.str(r.name);
    w.key("seed");
    w.u64(r.seed);
    w.key("nodes");
    w.usize(r.nodes);
    w.key("horizon_secs");
    w.num(r.horizon);

    w.key("goodput");
    w.num(r.goodput);
    w.key("goodput_floor");
    w.num(r.goodput_floor);
    w.key("productive_secs");
    w.num(r.productive_secs);
    w.key("recovery_secs");
    w.num(r.recovery_secs);
    w.key("redo_secs");
    w.num(r.redo_secs);

    w.key("incidents");
    w.u64(r.incidents_total);
    w.key("events");
    w.u64(r.events_total);
    w.key("overlap_incidents");
    w.u64(r.overlap_incidents);

    w.key("recoveries");
    w.begin_obj();
    w.key("smp");
    w.u64(r.smp_recoveries);
    w.key("raim5");
    w.u64(r.raim5_recoveries);
    w.key("durable");
    w.u64(r.durable_recoveries);
    w.end_obj();
    w.key("fatal_decisions");
    w.u64(r.fatal_decisions);

    w.key("brownouts");
    w.begin_obj();
    w.key("windows");
    w.u64(r.brownout_windows);
    w.key("overlapped");
    w.u64(r.brownout_overlaps);
    w.key("stall_secs");
    w.num(r.brownout_stall_secs);
    w.end_obj();

    w.key("classes");
    w.begin_obj();
    class(w, "independent", &r.independent);
    class(w, "rack_burst", &r.rack_burst);
    class(w, "flap", &r.flap);
    w.end_obj();

    w.key("lambda");
    w.begin_obj();
    w.key("knob");
    w.num(r.lambda_knob);
    w.key("posterior");
    w.num(r.lambda_posterior);
    w.key("mle");
    w.num(r.lambda_mle);
    w.key("events");
    w.u64(r.events_total);
    w.end_obj();

    w.key("cadence");
    w.begin_obj();
    w.key("snapshot_steps_final");
    w.u64(r.snapshot_steps_final);
    w.key("persist_steps_eq11");
    w.u64(r.persist_steps_eq11);
    w.key("persist_steps_effective");
    w.u64(r.persist_steps_effective);
    w.end_obj();

    curve(w, "goodput_curve", &r.goodput_curve);
    curve(w, "lambda_curve", &r.lambda_curve);
    w.end_obj();
}

/// Serialize the full soak artifact. Key order is fixed, so identical runs
/// produce byte-identical documents (diffable across CI uploads).
pub fn write_bench_json(runs: &[ScaleReport], witness: &WitnessReport) -> Vec<u8> {
    let mut w = JsonWriter::with_capacity(16 * 1024);
    w.begin_obj();
    w.key("bench");
    w.str("soak");
    w.key("runs");
    w.begin_arr();
    for r in runs {
        scale_run(&mut w, r);
    }
    w.end_arr();

    w.key("witness");
    w.begin_obj();
    w.key("seed");
    w.u64(witness.seed);
    w.key("incidents");
    w.u64(witness.incidents);
    w.key("restores");
    w.begin_obj();
    w.key("smp");
    w.u64(witness.smp_restores);
    w.key("raim5");
    w.u64(witness.raim5_restores);
    w.key("durable");
    w.u64(witness.durable_restores);
    w.end_obj();
    w.key("brownout_refusals");
    w.u64(witness.brownout_refusals);
    w.key("bytes_verified");
    w.u64(witness.bytes_verified);
    w.key("leaked_keys");
    w.usize(witness.leaked_keys);
    w.key("gc_second_pass_deletes");
    w.usize(witness.gc_second_pass_deletes);
    w.end_obj();

    w.end_obj();
    w.raw(b"\n");
    w.finish()
}

/// Write the artifact where the harness was asked to (`BENCH_soak.json`
/// next to the manifest by convention; CI uploads it).
pub fn write_bench_file(
    path: &Path,
    runs: &[ScaleReport],
    witness: &WitnessReport,
) -> Result<()> {
    std::fs::write(path, write_bench_json(runs, witness))
        .with_context(|| format!("writing soak benchmark to {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_json_is_stable_and_parseable() {
        let run = ScaleReport {
            name: "unit",
            seed: 3,
            goodput_curve: vec![(1.0, 0.5), (2.0, 0.75)],
            lambda_curve: vec![(1.0, 1e-6)],
            ..ScaleReport::default()
        };
        let wit = WitnessReport { seed: 7, incidents: 4, ..WitnessReport::default() };

        let a = write_bench_json(&[run.clone()], &wit);
        let b = write_bench_json(&[run], &wit);
        assert_eq!(a, b, "same inputs must serialize byte-identically");

        let text = String::from_utf8(a).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(doc.req_str("bench").unwrap(), "soak");
        assert_eq!(doc.req_arr("runs").unwrap().len(), 1);
        assert_eq!(
            doc.get("witness").unwrap().req_u64("seed").unwrap(),
            7
        );
    }
}
