//! 10k-node failure-trace soak harness: correlated fault injection over
//! the full REFT control plane, with a goodput/survival account per
//! failure class (paper fig. 8 style) and asserted invariants.
//!
//! Two planes, one trace format ([`CorrelatedTrace`](crate::hwsim::CorrelatedTrace)):
//!
//! * **Scale plane** ([`sim`]) — a deterministic event-driven simulation of
//!   10k+ nodes: a *real* [`Topology`](crate::topology::Topology) at full
//!   size, the *real* [`decide`](crate::elastic::decide) recovery tree per
//!   incident, and the *real* Gamma-posterior cadence schedulers
//!   ([`SnapshotScheduler`](crate::persist::SnapshotScheduler) Eq. 9,
//!   [`IntervalScheduler`](crate::persist::IntervalScheduler) Eq. 11)
//!   advanced on the sim clock. Only the data plane is abstracted into
//!   per-path recovery/redo costs — everything above it is the shipping
//!   control plane, which is the point: the soak proves the *decisions*
//!   and the *cadence math* survive correlated 10k-node schedules, and
//!   records the sim-time split (training vs re-doing vs recovering, per
//!   failure class).
//! * **Witness plane** ([`witness`]) — a bounded run of the REAL fabric
//!   (ReftCluster + SMP/RAIM5 + PersistEngine + retention GC on real
//!   storage) replaying the same incident shapes: software kill, single
//!   hardware loss, correlated whole-SG rack loss, and a storage brownout
//!   overlapping a durable recovery. Asserts bit-exact restores on every
//!   path and zero leaked storage keys after the final GC.
//!
//! Determinism: every run derives all its randomness from ONE master seed
//! via [`seed::stream`](crate::hwsim::seed::stream); the seed is embedded
//! in `BENCH_soak.json` ([`report`]) so any recorded schedule replays
//! bit-for-bit.

pub mod report;
pub mod sim;
pub mod witness;

pub use report::{write_bench_file, write_bench_json};
pub use sim::{run_scale, ClassStats, ScaleReport, SoakConfig};
pub use witness::{run_witness, WitnessReport};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::checkpoint::Storage;

/// Storage decorator modeling a transient backend brownout (object store
/// or PFS unavailable): while dark, every operation — data *and* metadata
/// plane — fails or reports absence, exactly what a recovery probing the
/// durable tier mid-outage sees. The witness plane toggles this around a
/// protection-exceeding incident to prove the control plane waits the
/// window out instead of declaring the state unrecoverable.
pub struct BrownoutStorage {
    inner: Arc<dyn Storage>,
    dark: AtomicBool,
    /// operations refused while dark (telemetry for the report)
    refusals: AtomicU64,
}

impl BrownoutStorage {
    pub fn wrap(inner: Arc<dyn Storage>) -> BrownoutStorage {
        BrownoutStorage { inner, dark: AtomicBool::new(false), refusals: AtomicU64::new(0) }
    }

    /// Enter (`true`) or leave (`false`) the brownout window.
    pub fn set_dark(&self, dark: bool) {
        self.dark.store(dark, Ordering::SeqCst);
    }

    pub fn is_dark(&self) -> bool {
        self.dark.load(Ordering::SeqCst)
    }

    pub fn refusals(&self) -> u64 {
        self.refusals.load(Ordering::SeqCst)
    }

    fn refuse(&self, key: &str) -> Result<()> {
        if self.is_dark() {
            self.refusals.fetch_add(1, Ordering::SeqCst);
            anyhow::bail!("storage brownout: `{key}` unreachable");
        }
        Ok(())
    }
}

impl Storage for BrownoutStorage {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        self.refuse(key)?;
        self.inner.put(key, bytes)
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        self.refuse(key)?;
        self.inner.get(key)
    }

    fn exists(&self, key: &str) -> bool {
        if self.is_dark() {
            self.refusals.fetch_add(1, Ordering::SeqCst);
            return false;
        }
        self.inner.exists(key)
    }

    fn list(&self) -> Vec<String> {
        if self.is_dark() {
            self.refusals.fetch_add(1, Ordering::SeqCst);
            return Vec::new();
        }
        self.inner.list()
    }

    fn delete(&self, key: &str) -> Result<()> {
        self.refuse(key)?;
        self.inner.delete(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::MemStorage;

    #[test]
    fn brownout_gates_every_plane() {
        let s = BrownoutStorage::wrap(Arc::new(MemStorage::new()));
        s.put("m/a", b"x").unwrap();
        assert!(s.exists("m/a"));
        assert_eq!(s.get("m/a").unwrap(), b"x");

        s.set_dark(true);
        assert!(s.get("m/a").is_err());
        assert!(s.put("m/b", b"y").is_err());
        assert!(!s.exists("m/a"), "metadata plane must brown out too");
        assert!(s.list().is_empty());
        assert!(s.delete("m/a").is_err());
        assert!(s.refusals() >= 5);

        s.set_dark(false);
        assert_eq!(s.get("m/a").unwrap(), b"x", "the window passes, nothing was lost");
        assert_eq!(s.list(), vec!["m/a".to_string()]);
    }
}
