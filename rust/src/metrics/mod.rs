//! Metrics: counters, wall-clock timers and simulated-time series.
//!
//! Two clocks coexist deliberately (DESIGN.md §Substitutions): *wall time*
//! measures real work this process does (XOR encode, memcpy, PJRT execute) —
//! that is what §Perf optimizes — while *sim time* carries the modeled
//! device-class transfers the benches report in paper shape.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// A monotonically growing set of named counters/gauges/timing stats.
/// Thread-safe; cheap enough for hot-path increments outside the innermost
/// loops.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    timers: BTreeMap<String, TimerStat>,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct TimerStat {
    pub count: u64,
    pub total: f64,
    pub min: f64,
    pub max: f64,
    /// most recent sample — what live controllers (cadence schedulers)
    /// read when they want the current cost rather than the run-long mean
    pub last: f64,
}

impl TimerStat {
    fn record(&mut self, secs: f64) {
        if self.count == 0 {
            self.min = secs;
            self.max = secs;
        } else {
            self.min = self.min.min(secs);
            self.max = self.max.max(secs);
        }
        self.count += 1;
        self.total += secs;
        self.last = secs;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total / self.count as f64
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn gauge(&self, name: &str, value: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), value);
    }

    pub fn record_secs(&self, name: &str, secs: f64) {
        let mut g = self.inner.lock().unwrap();
        g.timers.entry(name.to_string()).or_default().record(secs);
    }

    /// Time a closure under `name` (wall clock).
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record_secs(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    pub fn timer(&self, name: &str) -> TimerStat {
        self.inner
            .lock()
            .unwrap()
            .timers
            .get(name)
            .copied()
            .unwrap_or_default()
    }

    /// Dump everything as JSON (for EXPERIMENTS.md tables and CI diffing).
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters = Json::Obj(
            g.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            g.gauges
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        let timers = Json::Obj(
            g.timers
                .iter()
                .map(|(k, t)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::from(t.count as usize)),
                            ("total_s", Json::from(t.total)),
                            ("mean_s", Json::from(t.mean())),
                            ("min_s", Json::from(t.min)),
                            ("max_s", Json::from(t.max)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("timers", timers),
        ])
    }
}

/// A time series sampled on the simulation clock — used for the Fig. 3-style
/// utilization traces (GPU busy %, CPU busy %, host memory in use).
#[derive(Debug, Default, Clone)]
pub struct Trace {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Trace {
    pub fn new(name: impl Into<String>) -> Self {
        Trace { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, t: f64, v: f64) {
        self.points.push((t, v));
    }

    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|(_, v)| v).sum::<f64>() / self.points.len() as f64
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("t,value\n");
        for (t, v) in &self.points {
            s.push_str(&format!("{t:.6},{v:.6}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("saves", 1);
        m.inc("saves", 2);
        m.gauge("mem", 12.5);
        assert_eq!(m.counter("saves"), 3);
        assert_eq!(m.gauge_value("mem"), Some(12.5));
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timer_stats() {
        let m = Metrics::new();
        m.record_secs("op", 1.0);
        m.record_secs("op", 3.0);
        let t = m.timer("op");
        assert_eq!(t.count, 2);
        assert_eq!(t.mean(), 2.0);
        assert_eq!(t.min, 1.0);
        assert_eq!(t.max, 3.0);
        assert_eq!(t.last, 3.0);
        m.record_secs("op", 2.0);
        assert_eq!(m.timer("op").last, 2.0, "last tracks the newest sample");
    }

    #[test]
    fn time_closure_runs_once() {
        let m = Metrics::new();
        let mut calls = 0;
        let out = m.time("f", || {
            calls += 1;
            42
        });
        assert_eq!((out, calls), (42, 1));
        assert_eq!(m.timer("f").count, 1);
    }

    #[test]
    fn json_dump_contains_everything() {
        let m = Metrics::new();
        m.inc("c", 5);
        m.gauge("g", 1.5);
        m.record_secs("t", 0.25);
        let j = m.to_json();
        assert_eq!(j.at(&["counters", "c"]).as_usize(), Some(5));
        assert_eq!(j.at(&["gauges", "g"]).as_f64(), Some(1.5));
        assert_eq!(j.at(&["timers", "t", "count"]).as_usize(), Some(1));
    }

    #[test]
    fn trace_csv() {
        let mut tr = Trace::new("gpu");
        tr.push(0.0, 0.9);
        tr.push(1.0, 0.7);
        assert!((tr.mean() - 0.8).abs() < 1e-12);
        assert!(tr.to_csv().lines().count() == 3);
    }
}
