//! Metrics: counters, wall-clock timers with log2-bucketed histograms, and
//! simulated-time series.
//!
//! Two clocks coexist deliberately (DESIGN.md §Substitutions): *wall time*
//! measures real work this process does (XOR encode, memcpy, PJRT execute) —
//! that is what §Perf optimizes — while *sim time* carries the modeled
//! device-class transfers the benches report in paper shape.
//!
//! ## Hot path
//!
//! The known metric names (everything the trainers, coordinator, and persist
//! driver touch per iteration) are **pre-interned** into static key tables
//! ([`keys`]). For those, `inc`/`record_secs` route to per-slot atomics —
//! no lock, no allocation — whether the caller uses the string API (one
//! binary search over the static table) or a [`CounterKey`]/[`TimerKey`]
//! handle directly (one array index). Unknown names keep the old
//! mutex-guarded map so dynamic metrics still work; they are just not free.
//!
//! ## Histograms
//!
//! Every timer — fast or dynamic — feeds a log2-bucketed [`Histogram`]
//! (bucket *i* counts samples in `[2^i, 2^{i+1})` nanoseconds), so stall
//! *distributions* (p50/p95/p99) are first-class, not just count/mean/max.
//! The paper's "near-zero overhead" claim is a claim about tails; the
//! `obs_overhead` bench section reads these quantiles.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// Handle to a pre-interned counter slot — see [`keys`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterKey(usize);

/// Handle to a pre-interned timer slot — see [`keys`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimerKey(usize);

/// The static key tables. Arrays are sorted (the string API binary-searches
/// them); each `const` names its slot index. A unit test pins the
/// index↔name agreement and the sort order.
pub mod keys {
    use super::{CounterKey, TimerKey};

    /// Known counter names, sorted.
    pub static KNOWN_COUNTERS: &[&str] = &[
        "checkpoints",
        "failures_hardware",
        "failures_software",
        "persist_aborts",
        "persist_enqueues",
        "persisted_bytes",
        "recoveries_checkpoint",
        "recoveries_inmemory",
        "recoveries_legacy",
        "recoveries_manifest",
        "recovery_mispredictions",
        "recovery_plans",
        "recovery_predicted_fatal",
        "recovery_predicted_inmemory",
        "recovery_predicted_legacy",
        "recovery_predicted_manifest",
        "saves",
        "snapshots",
        "snapshots_aborted",
        "snapshots_completed",
        "snapshots_superseded",
        "steps",
    ];

    pub const CHECKPOINTS: CounterKey = CounterKey(0);
    pub const FAILURES_HARDWARE: CounterKey = CounterKey(1);
    pub const FAILURES_SOFTWARE: CounterKey = CounterKey(2);
    pub const PERSIST_ABORTS: CounterKey = CounterKey(3);
    pub const PERSIST_ENQUEUES: CounterKey = CounterKey(4);
    pub const PERSISTED_BYTES: CounterKey = CounterKey(5);
    pub const RECOVERIES_CHECKPOINT: CounterKey = CounterKey(6);
    pub const RECOVERIES_INMEMORY: CounterKey = CounterKey(7);
    pub const RECOVERIES_LEGACY: CounterKey = CounterKey(8);
    pub const RECOVERIES_MANIFEST: CounterKey = CounterKey(9);
    pub const RECOVERY_MISPREDICTIONS: CounterKey = CounterKey(10);
    pub const RECOVERY_PLANS: CounterKey = CounterKey(11);
    pub const RECOVERY_PREDICTED_FATAL: CounterKey = CounterKey(12);
    pub const RECOVERY_PREDICTED_INMEMORY: CounterKey = CounterKey(13);
    pub const RECOVERY_PREDICTED_LEGACY: CounterKey = CounterKey(14);
    pub const RECOVERY_PREDICTED_MANIFEST: CounterKey = CounterKey(15);
    pub const SAVES: CounterKey = CounterKey(16);
    pub const SNAPSHOTS: CounterKey = CounterKey(17);
    pub const SNAPSHOTS_ABORTED: CounterKey = CounterKey(18);
    pub const SNAPSHOTS_COMPLETED: CounterKey = CounterKey(19);
    pub const SNAPSHOTS_SUPERSEDED: CounterKey = CounterKey(20);
    pub const STEPS: CounterKey = CounterKey(21);

    /// Known timer names, sorted.
    pub static KNOWN_TIMERS: &[&str] = &[
        "adam",
        "ckpt_encode",
        "ckpt_put",
        "fwd_bwd",
        "persist_flush",
        "persist_job",
        "persist_stall",
        "snapshot",
        "snapshot_recovery",
        "snapshot_tick",
        "stage_bwd",
        "stage_fwd",
        "stage_fwdbwd",
        "step_wall",
    ];

    pub const ADAM: TimerKey = TimerKey(0);
    pub const CKPT_ENCODE: TimerKey = TimerKey(1);
    pub const CKPT_PUT: TimerKey = TimerKey(2);
    pub const FWD_BWD: TimerKey = TimerKey(3);
    pub const PERSIST_FLUSH: TimerKey = TimerKey(4);
    pub const PERSIST_JOB: TimerKey = TimerKey(5);
    pub const PERSIST_STALL: TimerKey = TimerKey(6);
    pub const SNAPSHOT: TimerKey = TimerKey(7);
    pub const SNAPSHOT_RECOVERY: TimerKey = TimerKey(8);
    pub const SNAPSHOT_TICK: TimerKey = TimerKey(9);
    pub const STAGE_BWD: TimerKey = TimerKey(10);
    pub const STAGE_FWD: TimerKey = TimerKey(11);
    pub const STAGE_FWDBWD: TimerKey = TimerKey(12);
    pub const STEP_WALL: TimerKey = TimerKey(13);

    pub(super) fn counter_index(name: &str) -> Option<usize> {
        KNOWN_COUNTERS.binary_search(&name).ok()
    }

    pub(super) fn timer_index(name: &str) -> Option<usize> {
        KNOWN_TIMERS.binary_search(&name).ok()
    }
}

/// Number of log2 buckets: bucket `i` counts samples in `[2^i, 2^{i+1})`
/// nanoseconds (bucket 0 also absorbs 0 ns), which spans 1 ns to ~584
/// years — every wall-clock duration this system can see.
pub const HIST_BUCKETS: usize = 64;

/// A log2-bucketed latency histogram (nanosecond samples). Plain data —
/// what [`Metrics::histogram`] snapshots out of the live atomics.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub count: u64,
    pub total_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: 0,
            total_ns: 0,
            min_ns: 0,
            max_ns: 0,
            buckets: [0; HIST_BUCKETS],
        }
    }
}

/// Which bucket a sample lands in: `floor(log2(ns))`, with 0 ns joining
/// bucket 0.
pub fn bucket_of(ns: u64) -> usize {
    ns.max(1).ilog2() as usize
}

/// The `[lo, hi)` nanosecond range bucket `i` covers (bucket 0 starts at 0).
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    debug_assert!(i < HIST_BUCKETS);
    let lo = if i == 0 { 0 } else { 1u64 << i };
    let hi = if i + 1 >= 64 { u64::MAX } else { 1u64 << (i + 1) };
    (lo, hi)
}

impl Histogram {
    pub fn record_ns(&mut self, ns: u64) {
        if self.count == 0 {
            self.min_ns = ns;
            self.max_ns = ns;
        } else {
            self.min_ns = self.min_ns.min(ns);
            self.max_ns = self.max_ns.max(ns);
        }
        self.count += 1;
        self.total_ns = self.total_ns.saturating_add(ns);
        self.buckets[bucket_of(ns)] += 1;
    }

    pub fn record_secs(&mut self, secs: f64) {
        self.record_ns(secs_to_ns(secs));
    }

    /// Quantile in **seconds**, `q` in `[0, 1]`. Linear interpolation
    /// within the covering bucket, clamped to the exact observed
    /// `[min, max]`; monotone in `q` by construction. The empty histogram
    /// answers 0.0 for every quantile.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = q * self.count as f64;
        let mut cum = 0.0f64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let c = c as f64;
            if cum + c >= rank {
                let (lo, hi) = bucket_bounds(i);
                let frac = ((rank - cum) / c).clamp(0.0, 1.0);
                let v = lo as f64 + frac * (hi as f64 - lo as f64);
                return v.clamp(self.min_ns as f64, self.max_ns as f64) / 1e9;
            }
            cum += c;
        }
        self.max_ns as f64 / 1e9
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

fn secs_to_ns(secs: f64) -> u64 {
    if secs <= 0.0 {
        return 0;
    }
    let ns = secs * 1e9;
    if ns >= u64::MAX as f64 {
        u64::MAX
    } else {
        ns as u64
    }
}

/// A monotonically growing set of named counters/gauges/timing stats.
/// Thread-safe; known-name updates are lock-free (see module docs).
#[derive(Debug)]
pub struct Metrics {
    fast_counters: Box<[AtomicU64]>,
    fast_timers: Box<[FastTimer]>,
    inner: Mutex<Inner>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            fast_counters: (0..keys::KNOWN_COUNTERS.len())
                .map(|_| AtomicU64::new(0))
                .collect(),
            fast_timers: (0..keys::KNOWN_TIMERS.len()).map(|_| FastTimer::new()).collect(),
            inner: Mutex::new(Inner::default()),
        }
    }
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    timers: BTreeMap<String, DynTimer>,
}

#[derive(Debug, Default)]
struct DynTimer {
    stat: TimerStat,
    hist: Histogram,
}

/// One pre-interned timer slot: five atomics + the bucket array, all
/// updated relaxed. `min_ns` starts at `u64::MAX` so `fetch_min` works
/// without a sentinel branch.
#[derive(Debug)]
struct FastTimer {
    count: AtomicU64,
    total_ns: AtomicU64,
    min_ns: AtomicU64,
    max_ns: AtomicU64,
    last_ns: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl FastTimer {
    fn new() -> FastTimer {
        FastTimer {
            count: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
            max_ns: AtomicU64::new(0),
            last_ns: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn record_ns(&self, ns: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        self.last_ns.store(ns, Ordering::Relaxed);
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }

    fn stat(&self) -> TimerStat {
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return TimerStat::default();
        }
        TimerStat {
            count,
            total: self.total_ns.load(Ordering::Relaxed) as f64 / 1e9,
            min: self.min_ns.load(Ordering::Relaxed) as f64 / 1e9,
            max: self.max_ns.load(Ordering::Relaxed) as f64 / 1e9,
            last: self.last_ns.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }

    fn histogram(&self) -> Histogram {
        let count = self.count.load(Ordering::Relaxed);
        Histogram {
            count,
            total_ns: self.total_ns.load(Ordering::Relaxed),
            min_ns: if count == 0 { 0 } else { self.min_ns.load(Ordering::Relaxed) },
            max_ns: self.max_ns.load(Ordering::Relaxed),
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct TimerStat {
    pub count: u64,
    pub total: f64,
    pub min: f64,
    pub max: f64,
    /// most recent sample — what live controllers (cadence schedulers)
    /// read when they want the current cost rather than the run-long mean
    pub last: f64,
}

impl TimerStat {
    fn record(&mut self, secs: f64) {
        if self.count == 0 {
            self.min = secs;
            self.max = secs;
        } else {
            self.min = self.min.min(secs);
            self.max = self.max.max(secs);
        }
        self.count += 1;
        self.total += secs;
        self.last = secs;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total / self.count as f64
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-interned counter handle for `name`, if it is a known key.
    pub fn counter_key(name: &str) -> Option<CounterKey> {
        keys::counter_index(name).map(CounterKey)
    }

    /// Pre-interned timer handle for `name`, if it is a known key.
    pub fn timer_key(name: &str) -> Option<TimerKey> {
        keys::timer_index(name).map(TimerKey)
    }

    /// Lock-free counter bump via a pre-interned handle.
    #[inline]
    pub fn inc_k(&self, key: CounterKey, by: u64) {
        self.fast_counters[key.0].fetch_add(by, Ordering::Relaxed);
    }

    pub fn inc(&self, name: &str, by: u64) {
        if let Some(i) = keys::counter_index(name) {
            self.fast_counters[i].fetch_add(by, Ordering::Relaxed);
            return;
        }
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn gauge(&self, name: &str, value: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), value);
    }

    /// Lock-free timer sample via a pre-interned handle.
    #[inline]
    pub fn record_secs_k(&self, key: TimerKey, secs: f64) {
        self.fast_timers[key.0].record_ns(secs_to_ns(secs));
    }

    pub fn record_secs(&self, name: &str, secs: f64) {
        if let Some(i) = keys::timer_index(name) {
            self.fast_timers[i].record_ns(secs_to_ns(secs));
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let t = g.timers.entry(name.to_string()).or_default();
        t.stat.record(secs);
        t.hist.record_secs(secs);
    }

    /// Time a closure under `name` (wall clock).
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record_secs(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Time a closure via a pre-interned handle — the hot-path form.
    #[inline]
    pub fn time_k<T>(&self, key: TimerKey, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record_secs_k(key, t0.elapsed().as_secs_f64());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        if let Some(i) = keys::counter_index(name) {
            return self.fast_counters[i].load(Ordering::Relaxed);
        }
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    pub fn timer(&self, name: &str) -> TimerStat {
        if let Some(i) = keys::timer_index(name) {
            return self.fast_timers[i].stat();
        }
        self.inner
            .lock()
            .unwrap()
            .timers
            .get(name)
            .map(|t| t.stat)
            .unwrap_or_default()
    }

    /// Snapshot the latency histogram behind a timer (empty if the name
    /// was never recorded).
    pub fn histogram(&self, name: &str) -> Histogram {
        if let Some(i) = keys::timer_index(name) {
            return self.fast_timers[i].histogram();
        }
        self.inner
            .lock()
            .unwrap()
            .timers
            .get(name)
            .map(|t| t.hist.clone())
            .unwrap_or_default()
    }

    /// Convenience: `histogram(name).quantile(q)`.
    pub fn timer_quantile(&self, name: &str, q: f64) -> f64 {
        self.histogram(name).quantile(q)
    }

    /// Dump everything as JSON (for EXPERIMENTS.md tables and CI diffing).
    /// Timers now carry p50/p95/p99 from their histograms alongside the
    /// classic count/total/mean/min/max.
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let mut counters: BTreeMap<String, Json> = g
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
            .collect();
        for (i, name) in keys::KNOWN_COUNTERS.iter().enumerate() {
            let v = self.fast_counters[i].load(Ordering::Relaxed);
            if v > 0 {
                counters.insert(name.to_string(), Json::Num(v as f64));
            }
        }
        let gauges = Json::Obj(
            g.gauges
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        let timer_json = |stat: &TimerStat, hist: &Histogram| {
            Json::obj(vec![
                ("count", Json::from(stat.count as usize)),
                ("total_s", Json::from(stat.total)),
                ("mean_s", Json::from(stat.mean())),
                ("min_s", Json::from(stat.min)),
                ("max_s", Json::from(stat.max)),
                ("p50_s", Json::from(hist.p50())),
                ("p95_s", Json::from(hist.p95())),
                ("p99_s", Json::from(hist.p99())),
            ])
        };
        let mut timers: BTreeMap<String, Json> = g
            .timers
            .iter()
            .map(|(k, t)| (k.clone(), timer_json(&t.stat, &t.hist)))
            .collect();
        for (i, name) in keys::KNOWN_TIMERS.iter().enumerate() {
            let ft = &self.fast_timers[i];
            if ft.count.load(Ordering::Relaxed) > 0 {
                timers.insert(name.to_string(), timer_json(&ft.stat(), &ft.histogram()));
            }
        }
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", gauges),
            ("timers", Json::Obj(timers)),
        ])
    }
}

/// A time series sampled on the simulation clock — used for the Fig. 3-style
/// utilization traces (GPU busy %, CPU busy %, host memory in use).
#[derive(Debug, Default, Clone)]
pub struct Trace {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Trace {
    pub fn new(name: impl Into<String>) -> Self {
        Trace { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, t: f64, v: f64) {
        self.points.push((t, v));
    }

    pub fn mean(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|(_, v)| v).sum::<f64>() / self.points.len() as f64
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("t,value\n");
        for (t, v) in &self.points {
            s.push_str(&format!("{t:.6},{v:.6}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("saves", 1);
        m.inc("saves", 2);
        m.gauge("mem", 12.5);
        assert_eq!(m.counter("saves"), 3);
        assert_eq!(m.gauge_value("mem"), Some(12.5));
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timer_stats() {
        let m = Metrics::new();
        m.record_secs("op", 1.0);
        m.record_secs("op", 3.0);
        let t = m.timer("op");
        assert_eq!(t.count, 2);
        assert_eq!(t.mean(), 2.0);
        assert_eq!(t.min, 1.0);
        assert_eq!(t.max, 3.0);
        assert_eq!(t.last, 3.0);
        m.record_secs("op", 2.0);
        assert_eq!(m.timer("op").last, 2.0, "last tracks the newest sample");
    }

    #[test]
    fn time_closure_runs_once() {
        let m = Metrics::new();
        let mut calls = 0;
        let out = m.time("f", || {
            calls += 1;
            42
        });
        assert_eq!((out, calls), (42, 1));
        assert_eq!(m.timer("f").count, 1);
    }

    #[test]
    fn json_dump_contains_everything() {
        let m = Metrics::new();
        m.inc("c", 5);
        m.gauge("g", 1.5);
        m.record_secs("t", 0.25);
        let j = m.to_json();
        assert_eq!(j.at(&["counters", "c"]).as_usize(), Some(5));
        assert_eq!(j.at(&["gauges", "g"]).as_f64(), Some(1.5));
        assert_eq!(j.at(&["timers", "t", "count"]).as_usize(), Some(1));
        assert!(j.at(&["timers", "t", "p99_s"]).as_f64().is_some());
    }

    #[test]
    fn key_tables_are_sorted_and_consts_agree() {
        assert!(keys::KNOWN_COUNTERS.windows(2).all(|w| w[0] < w[1]), "counters sorted");
        assert!(keys::KNOWN_TIMERS.windows(2).all(|w| w[0] < w[1]), "timers sorted");
        // spot-check index↔name agreement for the hottest handles
        assert_eq!(keys::KNOWN_TIMERS[keys::SNAPSHOT.0], "snapshot");
        assert_eq!(keys::KNOWN_TIMERS[keys::SNAPSHOT_TICK.0], "snapshot_tick");
        assert_eq!(keys::KNOWN_TIMERS[keys::STEP_WALL.0], "step_wall");
        assert_eq!(keys::KNOWN_TIMERS[keys::PERSIST_STALL.0], "persist_stall");
        assert_eq!(keys::KNOWN_TIMERS[keys::PERSIST_JOB.0], "persist_job");
        assert_eq!(keys::KNOWN_COUNTERS[keys::SNAPSHOTS.0], "snapshots");
        assert_eq!(keys::KNOWN_COUNTERS[keys::STEPS.0], "steps");
        assert_eq!(keys::KNOWN_COUNTERS[keys::RECOVERY_PLANS.0], "recovery_plans");
        // every const resolves through the string lookup to itself
        for (i, name) in keys::KNOWN_COUNTERS.iter().enumerate() {
            assert_eq!(Metrics::counter_key(name), Some(CounterKey(i)));
        }
        for (i, name) in keys::KNOWN_TIMERS.iter().enumerate() {
            assert_eq!(Metrics::timer_key(name), Some(TimerKey(i)));
        }
        assert_eq!(Metrics::counter_key("definitely_dynamic"), None);
    }

    #[test]
    fn string_and_key_apis_share_slots() {
        let m = Metrics::new();
        m.inc("snapshots", 2);
        m.inc_k(keys::SNAPSHOTS, 3);
        assert_eq!(m.counter("snapshots"), 5);
        m.record_secs("snapshot", 0.5);
        m.record_secs_k(keys::SNAPSHOT, 1.5);
        let t = m.timer("snapshot");
        assert_eq!(t.count, 2);
        assert!((t.total - 2.0).abs() < 1e-6);
        assert!((t.min - 0.5).abs() < 1e-6);
        assert!((t.max - 1.5).abs() < 1e-6);
        assert!((t.last - 1.5).abs() < 1e-6);
        let out = m.time_k(keys::SNAPSHOT, || 7);
        assert_eq!(out, 7);
        assert_eq!(m.timer("snapshot").count, 3);
        // known names surface in the JSON dump exactly like dynamic ones
        let j = m.to_json();
        assert_eq!(j.at(&["counters", "snapshots"]).as_usize(), Some(5));
        assert_eq!(j.at(&["timers", "snapshot", "count"]).as_usize(), Some(3));
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // 0 and 1 ns share bucket 0; exact powers of two open their bucket
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), 63);
        for i in 0..HIST_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert!(lo < hi);
            assert_eq!(bucket_of(lo.max(1)), i, "lower bound lands in its bucket");
            assert_eq!(bucket_of(hi - 1), i, "last value before the bound stays");
        }
    }

    #[test]
    fn histogram_quantiles_monotone_and_clamped() {
        let mut h = Histogram::default();
        for ns in [100u64, 200, 300, 1000, 5000, 5000, 100_000] {
            h.record_ns(ns);
        }
        assert_eq!(h.count, 7);
        let mut prev = -1.0;
        for i in 0..=100 {
            let q = i as f64 / 100.0;
            let v = h.quantile(q);
            assert!(v >= prev, "quantile must be monotone in q ({q})");
            prev = v;
        }
        // clamped to the observed range
        assert!(h.quantile(0.0) >= 100.0 / 1e9);
        assert!((h.quantile(1.0) - 100_000.0 / 1e9).abs() < 1e-12);
        // p50 sits in the data's body, not at an extreme
        let p50 = h.quantile(0.5) * 1e9;
        assert!((100.0..=5000.0).contains(&p50), "p50 {p50} ns");
    }

    #[test]
    fn empty_histogram_quantiles_are_defined() {
        let h = Histogram::default();
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0.0);
        }
        assert_eq!(h.p50(), 0.0);
        // a never-recorded timer yields the same defined answer
        let m = Metrics::new();
        assert_eq!(m.timer_quantile("snapshot", 0.99), 0.0);
        assert_eq!(m.timer_quantile("no_such_timer", 0.5), 0.0);
    }

    #[test]
    fn single_sample_histogram_pins_all_quantiles() {
        let mut h = Histogram::default();
        h.record_ns(1_000_000); // 1 ms
        for q in [0.0, 0.5, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!((v - 1e-3).abs() < 1e-12, "q={q} gave {v}");
        }
    }

    #[test]
    fn trace_csv() {
        let mut tr = Trace::new("gpu");
        tr.push(0.0, 0.9);
        tr.push(1.0, 0.7);
        assert!((tr.mean() - 0.8).abs() < 1e-12);
        assert!(tr.to_csv().lines().count() == 3);
    }

    #[test]
    fn trace_csv_format_is_stable() {
        // header + fixed 6-decimal rows — what the plotting scripts parse
        let mut tr = Trace::new("cpu");
        tr.push(0.5, 0.25);
        tr.push(1.25, 3.0);
        let csv = tr.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "t,value");
        assert_eq!(lines[1], "0.500000,0.250000");
        assert_eq!(lines[2], "1.250000,3.000000");
        assert!(csv.ends_with('\n'), "trailing newline kept");
        // empty trace still emits the header
        assert_eq!(Trace::new("empty").to_csv(), "t,value\n");
        assert_eq!(Trace::new("empty").mean(), 0.0);
    }
}
