//! The live REFT cluster: per-node SMP threads + the snapshot/recovery
//! orchestration over them. This is what the trainer and the e2e examples
//! drive — real bytes, real threads, real XOR decode.
//!
//! Two save paths share the SMP protocol:
//! * the **blocking** path ([`ReftCluster::snapshot_all_blocking`]) drains
//!   every bucket inside the call — the CheckFreq-shaped baseline behavior
//!   and the semantics every pre-coordinator test relies on;
//! * the **asynchronous** path ([`ReftCluster::request_snapshot`] +
//!   [`ReftCluster::tick`]) goes through the hierarchical coordinator
//!   (§4.1 L1-L3, `snapshot::coord`): enqueue returns immediately and
//!   buckets drain across iteration ticks under a per-node budget.
//!
//! [`ReftCluster::snapshot_all`] picks the path from
//! `FtConfig::async_snapshot` but always completes the round before
//! returning, so its call sites keep snapshot-visible-on-return semantics.

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::mpsc::{channel, Receiver};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::FtConfig;
use crate::ec::Raim5Group;
use crate::obs;
use crate::smp::{BucketRef, Signal, Smp, SmpMsg};
use crate::snapshot::coord::parity_patches;
use crate::snapshot::payload::{PayloadView, SharedPayload};
use crate::snapshot::plan::NodeShard;
use crate::snapshot::{
    BucketPipe, CoordSink, DeltaPlanner, DeltaStats, SnapshotCoordinator, SnapshotPlan,
    StageShip, TickReport,
};
use crate::topology::Topology;

/// The in-memory fault-tolerance fabric of one training cluster.
pub struct ReftCluster {
    pub topo: Topology,
    pub plan: SnapshotPlan,
    pub ft: FtConfig,
    /// SMP per node; `None` marks a node that was lost and not yet replaced
    smps: Vec<Option<Smp>>,
    /// RAIM5 layout per PP stage (only for SGs with >= 2 nodes)
    groups: BTreeMap<usize, Raim5Group>,
    /// the asynchronous drain state machine (idle unless a snapshot is in
    /// flight); also consulted by the blocking path to cancel stale rounds
    coord: SnapshotCoordinator,
    /// the sparse-snapshot planner (`Some` when `ft.delta_extent_bytes > 0`):
    /// hashes each round into extent tables and decides per stage whether to
    /// ship the full payload or only the extents changed since the last
    /// *committed* round. Reset to force a full re-base whenever SMP contents
    /// may no longer match the committed tables (membership change, torn
    /// blocking round, mid-drain abort).
    delta: Option<DeltaPlanner>,
    /// the snapshot version counter (one per requested snapshot round)
    pub version: u64,
}

/// [`CoordSink`] over the live SMP channels: every coordinator action is one
/// FIFO message to the owning node's SMP.
struct SmpSink<'a> {
    smps: &'a [Option<Smp>],
}

impl SmpSink<'_> {
    fn smp(&self, node: usize) -> Result<&Smp> {
        self.smps
            .get(node)
            .and_then(Option::as_ref)
            .with_context(|| format!("node {node} is offline — cannot snapshot"))
    }
}

impl CoordSink for SmpSink<'_> {
    fn begin(&mut self, node: usize, version: u64, stage: usize, total_len: usize) -> Result<()> {
        self.smp(node)?
            .send(SmpMsg::BeginSnapshot { version, stage, total_len })
    }

    fn bucket(
        &mut self,
        node: usize,
        version: u64,
        stage: usize,
        offset: usize,
        view: PayloadView,
    ) -> Result<()> {
        self.smp(node)?.send(SmpMsg::Bucket {
            version,
            stage,
            offset,
            data: BucketRef::Shared(view),
        })
    }

    fn end(&mut self, node: usize, version: u64, stage: usize) -> Result<()> {
        self.smp(node)?.send(SmpMsg::EndSnapshot { version, stage })
    }

    fn begin_delta(
        &mut self,
        node: usize,
        version: u64,
        stage: usize,
        total_len: usize,
        delta_len: usize,
    ) -> Result<()> {
        self.smp(node)?
            .send(SmpMsg::BeginDeltaSnapshot { version, stage, total_len, delta_len })
    }

    fn store_parity(
        &mut self,
        node: usize,
        version: u64,
        stage: usize,
        data: Vec<u8>,
    ) -> Result<()> {
        self.smp(node)?.send(SmpMsg::StoreParity { version, stage, data })
    }

    fn store_parity_delta(
        &mut self,
        node: usize,
        version: u64,
        stage: usize,
        patches: Vec<(usize, Vec<u8>)>,
    ) -> Result<()> {
        self.smp(node)?.send(SmpMsg::StoreParityDelta { version, stage, patches })
    }

    fn abort(&mut self, node: usize, version: u64, stage: usize) -> Result<()> {
        self.smp(node)?.send(SmpMsg::AbortSnapshot { version, stage })
    }

    fn alive(&mut self, node: usize) -> bool {
        self.smps.get(node).and_then(Option::as_ref).is_some()
    }
}

impl ReftCluster {
    /// Bring up SMPs on every node and signal SNAP.
    pub fn start(topo: Topology, stage_payload_bytes: &[u64], ft: FtConfig) -> Result<Self> {
        let plan = SnapshotPlan::build(&topo, stage_payload_bytes);
        let mut groups = BTreeMap::new();
        if ft.raim5 {
            for stage in 0..topo.plan.pp {
                let lens = plan.sg_shard_lens(stage);
                if lens.len() >= 2 {
                    groups.insert(stage, Raim5Group::plan(&lens)?);
                }
            }
        }
        let smps: Vec<Option<Smp>> = (0..topo.nodes)
            .map(|n| Some(Smp::spawn(n, ft.clean_copies)))
            .collect();
        for smp in smps.iter().flatten() {
            smp.send(SmpMsg::Signal(Signal::Snap))?;
        }
        let coord = SnapshotCoordinator::new(
            plan.clone(),
            groups.clone(),
            ft.bucket_bytes,
            ft.drain_buckets_per_tick,
        );
        let delta = (ft.delta_extent_bytes > 0)
            .then(|| DeltaPlanner::new(ft.delta_extent_bytes, ft.delta_chain_max));
        Ok(ReftCluster { topo, plan, ft, smps, groups, coord, delta, version: 0 })
    }

    pub fn smp(&self, node: usize) -> Option<&Smp> {
        self.smps.get(node).and_then(Option::as_ref)
    }

    /// Per-node SMP inbox handles for the persistence engine's writer
    /// workers (`None` marks a lost node). Captured fresh at every persist
    /// enqueue so elastic replacements are picked up.
    pub fn persist_sources(&self) -> Vec<Option<std::sync::mpsc::Sender<SmpMsg>>> {
        (0..self.topo.nodes)
            .map(|n| self.smps[n].as_ref().map(Smp::sender))
            .collect()
    }

    // -- asynchronous save path (§4.1 hierarchical coordination) -----------

    /// L1 enqueue: open a new snapshot version and return immediately; the
    /// payload buckets drain across subsequent [`Self::tick`]s. A still
    /// in-flight older version is aborted (L3 supersession). Takes the
    /// captured payloads by shared reference — the enqueue moves `Arc`
    /// handles, never payload bytes.
    pub fn request_snapshot(&mut self, payloads: Vec<SharedPayload>) -> Result<u64> {
        self.version += 1;
        let v = self.version;
        let ships = self.delta.as_mut().map(|p| p.plan(v, &payloads));
        let mut sink = SmpSink { smps: &self.smps };
        let submitted = match ships {
            Some(ships) if ships.iter().any(|s| matches!(s, StageShip::Sparse(_))) => {
                self.coord.submit_sparse(v, payloads, ships, &mut sink)
            }
            _ => self.coord.submit(v, payloads, &mut sink),
        };
        if submitted.is_err() {
            // the enqueue never opened; v will never commit — forget its plan
            if let Some(p) = self.delta.as_mut() {
                p.drop_pending();
            }
        }
        submitted.map(|()| v)
    }

    /// L2 drain: move up to `drain_buckets_per_tick` buckets per node.
    /// Called by the trainers at every iteration boundary; a no-op when
    /// nothing is in flight.
    pub fn tick(&mut self) -> Result<TickReport> {
        let mut sink = SmpSink { smps: &self.smps };
        let report = self.coord.tick(&mut sink)?;
        if let Some(p) = self.delta.as_mut() {
            if report.completed {
                if let Some(v) = report.version {
                    p.commit(v);
                }
            } else if report.aborted {
                // a failed completion burst may have promoted the round on a
                // subset of SMPs — only a full re-base is safe to diff against
                p.reset();
            }
        }
        Ok(report)
    }

    /// Tick until the in-flight round completes or aborts (bounded by the
    /// coordinator's L2 completion bound — never an unbounded spin).
    pub fn drain_pending(&mut self) -> Result<()> {
        let bound = self.coord.ticks_bound();
        for _ in 0..=bound {
            if self.coord.is_idle() {
                break;
            }
            self.tick()?;
        }
        anyhow::ensure!(
            self.coord.is_idle(),
            "snapshot backlog failed to drain within {bound} ticks"
        );
        Ok(())
    }

    /// Abort any in-flight asynchronous round (aborts racing dead SMPs are
    /// ignored by design).
    pub fn cancel_in_flight(&mut self) {
        let mut sink = SmpSink { smps: &self.smps };
        self.coord.abort_in_flight(&mut sink);
        // an abort drops every dirty buffer before any promotion, so the
        // SMPs still hold the last committed round — dropping the pending
        // tables (not resetting) keeps the sparse chain alive
        if let Some(p) = self.delta.as_mut() {
            p.drop_pending();
        }
    }

    /// Sparse-snapshot planner counters (`None` when the delta layer is
    /// disabled): full vs sparse round counts and total vs shipped bytes.
    pub fn delta_stats(&self) -> Option<DeltaStats> {
        self.delta.as_ref().map(DeltaPlanner::stats)
    }

    /// Coordinator introspection (versions, pending buckets, stats).
    pub fn coordinator(&self) -> &SnapshotCoordinator {
        &self.coord
    }

    /// Snapshot one stage's payload across its sharding group in tiny
    /// buckets, then (if enabled) compute + place the RAIM5 parities.
    /// `payload` is the stage's full FT payload (identical across DP paths
    /// after gradient sync, so any replica is a valid source — §4.1).
    ///
    /// Zero-copy: every bucket is a [`PayloadView`] into the shared capture;
    /// the only payload copy is the SMP's flush into its dirty buffer.
    pub fn snapshot_stage(
        &mut self,
        version: u64,
        stage: usize,
        payload: &SharedPayload,
    ) -> Result<()> {
        let stage_len = self.plan.stage_bytes[stage] as usize;
        anyhow::ensure!(
            payload.len() == stage_len,
            "stage {stage} payload {} != planned {stage_len}",
            payload.len()
        );
        let shards: Vec<_> = self.plan.shards_for_stage(stage).cloned().collect();
        for shard in &shards {
            let Some(smp) = self.smp(shard.node) else {
                bail!("node {} is offline — cannot snapshot", shard.node);
            };
            let total = shard.len() as usize;
            smp.send(SmpMsg::BeginSnapshot { version, stage, total_len: total })?;
            for r in BucketPipe::new(shard.range.clone(), self.ft.bucket_bytes) {
                smp.send(SmpMsg::Bucket {
                    version,
                    stage,
                    // SMP-local offsets are shard-relative
                    offset: (r.start - shard.range.start) as usize,
                    data: BucketRef::Shared(
                        payload.view(r.start as usize..r.end as usize),
                    ),
                })?;
            }
            smp.send(SmpMsg::EndSnapshot { version, stage })?;
        }
        // parity pass: encode from the same payload bytes the SMPs now hold
        if let Some(group) = self.groups.get(&stage) {
            let views: Vec<&[u8]> = shards
                .iter()
                .map(|s| &payload.as_slice()[s.range.start as usize..s.range.end as usize])
                .collect();
            for (host_idx, shard) in shards.iter().enumerate() {
                let parity = group.encode_parity(host_idx, &views);
                let Some(smp) = self.smp(shard.node) else {
                    bail!("node {} offline during parity placement", shard.node);
                };
                smp.send(SmpMsg::StoreParity { version, stage, data: parity })?;
            }
        }
        Ok(())
    }

    /// The blocking counterpart of a coordinator sparse round: every SMP
    /// seeds its dirty buffer from its latest clean copy (the round the
    /// planner diffed against), only the buckets overlapping `changed`
    /// ranges drain, and parity is patched rather than re-stored. The full
    /// [`Self::snapshot_stage`] stays as the oracle path.
    fn snapshot_stage_sparse(
        &mut self,
        version: u64,
        stage: usize,
        payload: &SharedPayload,
        changed: &[Range<u64>],
    ) -> Result<()> {
        let stage_len = self.plan.stage_bytes[stage] as usize;
        anyhow::ensure!(
            payload.len() == stage_len,
            "stage {stage} payload {} != planned {stage_len}",
            payload.len()
        );
        let shards: Vec<NodeShard> = self.plan.shards_for_stage(stage).cloned().collect();
        for shard in &shards {
            let segs: Vec<Range<u64>> = changed
                .iter()
                .filter_map(|g| {
                    let lo = g.start.max(shard.range.start);
                    let hi = g.end.min(shard.range.end);
                    (lo < hi).then(|| lo..hi)
                })
                .collect();
            let delta_len: usize = segs.iter().map(|r| (r.end - r.start) as usize).sum();
            let Some(smp) = self.smp(shard.node) else {
                bail!("node {} is offline — cannot snapshot", shard.node);
            };
            smp.send(SmpMsg::BeginDeltaSnapshot {
                version,
                stage,
                total_len: shard.len() as usize,
                delta_len,
            })?;
            for seg in &segs {
                for r in BucketPipe::new(seg.clone(), self.ft.bucket_bytes) {
                    smp.send(SmpMsg::Bucket {
                        version,
                        stage,
                        // SMP-local offsets are shard-relative
                        offset: (r.start - shard.range.start) as usize,
                        data: BucketRef::Shared(
                            payload.view(r.start as usize..r.end as usize),
                        ),
                    })?;
                }
            }
            smp.send(SmpMsg::EndSnapshot { version, stage })?;
        }
        // parity pass: encode in full from the new payload, ship only the
        // spans that can differ (parity is XOR-linear in its contributors)
        if let Some(group) = self.groups.get(&stage) {
            let shard_refs: Vec<&NodeShard> = shards.iter().collect();
            let views: Vec<&[u8]> = shards
                .iter()
                .map(|s| &payload.as_slice()[s.range.start as usize..s.range.end as usize])
                .collect();
            for (host_idx, shard) in shards.iter().enumerate() {
                let parity = group.encode_parity(host_idx, &views);
                let patches = parity_patches(group, host_idx, &shard_refs, changed, &parity);
                let Some(smp) = self.smp(shard.node) else {
                    bail!("node {} offline during parity placement", shard.node);
                };
                smp.send(SmpMsg::StoreParityDelta { version, stage, patches })?;
            }
        }
        Ok(())
    }

    /// Snapshot all stages (one consistent version), complete on return.
    /// Dispatches on `FtConfig::async_snapshot`: the async flavour still
    /// exercises the coordinator (enqueue + bounded drain), the blocking
    /// flavour is the legacy in-caller bucket loop. Either way the round is
    /// fully promoted when this returns, so restore sees it immediately.
    /// `payloads.to_vec()` here clones `Arc` handles, not payload bytes.
    pub fn snapshot_all(&mut self, payloads: &[SharedPayload]) -> Result<u64> {
        if self.ft.async_snapshot {
            let v = self.request_snapshot(payloads.to_vec())?;
            self.drain_pending()?;
            anyhow::ensure!(
                self.coord.stats().last_completed_version == Some(v),
                "async snapshot v{v} aborted mid-drain"
            );
            Ok(v)
        } else {
            self.snapshot_all_blocking(payloads)
        }
    }

    /// The legacy synchronous save: every bucket of every stage drains
    /// inside this call (what the async coordinator is measured against,
    /// and the deterministic path recovery re-protection uses).
    pub fn snapshot_all_blocking(&mut self, payloads: &[SharedPayload]) -> Result<u64> {
        anyhow::ensure!(payloads.len() == self.topo.plan.pp);
        // a round the coordinator still has in flight is now stale
        self.cancel_in_flight();
        self.version += 1;
        let v = self.version;
        let ships = self.delta.as_mut().map(|p| p.plan(v, payloads));
        let mut outcome = Ok(());
        for (stage, payload) in payloads.iter().enumerate() {
            let r = match ships.as_ref().map(|s| &s[stage]) {
                Some(StageShip::Sparse(ranges)) => {
                    self.snapshot_stage_sparse(v, stage, payload, ranges)
                }
                _ => self.snapshot_stage(v, stage, payload),
            };
            if r.is_err() {
                outcome = r;
                break;
            }
        }
        match outcome {
            Ok(()) => {
                if let Some(p) = self.delta.as_mut() {
                    p.commit(v);
                }
                Ok(v)
            }
            Err(e) => {
                // a torn blocking round may have promoted v on earlier
                // stages' SMPs; the committed tables no longer describe what
                // every SMP holds, so force a full re-base
                if let Some(p) = self.delta.as_mut() {
                    p.reset();
                }
                Err(e)
            }
        }
    }

    /// Restore one stage's full payload from SMP shards, RAIM5-decoding the
    /// shards of `dead` nodes. Errors if protection is exceeded.
    ///
    /// This is the **parallel distributed in-memory load** (paper §4.2
    /// restart path): shard and parity fetches are issued to every surviving
    /// SG member up front so all SMPs serialize and ship concurrently, a
    /// scoped gather thread per survivor stitches its shard directly into
    /// the pre-allocated output buffer, and a lost shard is XOR-decoded
    /// straight into its slot (no decode-then-stitch copy).
    pub fn restore_stage(&self, stage: usize, dead: &[usize]) -> Result<Vec<u8>> {
        let mut out = vec![0u8; self.plan.stage_bytes[stage] as usize];
        self.restore_stage_into(stage, dead, &mut out)?;
        Ok(out)
    }

    fn restore_stage_into(&self, stage: usize, dead: &[usize], out: &mut [u8]) -> Result<()> {
        let _sp = obs::span_arg(obs::cat::ELASTIC, "restore_stage", 0, stage as u64);
        let shards: Vec<NodeShard> = self.plan.shards_for_stage(stage).cloned().collect();
        // The slice carving below requires the plan to tile the stage
        // payload contiguously in ascending *plan order* and fails loudly
        // otherwise (the contiguity ensure in the carve loop). Do NOT sort
        // here: RAIM5 parity placement uses plan-order SG indices, so
        // silently reordering would decode with mismatched indices.
        anyhow::ensure!(
            out.len() == self.plan.stage_bytes[stage] as usize,
            "restore buffer {} bytes != stage {stage} payload {}",
            out.len(),
            self.plan.stage_bytes[stage]
        );
        let dead_in_sg: Vec<usize> = (0..shards.len())
            .filter(|&i| dead.contains(&shards[i].node) || self.smp(shards[i].node).is_none())
            .collect();
        let need_decode = !dead_in_sg.is_empty();
        if need_decode {
            anyhow::ensure!(
                self.groups.contains_key(&stage),
                "node lost but RAIM5 is not enabled for this stage"
            );
            anyhow::ensure!(
                dead_in_sg.len() == 1,
                "{} nodes lost in SG {stage} — exceeds RAIM5 protection",
                dead_in_sg.len()
            );
        }

        // phase 1: issue every clean (+ parity) fetch before reading any
        // reply, so all surviving SMPs snapshot-clone and ship concurrently
        type Reply = Receiver<Option<(u64, Vec<u8>)>>;
        let mut fetches: Vec<Option<(Reply, Option<Reply>)>> = Vec::with_capacity(shards.len());
        for (i, shard) in shards.iter().enumerate() {
            if dead_in_sg.contains(&i) {
                fetches.push(None);
                continue;
            }
            let smp = self.smp(shard.node).context("survivor SMP gone")?;
            let (ctx, crx) = channel();
            smp.send(SmpMsg::GetClean { stage, reply: ctx })?;
            let prx = if need_decode {
                let (ptx, prx) = channel();
                smp.send(SmpMsg::GetParity { stage, reply: ptx })?;
                Some(prx)
            } else {
                None
            };
            fetches.push(Some((crx, prx)));
        }

        // carve the output buffer into disjoint per-shard slices
        let mut slices: Vec<&mut [u8]> = Vec::with_capacity(shards.len());
        {
            let mut rest: &mut [u8] = out;
            let mut cursor = 0u64;
            for shard in &shards {
                anyhow::ensure!(
                    shard.range.start == cursor,
                    "stage {stage} shard plan is not contiguous at byte {cursor}"
                );
                let (head, tail) = rest.split_at_mut(shard.len() as usize);
                slices.push(head);
                rest = tail;
                cursor = shard.range.end;
            }
            anyhow::ensure!(rest.is_empty(), "stage {stage} shard plan under-covers payload");
        }

        // phase 2: scoped gather — one thread per survivor receives its
        // shard and copies it straight into the stitched output slice
        let mut results: Vec<Option<(u64, Option<(u64, Vec<u8>)>)>> = Vec::new();
        results.resize_with(shards.len(), || None);
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(shards.len());
            for ((i, fetch), slice) in fetches.into_iter().enumerate().zip(slices) {
                let Some((crx, prx)) = fetch else {
                    continue; // lost shard: its slice stays zeroed for decode
                };
                let node = shards[i].node;
                handles.push((
                    i,
                    scope.spawn(move || -> Result<(u64, Option<(u64, Vec<u8>)>)> {
                        let (v, data) = crx
                            .recv()
                            .map_err(|_| anyhow!("SMP {node} died mid-restore"))?
                            .with_context(|| {
                                format!("no clean snapshot for stage {stage} on node {node}")
                            })?;
                        anyhow::ensure!(
                            data.len() == slice.len(),
                            "shard on node {node} has {} bytes, expected {}",
                            data.len(),
                            slice.len()
                        );
                        slice.copy_from_slice(&data);
                        let parity = match prx {
                            Some(p) => p
                                .recv()
                                .map_err(|_| anyhow!("SMP {node} died mid-restore"))?,
                            None => None,
                        };
                        Ok((v, parity))
                    }),
                ));
            }
            for (i, h) in handles {
                let r = h.join().map_err(|_| anyhow!("restore gather thread panicked"))?;
                results[i] = Some(r?);
            }
            Ok(())
        })?;

        // consistency: all survivors must agree on the snapshot version
        let versions: Vec<u64> = results.iter().flatten().map(|(v, _)| *v).collect();
        anyhow::ensure!(!versions.is_empty(), "no clean snapshot for stage {stage}");
        let v = versions[0];
        anyhow::ensure!(
            versions.iter().all(|&x| x == v),
            "inconsistent snapshot versions {versions:?} for stage {stage}"
        );
        obs::instant(obs::cat::ELASTIC, "restored", v, stage as u64);

        if let Some(&lost) = dead_in_sg.first() {
            let group = self.groups.get(&stage).expect("checked above");
            let empty: &[u8] = &[];
            let mut parities: Vec<&[u8]> = Vec::with_capacity(shards.len());
            for (i, r) in results.iter().enumerate() {
                match r {
                    Some((_, Some((pv, pdata)))) => {
                        anyhow::ensure!(*pv == v, "parity version {pv} != snapshot {v}");
                        parities.push(pdata);
                    }
                    Some((_, None)) => {
                        bail!("no parity on node {}", shards[i].node)
                    }
                    // the lost node's own parity is never read by the decoder
                    None => parities.push(empty),
                }
            }
            // split the output so survivor views and the lost shard's
            // destination slice can coexist; decode writes in place
            let lost_start = shards[lost].range.start as usize;
            let lost_end = shards[lost].range.end as usize;
            let (head, rest) = out.split_at_mut(lost_start);
            let (lost_slice, tail) = rest.split_at_mut(lost_end - lost_start);
            let views: Vec<&[u8]> = shards
                .iter()
                .enumerate()
                .map(|(j, s)| {
                    let (a, b) = (s.range.start as usize, s.range.end as usize);
                    if j == lost {
                        empty
                    } else if j < lost {
                        &head[a..b]
                    } else {
                        &tail[a - lost_end..b - lost_end]
                    }
                })
                .collect();
            group.decode_into(lost, &views, &parities, lost_slice)?;
            obs::instant(obs::cat::ELASTIC, "decode", v, shards[lost].node as u64);
        }
        Ok(())
    }

    /// Restore every stage concurrently (see [`Self::restore_stage`]): each
    /// stage's gather runs on its own scoped thread, so a multi-stage
    /// restart overlaps the per-SG network/decode work across stages.
    pub fn restore_all(&self, dead: &[usize]) -> Result<Vec<Vec<u8>>> {
        let pp = self.topo.plan.pp;
        if pp == 1 {
            return Ok(vec![self.restore_stage(0, dead)?]);
        }
        let mut out: Vec<Result<Vec<u8>>> = Vec::with_capacity(pp);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..pp)
                .map(|s| scope.spawn(move || self.restore_stage(s, dead)))
                .collect();
            for h in handles {
                out.push(
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("restore stage thread panicked"))),
                );
            }
        });
        out.into_iter().collect()
    }

    /// The pre-parallel serial restore: fetch shards one SMP at a time,
    /// decode into a temporary, stitch at the end. Kept as the measured
    /// baseline for `benches/hotpath.rs` and as the byte-identity oracle the
    /// parallel-path tests compare against.
    pub fn restore_stage_serial(&self, stage: usize, dead: &[usize]) -> Result<Vec<u8>> {
        let shards: Vec<_> = self.plan.shards_for_stage(stage).cloned().collect();
        let dead_in_sg: Vec<usize> = (0..shards.len())
            .filter(|&i| dead.contains(&shards[i].node) || self.smp(shards[i].node).is_none())
            .collect();
        let mut parts: Vec<Option<(u64, Vec<u8>)>> = Vec::with_capacity(shards.len());
        for (i, shard) in shards.iter().enumerate() {
            if dead_in_sg.contains(&i) {
                parts.push(None);
                continue;
            }
            let smp = self.smp(shard.node).context("survivor SMP gone")?;
            parts.push(smp.get_clean(stage)?);
        }
        // consistency: all survivors must agree on the snapshot version
        let versions: Vec<u64> = parts.iter().flatten().map(|(v, _)| *v).collect();
        anyhow::ensure!(!versions.is_empty(), "no clean snapshot for stage {stage}");
        let v = versions[0];
        anyhow::ensure!(
            versions.iter().all(|&x| x == v),
            "inconsistent snapshot versions {versions:?} for stage {stage}"
        );

        let mut shard_bytes: Vec<Vec<u8>> = Vec::with_capacity(shards.len());
        for p in &parts {
            shard_bytes.push(p.as_ref().map(|(_, d)| d.clone()).unwrap_or_default());
        }
        if !dead_in_sg.is_empty() {
            let group = self
                .groups
                .get(&stage)
                .context("node lost but RAIM5 is not enabled for this stage")?;
            anyhow::ensure!(
                dead_in_sg.len() == 1,
                "{} nodes lost in SG {stage} — exceeds RAIM5 protection",
                dead_in_sg.len()
            );
            let lost = dead_in_sg[0];
            // gather parities from survivors
            let mut parities: Vec<Vec<u8>> = vec![Vec::new(); shards.len()];
            for (i, shard) in shards.iter().enumerate() {
                if i == lost {
                    parities[i] = vec![0u8; group.parity_len()];
                    continue;
                }
                let smp = self.smp(shard.node).context("survivor SMP gone")?;
                let (pv, pdata) = smp
                    .get_parity(stage)?
                    .with_context(|| format!("no parity on node {}", shard.node))?;
                anyhow::ensure!(pv == v, "parity version {pv} != snapshot {v}");
                parities[i] = pdata;
            }
            let views: Vec<&[u8]> = shard_bytes.iter().map(Vec::as_slice).collect();
            let pviews: Vec<&[u8]> = parities.iter().map(Vec::as_slice).collect();
            shard_bytes[lost] = group.decode(lost, &views, &pviews)?;
        }
        // stitch the full payload back together
        let mut out = vec![0u8; self.plan.stage_bytes[stage] as usize];
        for (shard, bytes) in shards.iter().zip(&shard_bytes) {
            anyhow::ensure!(
                bytes.len() == shard.len() as usize,
                "shard on node {} has {} bytes, expected {}",
                shard.node,
                bytes.len(),
                shard.len()
            );
            out[shard.range.start as usize..shard.range.end as usize].copy_from_slice(bytes);
        }
        Ok(out)
    }

    /// Serial restore of every stage (see [`Self::restore_stage_serial`]).
    pub fn restore_all_serial(&self, dead: &[usize]) -> Result<Vec<Vec<u8>>> {
        (0..self.topo.plan.pp)
            .map(|s| self.restore_stage_serial(s, dead))
            .collect()
    }

    /// Simulate losing a node: its SMP dies with all buffers. An in-flight
    /// asynchronous round can no longer complete consistently, so it is
    /// aborted on the survivors (their last clean version stays served).
    pub fn kill_node(&mut self, node: usize) {
        obs::instant(obs::cat::ELASTIC, "kill_node", self.version, node as u64);
        if let Some(mut smp) = self.smps[node].take() {
            smp.kill();
        }
        self.cancel_in_flight();
        // the dead node's clean copies are gone; its replacement starts
        // empty, so the next round must re-base in full
        if let Some(p) = self.delta.as_mut() {
            p.reset();
        }
    }

    /// Elastic substitute-node introduction: a fresh SMP joins in place of a
    /// lost one (empty — it will be filled by decode + the next snapshot).
    pub fn replace_node(&mut self, node: usize) -> Result<()> {
        obs::instant(obs::cat::ELASTIC, "replace_node", self.version, node as u64);
        anyhow::ensure!(self.smps[node].is_none(), "node {node} is not vacant");
        let smp = Smp::spawn(node, self.ft.clean_copies);
        smp.send(SmpMsg::Signal(Signal::Snap))?;
        self.smps[node] = Some(smp);
        // the substitute holds no clean copy to patch — force a full re-base
        if let Some(p) = self.delta.as_mut() {
            p.reset();
        }
        Ok(())
    }

    /// Nodes currently alive.
    pub fn alive_nodes(&self) -> Vec<usize> {
        (0..self.topo.nodes)
            .filter(|&n| self.smps[n].is_some())
            .collect()
    }

    /// Total bytes resident across all SMPs (the paper's §6.2a memory-usage
    /// accounting).
    pub fn resident_bytes(&self) -> Result<usize> {
        let mut total = 0;
        for smp in self.smps.iter().flatten() {
            total += smp.stats()?.bytes_resident;
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ParallelPlan;
    use crate::util::rng::Rng;

    fn payload(len: usize, seed: u64) -> SharedPayload {
        let mut rng = Rng::seed_from(seed);
        SharedPayload::new((0..len).map(|_| rng.next_u64() as u8).collect())
    }

    fn dp6_cluster(raim5: bool) -> (ReftCluster, Vec<SharedPayload>) {
        let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
        let bytes = vec![60_000u64];
        let ft = FtConfig { raim5, bucket_bytes: 4096, ..FtConfig::default() };
        let cluster = ReftCluster::start(topo, &bytes, ft).unwrap();
        let payloads = vec![payload(60_000, 9)];
        (cluster, payloads)
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        let restored = c.restore_all(&[]).unwrap();
        assert_eq!(restored, payloads);
    }

    #[test]
    fn survives_single_node_loss_via_raim5() {
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        c.kill_node(3);
        let restored = c.restore_all(&[3]).unwrap();
        assert_eq!(restored, payloads, "decoded shard must be bit-identical");
    }

    #[test]
    fn two_losses_exceed_protection() {
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        c.kill_node(1);
        c.kill_node(4);
        assert!(c.restore_all(&[1, 4]).is_err());
    }

    #[test]
    fn without_raim5_node_loss_is_fatal_for_inmemory_path() {
        let (mut c, payloads) = dp6_cluster(false);
        c.snapshot_all(&payloads).unwrap();
        c.kill_node(0);
        assert!(c.restore_all(&[0]).is_err());
        // but the in-memory path still works with all nodes alive
        let (mut c2, payloads2) = dp6_cluster(false);
        c2.snapshot_all(&payloads2).unwrap();
        assert_eq!(c2.restore_all(&[]).unwrap(), payloads2);
    }

    #[test]
    fn restore_uses_latest_consistent_version() {
        let (mut c, mut payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        payloads[0] = payload(60_000, 77);
        c.snapshot_all(&payloads).unwrap();
        let restored = c.restore_all(&[]).unwrap();
        assert_eq!(restored, payloads);
    }

    #[test]
    fn replace_node_and_resnapshot() {
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        c.kill_node(2);
        let restored = c.restore_all(&[2]).unwrap();
        assert_eq!(restored, payloads);
        // elastic substitution: fresh node joins, next snapshot covers it
        c.replace_node(2).unwrap();
        assert_eq!(c.alive_nodes().len(), 6);
        c.snapshot_all(&payloads).unwrap();
        let again = c.restore_all(&[]).unwrap();
        assert_eq!(again, payloads);
    }

    #[test]
    fn multi_stage_3d_roundtrip_with_loss() {
        // 2 DP x 4 TP x 3 PP on the full testbed
        let topo = Topology::build(ParallelPlan::new(2, 4, 3), 6, 4).unwrap();
        let bytes = vec![40_000u64, 30_000, 50_000];
        let ft = FtConfig { bucket_bytes: 1024, ..FtConfig::default() };
        let mut c = ReftCluster::start(topo, &bytes, ft).unwrap();
        let payloads: Vec<SharedPayload> = bytes
            .iter()
            .enumerate()
            .map(|(i, &b)| payload(b as usize, i as u64))
            .collect();
        c.snapshot_all(&payloads).unwrap();
        // lose one node: it belongs to exactly one SG here
        c.kill_node(4);
        let restored = c.restore_all(&[4]).unwrap();
        assert_eq!(restored, payloads);
    }

    #[test]
    fn parallel_restore_matches_serial_baseline() {
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        assert_eq!(
            c.restore_all(&[]).unwrap(),
            c.restore_all_serial(&[]).unwrap(),
            "no-failure gather"
        );
        c.kill_node(3);
        let par = c.restore_all(&[3]).unwrap();
        let ser = c.restore_all_serial(&[3]).unwrap();
        assert_eq!(par, ser, "decode-into-place vs decode-then-stitch");
        assert_eq!(par, payloads);
    }

    fn dp6_async_cluster(bucket: usize, budget: usize) -> (ReftCluster, Vec<SharedPayload>) {
        let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
        let bytes = vec![60_000u64];
        let ft = FtConfig {
            bucket_bytes: bucket,
            async_snapshot: true,
            drain_buckets_per_tick: budget,
            ..FtConfig::default()
        };
        let cluster = ReftCluster::start(topo, &bytes, ft).unwrap();
        let payloads = vec![payload(60_000, 9)];
        (cluster, payloads)
    }

    #[test]
    fn async_compat_wrapper_completes_before_returning() {
        let (mut c, payloads) = dp6_async_cluster(1024, 2);
        let v = c.snapshot_all(&payloads).unwrap();
        assert_eq!(v, 1);
        assert!(c.coordinator().is_idle());
        assert_eq!(c.coordinator().stats().completed, 1);
        assert!(c.coordinator().stats().ticks > 1, "multi-tick drain");
        assert_eq!(c.restore_all(&[]).unwrap(), payloads);
    }

    #[test]
    fn request_snapshot_is_an_enqueue_then_ticks_finish_it() {
        let (mut c, payloads) = dp6_async_cluster(1024, 2);
        let v = c.request_snapshot(payloads.clone()).unwrap();
        assert_eq!(c.coordinator().in_flight_version(), Some(v));
        assert!(c.coordinator().pending_buckets() > 0);
        // nothing promoted yet: restore must fail (no clean snapshot)
        assert!(c.restore_all(&[]).is_err());
        let bound = c.coordinator().ticks_bound();
        let mut completed = false;
        for _ in 0..bound {
            if c.tick().unwrap().completed {
                completed = true;
                break;
            }
        }
        assert!(completed, "must finish within the L2 bound of {bound} ticks");
        assert_eq!(c.restore_all(&[]).unwrap(), payloads);
    }

    #[test]
    fn async_and_blocking_paths_restore_identical_bytes() {
        let (mut a, payloads) = dp6_async_cluster(4096, 4);
        a.snapshot_all(&payloads).unwrap();
        let (mut b, _) = dp6_cluster(true);
        b.snapshot_all_blocking(&payloads).unwrap();
        assert_eq!(
            a.restore_all(&[]).unwrap(),
            b.restore_all(&[]).unwrap(),
            "payload through the coordinator must be byte-identical"
        );
    }

    #[test]
    fn node_loss_mid_drain_keeps_previous_version_restorable() {
        let (mut c, payloads) = dp6_async_cluster(1024, 2);
        c.snapshot_all(&payloads).unwrap(); // v1 complete
        let newer = vec![payload(60_000, 33)];
        c.request_snapshot(newer).unwrap(); // v2 in flight
        c.tick().unwrap(); // partial drain
        c.kill_node(2); // v2 aborted on survivors; v1 stays clean
        let restored = c.restore_all(&[2]).unwrap();
        assert_eq!(restored, payloads, "torn v2 must never surface");
    }

    fn dp6_delta_cluster(async_snapshot: bool) -> (ReftCluster, Vec<SharedPayload>) {
        let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
        let bytes = vec![60_000u64];
        let ft = FtConfig {
            raim5: true,
            bucket_bytes: 1024,
            async_snapshot,
            drain_buckets_per_tick: 4,
            delta_extent_bytes: 512,
            delta_chain_max: 8,
            ..FtConfig::default()
        };
        let cluster = ReftCluster::start(topo, &bytes, ft).unwrap();
        let payloads = vec![payload(60_000, 9)];
        (cluster, payloads)
    }

    #[test]
    fn sparse_blocking_rounds_restore_and_decode_after_patches() {
        let (mut c, mut payloads) = dp6_delta_cluster(false);
        c.snapshot_all(&payloads).unwrap(); // full base round
        let mut bytes = payloads[0].as_slice().to_vec();
        for i in (3_000..4_000).chain(41_000..45_000) {
            bytes[i] ^= 0x5A;
        }
        payloads[0] = SharedPayload::new(bytes);
        c.snapshot_all(&payloads).unwrap(); // sparse round
        let st = c.delta_stats().unwrap();
        assert_eq!((st.full_rounds, st.sparse_rounds), (1, 1));
        assert!(st.shipped_bytes < st.payload_bytes, "{st:?}");
        assert_eq!(c.restore_all(&[]).unwrap(), payloads);
        // parity was patched in place, never re-stored in full — a decode of
        // a lost shard must still be bit-exact
        c.kill_node(2);
        assert_eq!(c.restore_all(&[2]).unwrap(), payloads);
    }

    #[test]
    fn sparse_async_rounds_commit_and_ship_only_changed_bytes() {
        let (mut c, mut payloads) = dp6_delta_cluster(true);
        c.snapshot_all(&payloads).unwrap(); // full base via the coordinator
        let mut bytes = payloads[0].as_slice().to_vec();
        for b in bytes.iter_mut().take(2_000) {
            *b = b.wrapping_add(1);
        }
        payloads[0] = SharedPayload::new(bytes);
        c.snapshot_all(&payloads).unwrap(); // sparse drain + commit on tick
        let st = c.delta_stats().unwrap();
        assert_eq!((st.full_rounds, st.sparse_rounds), (1, 1));
        assert_eq!(c.restore_all(&[]).unwrap(), payloads);
        // 60k full base + one 2k churn round padded to the 512 B extent
        // grain: far below two full rounds
        let sent = c.coordinator().stats().payload_bytes_sent;
        assert!(sent < 63_000, "shipped {sent} bytes");
    }

    #[test]
    fn node_replacement_forces_full_rebase_round() {
        let (mut c, mut payloads) = dp6_delta_cluster(false);
        c.snapshot_all(&payloads).unwrap();
        c.kill_node(4);
        c.replace_node(4).unwrap();
        // the unchanged payload would diff to an empty delta, but the fresh
        // SMP holds no base to patch — membership change must force a full
        // round, or node 4 would promote garbage
        c.snapshot_all(&payloads).unwrap();
        let st = c.delta_stats().unwrap();
        assert_eq!(st.full_rounds, 2);
        assert_eq!(c.restore_all(&[]).unwrap(), payloads);
        // and sparse rounds resume on the rebuilt base
        let mut bytes = payloads[0].as_slice().to_vec();
        bytes[100] ^= 1;
        payloads[0] = SharedPayload::new(bytes);
        c.snapshot_all(&payloads).unwrap();
        assert_eq!(c.delta_stats().unwrap().sparse_rounds, 1);
        assert_eq!(c.restore_all(&[]).unwrap(), payloads);
    }

    #[test]
    fn cancelled_sparse_round_keeps_diffing_against_last_committed() {
        let (mut c, mut payloads) = dp6_delta_cluster(true);
        c.snapshot_all(&payloads).unwrap(); // v1 full, committed
        let v1_payloads = payloads.clone();
        let mut bytes = payloads[0].as_slice().to_vec();
        bytes[10_000] ^= 0xFF;
        payloads[0] = SharedPayload::new(bytes);
        c.request_snapshot(payloads.clone()).unwrap(); // v2 sparse, in flight
        c.cancel_in_flight(); // v2 never promotes anywhere
        assert_eq!(c.restore_all(&[]).unwrap(), v1_payloads);
        // v3 must diff against v1 (the last *committed* round): the byte v2
        // would have shipped is shipped again, so the restore is exact
        c.snapshot_all(&payloads).unwrap();
        assert_eq!(c.restore_all(&[]).unwrap(), payloads);
    }

    #[test]
    fn memory_accounting_within_paper_bound() {
        // §6.2a: REFT uses at most ~3x (payload) of CPU memory per node
        // budget; with parity ~ payload/m extra, resident should be well
        // under 2x the total payload for one clean copy
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        let resident = c.resident_bytes().unwrap();
        let payload_total: usize = payloads.iter().map(SharedPayload::len).sum();
        assert!(resident >= payload_total);
        assert!(
            resident <= payload_total * 2,
            "{resident} vs payload {payload_total}"
        );
    }
}
