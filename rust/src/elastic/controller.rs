//! The live REFT cluster: per-node SMP threads + the snapshot/recovery
//! orchestration over them. This is what the trainer and the e2e examples
//! drive — real bytes, real threads, real XOR decode.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::FtConfig;
use crate::ec::Raim5Group;
use crate::smp::{Signal, Smp, SmpMsg};
use crate::snapshot::{BucketPipe, SnapshotPlan};
use crate::topology::Topology;

/// The in-memory fault-tolerance fabric of one training cluster.
pub struct ReftCluster {
    pub topo: Topology,
    pub plan: SnapshotPlan,
    pub ft: FtConfig,
    /// SMP per node; `None` marks a node that was lost and not yet replaced
    smps: Vec<Option<Smp>>,
    /// RAIM5 layout per PP stage (only for SGs with >= 2 nodes)
    groups: BTreeMap<usize, Raim5Group>,
    /// the snapshot version counter (one per completed snapshot round)
    pub version: u64,
}

impl ReftCluster {
    /// Bring up SMPs on every node and signal SNAP.
    pub fn start(topo: Topology, stage_payload_bytes: &[u64], ft: FtConfig) -> Result<Self> {
        let plan = SnapshotPlan::build(&topo, stage_payload_bytes);
        let mut groups = BTreeMap::new();
        if ft.raim5 {
            for stage in 0..topo.plan.pp {
                let lens = plan.sg_shard_lens(stage);
                if lens.len() >= 2 {
                    groups.insert(stage, Raim5Group::plan(&lens)?);
                }
            }
        }
        let smps: Vec<Option<Smp>> = (0..topo.nodes)
            .map(|n| Some(Smp::spawn(n, ft.clean_copies)))
            .collect();
        for smp in smps.iter().flatten() {
            smp.send(SmpMsg::Signal(Signal::Snap))?;
        }
        Ok(ReftCluster { topo, plan, ft, smps, groups, version: 0 })
    }

    pub fn smp(&self, node: usize) -> Option<&Smp> {
        self.smps.get(node).and_then(Option::as_ref)
    }

    /// Snapshot one stage's payload across its sharding group in tiny
    /// buckets, then (if enabled) compute + place the RAIM5 parities.
    /// `payload` is the stage's full FT payload (identical across DP paths
    /// after gradient sync, so any replica is a valid source — §4.1).
    pub fn snapshot_stage(&mut self, version: u64, stage: usize, payload: &[u8]) -> Result<()> {
        let stage_len = self.plan.stage_bytes[stage] as usize;
        anyhow::ensure!(
            payload.len() == stage_len,
            "stage {stage} payload {} != planned {stage_len}",
            payload.len()
        );
        let shards: Vec<_> = self.plan.shards_for_stage(stage).cloned().collect();
        for shard in &shards {
            let Some(smp) = self.smp(shard.node) else {
                bail!("node {} is offline — cannot snapshot", shard.node);
            };
            let total = shard.len() as usize;
            smp.send(SmpMsg::BeginSnapshot { version, stage, total_len: total })?;
            // one write into the node's "shared-memory segment" per shard;
            // buckets are zero-copy views into it (the SMP does the flush
            // copy into its dirty buffer — the paper's Fig. 6 data flow)
            let seg = std::sync::Arc::new(
                payload[shard.range.start as usize..shard.range.end as usize].to_vec(),
            );
            for r in BucketPipe::new(0..shard.len(), self.ft.bucket_bytes) {
                smp.send(SmpMsg::Bucket {
                    version,
                    stage,
                    // SMP-local offsets are shard-relative
                    offset: r.start as usize,
                    data: crate::smp::BucketRef::Shared {
                        seg: std::sync::Arc::clone(&seg),
                        range: r.start as usize..r.end as usize,
                    },
                })?;
            }
            smp.send(SmpMsg::EndSnapshot { version, stage })?;
        }
        // parity pass: encode from the same payload bytes the SMPs now hold
        if let Some(group) = self.groups.get(&stage) {
            let views: Vec<&[u8]> = shards
                .iter()
                .map(|s| &payload[s.range.start as usize..s.range.end as usize])
                .collect();
            for (host_idx, shard) in shards.iter().enumerate() {
                let parity = group.encode_parity(host_idx, &views);
                let Some(smp) = self.smp(shard.node) else {
                    bail!("node {} offline during parity placement", shard.node);
                };
                smp.send(SmpMsg::StoreParity { version, stage, data: parity })?;
            }
        }
        Ok(())
    }

    /// Snapshot all stages (one consistent version).
    pub fn snapshot_all(&mut self, payloads: &[Vec<u8>]) -> Result<u64> {
        anyhow::ensure!(payloads.len() == self.topo.plan.pp);
        self.version += 1;
        let v = self.version;
        for (stage, payload) in payloads.iter().enumerate() {
            self.snapshot_stage(v, stage, payload)?;
        }
        Ok(v)
    }

    /// Restore one stage's full payload from SMP shards, RAIM5-decoding the
    /// shards of `dead` nodes. Errors if protection is exceeded.
    pub fn restore_stage(&self, stage: usize, dead: &[usize]) -> Result<Vec<u8>> {
        let shards: Vec<_> = self.plan.shards_for_stage(stage).cloned().collect();
        let dead_in_sg: Vec<usize> = (0..shards.len())
            .filter(|&i| dead.contains(&shards[i].node) || self.smp(shards[i].node).is_none())
            .collect();
        let mut parts: Vec<Option<(u64, Vec<u8>)>> = Vec::with_capacity(shards.len());
        for (i, shard) in shards.iter().enumerate() {
            if dead_in_sg.contains(&i) {
                parts.push(None);
                continue;
            }
            let smp = self.smp(shard.node).context("survivor SMP gone")?;
            parts.push(smp.get_clean(stage)?);
        }
        // consistency: all survivors must agree on the snapshot version
        let versions: Vec<u64> = parts.iter().flatten().map(|(v, _)| *v).collect();
        anyhow::ensure!(!versions.is_empty(), "no clean snapshot for stage {stage}");
        let v = versions[0];
        anyhow::ensure!(
            versions.iter().all(|&x| x == v),
            "inconsistent snapshot versions {versions:?} for stage {stage}"
        );

        let mut shard_bytes: Vec<Vec<u8>> = Vec::with_capacity(shards.len());
        for p in &parts {
            shard_bytes.push(p.as_ref().map(|(_, d)| d.clone()).unwrap_or_default());
        }
        if !dead_in_sg.is_empty() {
            let group = self
                .groups
                .get(&stage)
                .context("node lost but RAIM5 is not enabled for this stage")?;
            anyhow::ensure!(
                dead_in_sg.len() == 1,
                "{} nodes lost in SG {stage} — exceeds RAIM5 protection",
                dead_in_sg.len()
            );
            let lost = dead_in_sg[0];
            // gather parities from survivors
            let mut parities: Vec<Vec<u8>> = vec![Vec::new(); shards.len()];
            for (i, shard) in shards.iter().enumerate() {
                if i == lost {
                    parities[i] = vec![0u8; group.parity_len()];
                    continue;
                }
                let smp = self.smp(shard.node).context("survivor SMP gone")?;
                let (pv, pdata) = smp
                    .get_parity(stage)?
                    .with_context(|| format!("no parity on node {}", shard.node))?;
                anyhow::ensure!(pv == v, "parity version {pv} != snapshot {v}");
                parities[i] = pdata;
            }
            let views: Vec<&[u8]> = shard_bytes.iter().map(Vec::as_slice).collect();
            let pviews: Vec<&[u8]> = parities.iter().map(Vec::as_slice).collect();
            shard_bytes[lost] = group.decode(lost, &views, &pviews)?;
        }
        // stitch the full payload back together
        let mut out = vec![0u8; self.plan.stage_bytes[stage] as usize];
        for (shard, bytes) in shards.iter().zip(&shard_bytes) {
            anyhow::ensure!(
                bytes.len() == shard.len() as usize,
                "shard on node {} has {} bytes, expected {}",
                shard.node,
                bytes.len(),
                shard.len()
            );
            out[shard.range.start as usize..shard.range.end as usize].copy_from_slice(bytes);
        }
        Ok(out)
    }

    /// Restore every stage (see [`Self::restore_stage`]).
    pub fn restore_all(&self, dead: &[usize]) -> Result<Vec<Vec<u8>>> {
        (0..self.topo.plan.pp)
            .map(|s| self.restore_stage(s, dead))
            .collect()
    }

    /// Simulate losing a node: its SMP dies with all buffers.
    pub fn kill_node(&mut self, node: usize) {
        if let Some(mut smp) = self.smps[node].take() {
            smp.kill();
        }
    }

    /// Elastic substitute-node introduction: a fresh SMP joins in place of a
    /// lost one (empty — it will be filled by decode + the next snapshot).
    pub fn replace_node(&mut self, node: usize) -> Result<()> {
        anyhow::ensure!(self.smps[node].is_none(), "node {node} is not vacant");
        let smp = Smp::spawn(node, self.ft.clean_copies);
        smp.send(SmpMsg::Signal(Signal::Snap))?;
        self.smps[node] = Some(smp);
        Ok(())
    }

    /// Nodes currently alive.
    pub fn alive_nodes(&self) -> Vec<usize> {
        (0..self.topo.nodes)
            .filter(|&n| self.smps[n].is_some())
            .collect()
    }

    /// Total bytes resident across all SMPs (the paper's §6.2a memory-usage
    /// accounting).
    pub fn resident_bytes(&self) -> Result<usize> {
        let mut total = 0;
        for smp in self.smps.iter().flatten() {
            total += smp.stats()?.bytes_resident;
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ParallelPlan;
    use crate::util::rng::Rng;

    fn payload(len: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::seed_from(seed);
        (0..len).map(|_| rng.next_u64() as u8).collect()
    }

    fn dp6_cluster(raim5: bool) -> (ReftCluster, Vec<Vec<u8>>) {
        let topo = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
        let bytes = vec![60_000u64];
        let ft = FtConfig { raim5, bucket_bytes: 4096, ..FtConfig::default() };
        let cluster = ReftCluster::start(topo, &bytes, ft).unwrap();
        let payloads = vec![payload(60_000, 9)];
        (cluster, payloads)
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        let restored = c.restore_all(&[]).unwrap();
        assert_eq!(restored, payloads);
    }

    #[test]
    fn survives_single_node_loss_via_raim5() {
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        c.kill_node(3);
        let restored = c.restore_all(&[3]).unwrap();
        assert_eq!(restored, payloads, "decoded shard must be bit-identical");
    }

    #[test]
    fn two_losses_exceed_protection() {
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        c.kill_node(1);
        c.kill_node(4);
        assert!(c.restore_all(&[1, 4]).is_err());
    }

    #[test]
    fn without_raim5_node_loss_is_fatal_for_inmemory_path() {
        let (mut c, payloads) = dp6_cluster(false);
        c.snapshot_all(&payloads).unwrap();
        c.kill_node(0);
        assert!(c.restore_all(&[0]).is_err());
        // but the in-memory path still works with all nodes alive
        let (mut c2, payloads2) = dp6_cluster(false);
        c2.snapshot_all(&payloads2).unwrap();
        assert_eq!(c2.restore_all(&[]).unwrap(), payloads2);
    }

    #[test]
    fn restore_uses_latest_consistent_version() {
        let (mut c, mut payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        payloads[0] = payload(60_000, 77);
        c.snapshot_all(&payloads).unwrap();
        let restored = c.restore_all(&[]).unwrap();
        assert_eq!(restored, payloads);
    }

    #[test]
    fn replace_node_and_resnapshot() {
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        c.kill_node(2);
        let restored = c.restore_all(&[2]).unwrap();
        assert_eq!(restored, payloads);
        // elastic substitution: fresh node joins, next snapshot covers it
        c.replace_node(2).unwrap();
        assert_eq!(c.alive_nodes().len(), 6);
        c.snapshot_all(&payloads).unwrap();
        let again = c.restore_all(&[]).unwrap();
        assert_eq!(again, payloads);
    }

    #[test]
    fn multi_stage_3d_roundtrip_with_loss() {
        // 2 DP x 4 TP x 3 PP on the full testbed
        let topo = Topology::build(ParallelPlan::new(2, 4, 3), 6, 4).unwrap();
        let bytes = vec![40_000u64, 30_000, 50_000];
        let ft = FtConfig { bucket_bytes: 1024, ..FtConfig::default() };
        let mut c = ReftCluster::start(topo, &bytes, ft).unwrap();
        let payloads: Vec<Vec<u8>> = bytes
            .iter()
            .enumerate()
            .map(|(i, &b)| payload(b as usize, i as u64))
            .collect();
        c.snapshot_all(&payloads).unwrap();
        // lose one node: it belongs to exactly one SG here
        c.kill_node(4);
        let restored = c.restore_all(&[4]).unwrap();
        assert_eq!(restored, payloads);
    }

    #[test]
    fn memory_accounting_within_paper_bound() {
        // §6.2a: REFT uses at most ~3x (payload) of CPU memory per node
        // budget; with parity ~ payload/m extra, resident should be well
        // under 2x the total payload for one clean copy
        let (mut c, payloads) = dp6_cluster(true);
        c.snapshot_all(&payloads).unwrap();
        let resident = c.resident_bytes().unwrap();
        let payload_total: usize = payloads.iter().map(Vec::len).sum();
        assert!(resident >= payload_total);
        assert!(
            resident <= payload_total * 2,
            "{resident} vs payload {payload_total}"
        );
    }
}
