//! Elastic failure handling (paper §3 workflow + §4.2 "Elastic
//! Functionality"): status propagation, the recovery decision tree, and the
//! live recovery orchestrator that drives SMPs and RAIM5.
//!
//! Decision tree on failure (paper Fig. 2):
//! 1. **software failure** (UNHEALTHY): training processes died, SMPs alive →
//!    resume directly from the SMPs' clean snapshots;
//! 2. **hardware failure, <= 1 node per SG** (OFFLINE): a substitute node
//!    joins; its shard is rebuilt by the RAIM5 subtraction decoder from the
//!    surviving SG members;
//! 3. **protection exceeded** (>= 2 nodes in one SG, or RAIM5 disabled):
//!    fall back to the durable tier — the decision names **which** tier
//!    serves ([`DurableTier`]): the newest *complete* persistence manifest
//!    when the background engine has committed one (its atomic commit makes
//!    partial uploads invisible — see `crate::persist`), else the latest
//!    inline legacy checkpoint — so the controller telemetry can report the
//!    tier recovery actually used instead of one opaque "load checkpoint";
//! 4. nothing durable either → fatal (restart from scratch).

pub mod controller;

pub use controller::ReftCluster;

use crate::checkpoint::Storage;
use crate::topology::Topology;

/// Per-node rendezvous status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStatus {
    Healthy,
    /// training process dead, node + SMP alive
    Unhealthy,
    /// node lost
    Offline,
}

/// Which durable tier serves a checkpoint fallback.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DurableTier {
    /// a committed persistence-engine manifest (`crate::persist`) — the
    /// sharded, CRC-verified, parallel-loadable tier
    Manifest,
    /// a legacy inline `CheckpointFile` blob
    Legacy,
}

/// Which durable fallbacks exist, probed per tier, so the decision tree —
/// and the telemetry built on it — can say *which* tier a fallback will
/// use rather than a tier-blind "a checkpoint exists".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DurableAvailability {
    /// at least one committed persistence manifest exists for the model
    pub manifest: bool,
    /// at least one legacy inline checkpoint exists for the model
    pub legacy: bool,
}

impl DurableAvailability {
    pub fn none() -> DurableAvailability {
        DurableAvailability::default()
    }

    pub fn any(&self) -> bool {
        self.manifest || self.legacy
    }

    /// Probe a storage tier for `model`. Listing-only — neither tier's
    /// payload is fetched or verified here; the loader still degrades to
    /// older manifests or across tiers if the newest turns out corrupt.
    pub fn probe(storage: &dyn Storage, model: &str) -> DurableAvailability {
        DurableAvailability {
            manifest: !crate::persist::persisted_steps(storage, model).is_empty(),
            legacy: storage.latest_for(model).is_some(),
        }
    }

    /// The tier a checkpoint fallback would serve from: the manifest tier
    /// when a committed manifest exists (atomic, shard-verified, parallel
    /// load), else the legacy tier. The actual loader may still cross
    /// tiers when the legacy checkpoint holds strictly newer state
    /// (`persist::resolve_for_recovery`'s tie-break).
    fn preferred_tier(&self) -> Option<DurableTier> {
        if self.manifest {
            Some(DurableTier::Manifest)
        } else if self.legacy {
            Some(DurableTier::Legacy)
        } else {
            None
        }
    }
}

/// What recovery path to take.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecoveryDecision {
    /// everything healthy — nothing to do
    None,
    /// resume from SMP clean snapshots (software failures only)
    ResumeFromSmp,
    /// decode the listed (stage, lost node) shards via RAIM5, then resume
    DecodeRaim5 { lost: Vec<(usize, usize)> },
    /// in-memory protection exceeded — reload from the named durable tier
    LoadCheckpoint { tier: DurableTier },
    /// no checkpoint available in either durable tier
    Fatal,
}

fn durable_fallback(durable: DurableAvailability) -> RecoveryDecision {
    match durable.preferred_tier() {
        Some(tier) => RecoveryDecision::LoadCheckpoint { tier },
        None => RecoveryDecision::Fatal,
    }
}

/// The pure decision function (property-tested in `rust/tests/proptests.rs`).
pub fn decide(
    topo: &Topology,
    status: &[NodeStatus],
    raim5: bool,
    durable: DurableAvailability,
) -> RecoveryDecision {
    assert!(status.len() >= topo.nodes_in_use());
    let any_unhealthy = status.iter().any(|s| *s == NodeStatus::Unhealthy);
    let offline: Vec<usize> = status
        .iter()
        .enumerate()
        .filter(|(_, s)| **s == NodeStatus::Offline)
        .map(|(i, _)| i)
        .collect();

    if offline.is_empty() {
        if any_unhealthy {
            return RecoveryDecision::ResumeFromSmp;
        }
        return RecoveryDecision::None;
    }

    // hardware losses: check per-SG tolerance
    let mut lost = Vec::new();
    for sg in topo.sharding_groups() {
        let dead: Vec<usize> = sg
            .nodes
            .iter()
            .copied()
            .filter(|n| offline.contains(n))
            .collect();
        if dead.is_empty() {
            continue;
        }
        // single-node SGs have no peers to decode from
        if !raim5 || dead.len() > 1 || sg.len() < 2 {
            return durable_fallback(durable);
        }
        lost.push((sg.stage, dead[0]));
    }
    if lost.is_empty() {
        // offline nodes host no SG (idle spares) — treat as software-level
        return if any_unhealthy {
            RecoveryDecision::ResumeFromSmp
        } else {
            RecoveryDecision::None
        };
    }
    RecoveryDecision::DecodeRaim5 { lost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{storage::step_key, MemStorage};
    use crate::topology::ParallelPlan;

    fn topo_2x4x3() -> Topology {
        Topology::build(ParallelPlan::new(2, 4, 3), 6, 4).unwrap()
    }

    /// Legacy-only durable tier — what every pre-engine run has.
    fn legacy_only() -> DurableAvailability {
        DurableAvailability { manifest: false, legacy: true }
    }

    #[test]
    fn all_healthy_is_none() {
        let t = topo_2x4x3();
        let s = vec![NodeStatus::Healthy; 6];
        assert_eq!(decide(&t, &s, true, legacy_only()), RecoveryDecision::None);
    }

    #[test]
    fn software_failure_resumes_from_smp() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[2] = NodeStatus::Unhealthy;
        assert_eq!(decide(&t, &s, true, legacy_only()), RecoveryDecision::ResumeFromSmp);
        // multiple software failures still fine
        s[4] = NodeStatus::Unhealthy;
        assert_eq!(decide(&t, &s, true, legacy_only()), RecoveryDecision::ResumeFromSmp);
    }

    #[test]
    fn single_node_loss_decodes() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[0] = NodeStatus::Offline; // node 0 hosts stage 0 of DP path 0
        match decide(&t, &s, true, legacy_only()) {
            RecoveryDecision::DecodeRaim5 { lost } => {
                assert_eq!(lost, vec![(0, 0)]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn one_loss_per_sg_is_still_decodable() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        // nodes 0 (SG0, dp0) and 4 (SG1, dp1): different SGs -> decodable
        s[0] = NodeStatus::Offline;
        s[4] = NodeStatus::Offline;
        match decide(&t, &s, true, legacy_only()) {
            RecoveryDecision::DecodeRaim5 { lost } => {
                assert_eq!(lost.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn two_losses_same_sg_falls_back_to_named_tier() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        // SG0 = {node0 (dp0), node3 (dp1)}
        s[0] = NodeStatus::Offline;
        s[3] = NodeStatus::Offline;
        // manifest tier preferred whenever a committed manifest exists
        assert_eq!(
            decide(&t, &s, true, DurableAvailability { manifest: true, legacy: true }),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Manifest }
        );
        assert_eq!(
            decide(&t, &s, true, DurableAvailability { manifest: true, legacy: false }),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Manifest }
        );
        // legacy tier only when no manifest committed
        assert_eq!(
            decide(&t, &s, true, legacy_only()),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Legacy }
        );
        assert_eq!(
            decide(&t, &s, true, DurableAvailability::none()),
            RecoveryDecision::Fatal
        );
    }

    #[test]
    fn raim5_disabled_always_falls_back_on_hw_loss() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[1] = NodeStatus::Offline;
        assert_eq!(
            decide(&t, &s, false, legacy_only()),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Legacy }
        );
    }

    #[test]
    fn single_node_sg_cannot_decode() {
        // PP-6 strong scaling: each SG has exactly one node
        let t = Topology::build(ParallelPlan::new(1, 4, 6), 6, 4).unwrap();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[2] = NodeStatus::Offline;
        assert_eq!(
            decide(&t, &s, true, legacy_only()),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Legacy }
        );
    }

    #[test]
    fn probe_reports_each_tier_independently() {
        let s = MemStorage::new();
        assert_eq!(DurableAvailability::probe(&s, "m"), DurableAvailability::none());
        assert!(!DurableAvailability::probe(&s, "m").any());
        // a legacy inline checkpoint lights the legacy tier only
        s.put(&step_key("m", 7), b"ckpt").unwrap();
        let d = DurableAvailability::probe(&s, "m");
        assert_eq!(d, DurableAvailability { manifest: false, legacy: true });
        // a committed manifest lights the manifest tier (and wins)
        s.put(&crate::persist::manifest_key("m", 9), b"{}").unwrap();
        let d = DurableAvailability::probe(&s, "m");
        assert!(d.manifest && d.legacy);
        assert_eq!(d.preferred_tier(), Some(DurableTier::Manifest));
        // other models' artifacts don't bleed over
        assert_eq!(DurableAvailability::probe(&s, "other"), DurableAvailability::none());
    }
}
