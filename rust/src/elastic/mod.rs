//! Elastic failure handling (paper §3 workflow + §4.2 "Elastic
//! Functionality"): status propagation, the recovery decision tree, and the
//! live recovery orchestrator that drives SMPs and RAIM5.
//!
//! Decision tree on failure (paper Fig. 2):
//! 1. **software failure** (UNHEALTHY): training processes died, SMPs alive →
//!    resume directly from the SMPs' clean snapshots;
//! 2. **hardware failure, <= 1 node per SG** (OFFLINE): a substitute node
//!    joins; its shard is rebuilt by the RAIM5 subtraction decoder from the
//!    surviving SG members;
//! 3. **protection exceeded** (>= 2 nodes in one SG, or RAIM5 disabled):
//!    fall back to the durable tier — the newest *complete* persistence
//!    manifest when the background engine is on (its atomic commit makes
//!    partial uploads invisible — see `crate::persist`), else the latest
//!    inline checkpoint;
//! 4. nothing durable either → fatal (restart from scratch).

pub mod controller;

pub use controller::ReftCluster;

use crate::topology::Topology;

/// Per-node rendezvous status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStatus {
    Healthy,
    /// training process dead, node + SMP alive
    Unhealthy,
    /// node lost
    Offline,
}

/// What recovery path to take.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecoveryDecision {
    /// everything healthy — nothing to do
    None,
    /// resume from SMP clean snapshots (software failures only)
    ResumeFromSmp,
    /// decode the listed (stage, lost node) shards via RAIM5, then resume
    DecodeRaim5 { lost: Vec<(usize, usize)> },
    /// in-memory protection exceeded — reload the durable checkpoint
    LoadCheckpoint,
    /// no checkpoint available either
    Fatal,
}

/// The pure decision function (property-tested in `rust/tests/proptests.rs`).
pub fn decide(
    topo: &Topology,
    status: &[NodeStatus],
    raim5: bool,
    ckpt_available: bool,
) -> RecoveryDecision {
    assert!(status.len() >= topo.nodes_in_use());
    let any_unhealthy = status.iter().any(|s| *s == NodeStatus::Unhealthy);
    let offline: Vec<usize> = status
        .iter()
        .enumerate()
        .filter(|(_, s)| **s == NodeStatus::Offline)
        .map(|(i, _)| i)
        .collect();

    if offline.is_empty() {
        if any_unhealthy {
            return RecoveryDecision::ResumeFromSmp;
        }
        return RecoveryDecision::None;
    }

    // hardware losses: check per-SG tolerance
    let mut lost = Vec::new();
    for sg in topo.sharding_groups() {
        let dead: Vec<usize> = sg
            .nodes
            .iter()
            .copied()
            .filter(|n| offline.contains(n))
            .collect();
        if dead.is_empty() {
            continue;
        }
        // single-node SGs have no peers to decode from
        if !raim5 || dead.len() > 1 || sg.len() < 2 {
            return if ckpt_available {
                RecoveryDecision::LoadCheckpoint
            } else {
                RecoveryDecision::Fatal
            };
        }
        lost.push((sg.stage, dead[0]));
    }
    if lost.is_empty() {
        // offline nodes host no SG (idle spares) — treat as software-level
        return if any_unhealthy {
            RecoveryDecision::ResumeFromSmp
        } else {
            RecoveryDecision::None
        };
    }
    RecoveryDecision::DecodeRaim5 { lost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ParallelPlan;

    fn topo_2x4x3() -> Topology {
        Topology::build(ParallelPlan::new(2, 4, 3), 6, 4).unwrap()
    }

    #[test]
    fn all_healthy_is_none() {
        let t = topo_2x4x3();
        let s = vec![NodeStatus::Healthy; 6];
        assert_eq!(decide(&t, &s, true, true), RecoveryDecision::None);
    }

    #[test]
    fn software_failure_resumes_from_smp() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[2] = NodeStatus::Unhealthy;
        assert_eq!(decide(&t, &s, true, true), RecoveryDecision::ResumeFromSmp);
        // multiple software failures still fine
        s[4] = NodeStatus::Unhealthy;
        assert_eq!(decide(&t, &s, true, true), RecoveryDecision::ResumeFromSmp);
    }

    #[test]
    fn single_node_loss_decodes() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[0] = NodeStatus::Offline; // node 0 hosts stage 0 of DP path 0
        match decide(&t, &s, true, true) {
            RecoveryDecision::DecodeRaim5 { lost } => {
                assert_eq!(lost, vec![(0, 0)]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn one_loss_per_sg_is_still_decodable() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        // nodes 0 (SG0, dp0) and 4 (SG1, dp1): different SGs -> decodable
        s[0] = NodeStatus::Offline;
        s[4] = NodeStatus::Offline;
        match decide(&t, &s, true, true) {
            RecoveryDecision::DecodeRaim5 { lost } => {
                assert_eq!(lost.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn two_losses_same_sg_falls_back() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        // SG0 = {node0 (dp0), node3 (dp1)}
        s[0] = NodeStatus::Offline;
        s[3] = NodeStatus::Offline;
        assert_eq!(decide(&t, &s, true, true), RecoveryDecision::LoadCheckpoint);
        assert_eq!(decide(&t, &s, true, false), RecoveryDecision::Fatal);
    }

    #[test]
    fn raim5_disabled_always_falls_back_on_hw_loss() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[1] = NodeStatus::Offline;
        assert_eq!(decide(&t, &s, false, true), RecoveryDecision::LoadCheckpoint);
    }

    #[test]
    fn single_node_sg_cannot_decode() {
        // PP-6 strong scaling: each SG has exactly one node
        let t = Topology::build(ParallelPlan::new(1, 4, 6), 6, 4).unwrap();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[2] = NodeStatus::Offline;
        assert_eq!(decide(&t, &s, true, true), RecoveryDecision::LoadCheckpoint);
    }
}
