//! Elastic failure handling (paper §3 workflow + §4.2 "Elastic
//! Functionality"): status propagation, the recovery decision tree, and the
//! live recovery orchestrator that drives SMPs and RAIM5.
//!
//! Decision tree on failure (paper Fig. 2):
//! 1. **software failure** (UNHEALTHY): training processes died, SMPs alive →
//!    resume directly from the SMPs' clean snapshots;
//! 2. **hardware failure, <= 1 node per SG** (OFFLINE): a substitute node
//!    joins; its shard is rebuilt by the RAIM5 subtraction decoder from the
//!    surviving SG members;
//! 3. **protection exceeded** (>= 2 nodes in one SG, or RAIM5 disabled):
//!    fall back to the durable tier — the decision names **which** tier
//!    serves ([`DurableTier`]): the newest *complete* persistence manifest
//!    when the background engine has committed one (its atomic commit makes
//!    partial uploads invisible — see `crate::persist`), else the latest
//!    inline legacy checkpoint — so the controller telemetry can report the
//!    tier recovery actually used instead of one opaque "load checkpoint";
//! 4. nothing durable either → fatal (restart from scratch).
//!
//! With `ft.reshape_on_restore` on, case 3's manifest leaf is shape-aware
//! ([`decide_elastic`]): a manifest persisted under a **different** dp/tp/pp
//! split plans onto the [`RecoveryDecision::Reshape`] leaf — the
//! redistribution pass in `crate::persist::reshape` regathers it into the
//! surviving fleet's shape — instead of being skipped (which used to force
//! an elastic shrink/grow to abort to a fresh run).

pub mod controller;

pub use controller::ReftCluster;

use crate::checkpoint::Storage;
use crate::metrics::{keys, Metrics};
use crate::obs;
use crate::topology::Topology;

/// Per-node rendezvous status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStatus {
    Healthy,
    /// training process dead, node + SMP alive
    Unhealthy,
    /// node lost
    Offline,
}

/// Which durable tier serves a checkpoint fallback.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DurableTier {
    /// a committed persistence-engine manifest (`crate::persist`) — the
    /// sharded, CRC-verified, parallel-loadable tier
    Manifest,
    /// a legacy inline `CheckpointFile` blob
    Legacy,
}

/// Which durable fallbacks exist, probed per tier, so the decision tree —
/// and the telemetry built on it — can say *which* tier a fallback will
/// use rather than a tier-blind "a checkpoint exists".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DurableAvailability {
    /// at least one committed, *decodable* persistence manifest exists for
    /// the model (a torn or garbage manifest blob does not count — it could
    /// never serve a recovery)
    pub manifest: bool,
    /// at least one legacy inline checkpoint exists for the model
    pub legacy: bool,
    /// the step whose state the newest decodable manifest actually
    /// contains (`snapshot_step`) — the cross-tier tie-break input
    pub manifest_step: Option<u64>,
    /// the step of the newest legacy inline checkpoint
    pub legacy_step: Option<u64>,
    /// the stage count the newest decodable manifest was persisted under —
    /// the reshape-on-restore input: when it differs from the recovering
    /// run's stage count, a shape-matched restore is impossible but a
    /// [`RecoveryDecision::Reshape`] may still serve
    pub manifest_stages: Option<usize>,
}

impl DurableAvailability {
    pub fn none() -> DurableAvailability {
        DurableAvailability::default()
    }

    pub fn any(&self) -> bool {
        self.manifest || self.legacy
    }

    /// Probe the durable tiers for `model`. Metadata-only on the payload
    /// side — no shard bytes are fetched or CRC-verified — but the newest
    /// manifests ARE decoded (small JSON documents) so a torn manifest
    /// cannot masquerade as an available tier, and so the tie-break can
    /// compare the *contained* steps the way the loader will. The loader
    /// still degrades to older manifests or across tiers if shards turn
    /// out corrupt.
    pub fn probe(storage: &dyn Storage, model: &str) -> DurableAvailability {
        let mut manifest_step = None;
        let mut manifest_stages = None;
        for step in crate::persist::persisted_steps(storage, model).into_iter().rev() {
            let decoded = storage
                .get(&crate::persist::manifest_key(model, step))
                .ok()
                .and_then(|b| crate::persist::PersistManifest::decode(&b).ok());
            if let Some(man) = decoded {
                manifest_step = Some(man.snapshot_step);
                manifest_stages = Some(man.stage_bytes.len());
                break;
            }
        }
        let legacy_key = storage.latest_for(model);
        let legacy_step = legacy_key
            .as_deref()
            .and_then(|k| crate::persist::step_of_key(k, &format!("{model}/step-")));
        DurableAvailability {
            manifest: manifest_step.is_some(),
            legacy: legacy_key.is_some(),
            manifest_step,
            legacy_step,
            manifest_stages,
        }
    }

    /// The tier a checkpoint fallback would serve from, mirroring
    /// `persist::resolve_for_recovery`'s cross-tier tie-break: the manifest
    /// tier (atomic, shard-verified, parallel load) unless the legacy
    /// inline checkpoint holds strictly newer state than the manifest's
    /// contained `snapshot_step`.
    pub fn preferred_tier(&self) -> Option<DurableTier> {
        match (self.manifest, self.legacy) {
            (true, true) => match (self.manifest_step, self.legacy_step) {
                (Some(m), Some(l)) if l > m => Some(DurableTier::Legacy),
                _ => Some(DurableTier::Manifest),
            },
            (true, false) => Some(DurableTier::Manifest),
            (false, true) => Some(DurableTier::Legacy),
            (false, false) => None,
        }
    }
}

/// What recovery path to take.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecoveryDecision {
    /// everything healthy — nothing to do
    None,
    /// resume from SMP clean snapshots (software failures only)
    ResumeFromSmp,
    /// decode the listed (stage, lost node) shards via RAIM5, then resume
    DecodeRaim5 { lost: Vec<(usize, usize)> },
    /// in-memory protection exceeded — reload from the named durable tier
    LoadCheckpoint { tier: DurableTier },
    /// in-memory protection exceeded AND the newest manifest was persisted
    /// under a different stage shape: redistribute it into the recovering
    /// run's shape through the reshape pass (`persist::reshape`) instead of
    /// aborting to a fresh run — the elastic shrink/grow-and-continue leaf,
    /// taken only when `ft.reshape_on_restore` is on
    Reshape { from_stages: usize, to_stages: usize },
    /// no checkpoint available in either durable tier
    Fatal,
}

fn durable_fallback(durable: DurableAvailability) -> RecoveryDecision {
    match durable.preferred_tier() {
        Some(tier) => RecoveryDecision::LoadCheckpoint { tier },
        None => RecoveryDecision::Fatal,
    }
}

/// The pure decision function (property-tested in `rust/tests/proptests.rs`).
pub fn decide(
    topo: &Topology,
    status: &[NodeStatus],
    raim5: bool,
    durable: DurableAvailability,
) -> RecoveryDecision {
    assert!(status.len() >= topo.nodes_in_use());
    let any_unhealthy = status.iter().any(|s| *s == NodeStatus::Unhealthy);
    let offline: Vec<usize> = status
        .iter()
        .enumerate()
        .filter(|(_, s)| **s == NodeStatus::Offline)
        .map(|(i, _)| i)
        .collect();

    if offline.is_empty() {
        if any_unhealthy {
            return RecoveryDecision::ResumeFromSmp;
        }
        return RecoveryDecision::None;
    }

    // hardware losses: check per-SG tolerance
    let mut lost = Vec::new();
    for sg in topo.sharding_groups() {
        let dead: Vec<usize> = sg
            .nodes
            .iter()
            .copied()
            .filter(|n| offline.contains(n))
            .collect();
        if dead.is_empty() {
            continue;
        }
        // single-node SGs have no peers to decode from
        if !raim5 || dead.len() > 1 || sg.len() < 2 {
            return durable_fallback(durable);
        }
        lost.push((sg.stage, dead[0]));
    }
    if lost.is_empty() {
        // offline nodes host no SG (idle spares) — treat as software-level
        return if any_unhealthy {
            RecoveryDecision::ResumeFromSmp
        } else {
            RecoveryDecision::None
        };
    }
    RecoveryDecision::DecodeRaim5 { lost }
}

/// [`decide`], shape-aware: when the tree lands on the manifest tier but
/// the newest manifest was persisted under a different stage count than
/// the `expected_stages` this run is shaped for, the shape-matched load
/// would find nothing — with `reshape_on_restore` on, the decision becomes
/// [`RecoveryDecision::Reshape`] (redistribute and continue); off, the
/// verdict is unchanged (the loader degrades to older shape-matched
/// manifests or the legacy tier, the pre-reshape behavior).
pub fn decide_elastic(
    topo: &Topology,
    status: &[NodeStatus],
    raim5: bool,
    durable: DurableAvailability,
    expected_stages: usize,
    reshape_on_restore: bool,
) -> RecoveryDecision {
    let base = decide(topo, status, raim5, durable);
    if !reshape_on_restore {
        return base;
    }
    match (&base, durable.manifest_stages) {
        (
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Manifest },
            Some(from_stages),
        ) if from_stages != expected_stages => {
            RecoveryDecision::Reshape { from_stages, to_stages: expected_stages }
        }
        _ => base,
    }
}

/// Where a recovery actually got its bytes from — the "actual" side of the
/// control plane's predicted-vs-actual telemetry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryPath {
    /// SMP restore / RAIM5 decode — no storage touched
    InMemory,
    /// the durable tier, naming which one served
    Durable(DurableTier),
}

/// The decision-tree output the trainers compute **before** any restore
/// attempt (ROADMAP: recovery used to try-restore then fall back): the
/// probed durable availability plus the pure [`decide`] verdict, with
/// telemetry hooks recording the predicted tier and counting mispredictions
/// against the tier recovery actually used.
#[derive(Debug, Clone)]
pub struct RecoveryPlan {
    pub decision: RecoveryDecision,
    pub durable: DurableAvailability,
}

impl RecoveryPlan {
    /// Probe the durable tiers and run the decision tree for a trainer
    /// recovery: `dead` nodes are OFFLINE, every survivor is UNHEALTHY
    /// (failure injection collapses training cluster-wide — recovery is
    /// only ever called with training down).
    pub fn probe(
        topo: &Topology,
        dead: &[usize],
        raim5: bool,
        storage: &dyn Storage,
        model: &str,
    ) -> RecoveryPlan {
        let durable = DurableAvailability::probe(storage, model);
        let mut status = vec![NodeStatus::Unhealthy; topo.nodes];
        for &n in dead {
            if n < status.len() {
                status[n] = NodeStatus::Offline;
            }
        }
        RecoveryPlan { decision: decide(topo, &status, raim5, durable), durable }
    }

    /// [`RecoveryPlan::probe`], shape-aware: runs [`decide_elastic`] so a
    /// manifest persisted under a different stage shape plans onto the
    /// [`RecoveryDecision::Reshape`] leaf when `reshape_on_restore` allows
    /// it — the entry point both trainers use.
    pub fn probe_elastic(
        topo: &Topology,
        dead: &[usize],
        raim5: bool,
        storage: &dyn Storage,
        model: &str,
        expected_stages: usize,
        reshape_on_restore: bool,
    ) -> RecoveryPlan {
        let durable = DurableAvailability::probe(storage, model);
        let mut status = vec![NodeStatus::Unhealthy; topo.nodes];
        for &n in dead {
            if n < status.len() {
                status[n] = NodeStatus::Offline;
            }
        }
        RecoveryPlan {
            decision: decide_elastic(
                topo,
                &status,
                raim5,
                durable,
                expected_stages,
                reshape_on_restore,
            ),
            durable,
        }
    }

    /// A plan for a run with no in-memory fabric at all (non-REFT methods):
    /// the durable tier is the only option, so the tree degenerates to the
    /// fallback leaf.
    pub fn durable_only(storage: &dyn Storage, model: &str) -> RecoveryPlan {
        let durable = DurableAvailability::probe(storage, model);
        RecoveryPlan { decision: durable_fallback(durable), durable }
    }

    /// The path this plan predicts recovery will take; `None` means the
    /// tree bottomed out (nothing in memory, nothing durable).
    pub fn predicted(&self) -> Option<RecoveryPath> {
        match &self.decision {
            RecoveryDecision::None
            | RecoveryDecision::ResumeFromSmp
            | RecoveryDecision::DecodeRaim5 { .. } => Some(RecoveryPath::InMemory),
            RecoveryDecision::LoadCheckpoint { tier } => Some(RecoveryPath::Durable(*tier)),
            // a reshape serves from the manifest tier — the redistribution
            // pass is a manifest load with a different target tiling
            RecoveryDecision::Reshape { .. } => {
                Some(RecoveryPath::Durable(DurableTier::Manifest))
            }
            RecoveryDecision::Fatal => None,
        }
    }

    /// Record the prediction (`recovery_predicted_*` counters) and leave a
    /// plan-decision event in the flight recorder (arg encodes the leaf:
    /// 0 in-memory, 1 manifest, 2 legacy, 3 fatal, 4 reshape).
    pub fn record_predicted(&self, metrics: &Metrics) {
        metrics.inc_k(keys::RECOVERY_PLANS, 1);
        let (key, code) = match (&self.decision, self.predicted()) {
            (RecoveryDecision::Reshape { .. }, _) => {
                (keys::RECOVERY_PREDICTED_MANIFEST, 4)
            }
            (_, Some(RecoveryPath::InMemory)) => (keys::RECOVERY_PREDICTED_INMEMORY, 0),
            (_, Some(RecoveryPath::Durable(DurableTier::Manifest))) => {
                (keys::RECOVERY_PREDICTED_MANIFEST, 1)
            }
            (_, Some(RecoveryPath::Durable(DurableTier::Legacy))) => {
                (keys::RECOVERY_PREDICTED_LEGACY, 2)
            }
            (_, None) => (keys::RECOVERY_PREDICTED_FATAL, 3),
        };
        metrics.inc_k(key, 1);
        obs::instant(obs::cat::ELASTIC, "plan", 0, code);
    }

    /// Record the path recovery actually took; a mismatch with the
    /// prediction bumps `recovery_mispredictions` — the counter that says
    /// the probe and the loader disagreed (stale probe, shard corruption
    /// found at load time, shape-filtered manifest, ...).
    pub fn record_actual(&self, metrics: &Metrics, actual: RecoveryPath) {
        if self.predicted() != Some(actual) {
            metrics.inc_k(keys::RECOVERY_MISPREDICTIONS, 1);
            obs::instant(obs::cat::ELASTIC, "mispredict", 0, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{storage::step_key, MemStorage};
    use crate::topology::ParallelPlan;

    fn topo_2x4x3() -> Topology {
        Topology::build(ParallelPlan::new(2, 4, 3), 6, 4).unwrap()
    }

    /// Legacy-only durable tier — what every pre-engine run has.
    fn legacy_only() -> DurableAvailability {
        DurableAvailability { legacy: true, legacy_step: Some(1), ..Default::default() }
    }

    /// Both tiers present, manifest containing the newer state.
    fn both_tiers() -> DurableAvailability {
        DurableAvailability {
            manifest: true,
            legacy: true,
            manifest_step: Some(10),
            legacy_step: Some(5),
            manifest_stages: Some(3),
        }
    }

    /// A minimal valid one-shard manifest whose blob decodes cleanly.
    fn tiny_manifest(step: u64, snapshot_step: u64) -> crate::persist::PersistManifest {
        crate::persist::PersistManifest {
            model: "m".into(),
            step,
            version: 1,
            snapshot_step,
            stage_bytes: vec![4],
            shards: vec![crate::persist::ShardEntry {
                key: crate::persist::shard_key("m", step, 0, 0),
                stage: 0,
                node: 0,
                offset: 0,
                len: 4,
                crc32: crc32fast::hash(&[7; 4]),
                extents: vec![],
                parts: vec![],
            }],
            base_step: None,
            atoms: vec![],
        }
    }

    #[test]
    fn all_healthy_is_none() {
        let t = topo_2x4x3();
        let s = vec![NodeStatus::Healthy; 6];
        assert_eq!(decide(&t, &s, true, legacy_only()), RecoveryDecision::None);
    }

    #[test]
    fn software_failure_resumes_from_smp() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[2] = NodeStatus::Unhealthy;
        assert_eq!(decide(&t, &s, true, legacy_only()), RecoveryDecision::ResumeFromSmp);
        // multiple software failures still fine
        s[4] = NodeStatus::Unhealthy;
        assert_eq!(decide(&t, &s, true, legacy_only()), RecoveryDecision::ResumeFromSmp);
    }

    #[test]
    fn single_node_loss_decodes() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[0] = NodeStatus::Offline; // node 0 hosts stage 0 of DP path 0
        match decide(&t, &s, true, legacy_only()) {
            RecoveryDecision::DecodeRaim5 { lost } => {
                assert_eq!(lost, vec![(0, 0)]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn one_loss_per_sg_is_still_decodable() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        // nodes 0 (SG0, dp0) and 4 (SG1, dp1): different SGs -> decodable
        s[0] = NodeStatus::Offline;
        s[4] = NodeStatus::Offline;
        match decide(&t, &s, true, legacy_only()) {
            RecoveryDecision::DecodeRaim5 { lost } => {
                assert_eq!(lost.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn two_losses_same_sg_falls_back_to_named_tier() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        // SG0 = {node0 (dp0), node3 (dp1)}
        s[0] = NodeStatus::Offline;
        s[3] = NodeStatus::Offline;
        // manifest tier preferred whenever a committed manifest exists
        assert_eq!(
            decide(&t, &s, true, both_tiers()),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Manifest }
        );
        assert_eq!(
            decide(
                &t,
                &s,
                true,
                DurableAvailability { manifest: true, manifest_step: Some(10), ..Default::default() }
            ),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Manifest }
        );
        // ...unless the legacy inline checkpoint holds strictly newer state
        // (the loader's cross-tier tie-break, mirrored in the prediction)
        assert_eq!(
            decide(
                &t,
                &s,
                true,
                DurableAvailability {
                    manifest: true,
                    legacy: true,
                    manifest_step: Some(10),
                    legacy_step: Some(11),
                }
            ),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Legacy }
        );
        // legacy tier only when no manifest committed
        assert_eq!(
            decide(&t, &s, true, legacy_only()),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Legacy }
        );
        assert_eq!(
            decide(&t, &s, true, DurableAvailability::none()),
            RecoveryDecision::Fatal
        );
    }

    #[test]
    fn raim5_disabled_always_falls_back_on_hw_loss() {
        let t = topo_2x4x3();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[1] = NodeStatus::Offline;
        assert_eq!(
            decide(&t, &s, false, legacy_only()),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Legacy }
        );
    }

    #[test]
    fn single_node_sg_cannot_decode() {
        // PP-6 strong scaling: each SG has exactly one node
        let t = Topology::build(ParallelPlan::new(1, 4, 6), 6, 4).unwrap();
        let mut s = vec![NodeStatus::Healthy; 6];
        s[2] = NodeStatus::Offline;
        assert_eq!(
            decide(&t, &s, true, legacy_only()),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Legacy }
        );
    }

    #[test]
    fn probe_reports_each_tier_independently() {
        let s = MemStorage::new();
        // empty store: nothing available, preferred tier is None
        assert_eq!(DurableAvailability::probe(&s, "m"), DurableAvailability::none());
        assert!(!DurableAvailability::probe(&s, "m").any());
        assert_eq!(DurableAvailability::probe(&s, "m").preferred_tier(), None);
        // a legacy inline checkpoint lights the legacy tier only
        s.put(&step_key("m", 7), b"ckpt").unwrap();
        let d = DurableAvailability::probe(&s, "m");
        assert_eq!((d.manifest, d.legacy, d.legacy_step), (false, true, Some(7)));
        assert_eq!(d.preferred_tier(), Some(DurableTier::Legacy));
        // a committed manifest lights the manifest tier (and wins while its
        // contained state is at least as new)
        s.put(&crate::persist::manifest_key("m", 9), &tiny_manifest(9, 9).encode())
            .unwrap();
        let d = DurableAvailability::probe(&s, "m");
        assert!(d.manifest && d.legacy);
        assert_eq!(d.manifest_step, Some(9));
        assert_eq!(d.preferred_tier(), Some(DurableTier::Manifest));
        // other models' artifacts don't bleed over
        assert_eq!(DurableAvailability::probe(&s, "other"), DurableAvailability::none());
    }

    #[test]
    fn probe_skips_torn_manifests() {
        let s = MemStorage::new();
        // a torn/partial manifest blob (crash mid-put on a non-atomic
        // backend, or bit rot) must not light the manifest tier...
        s.put(&crate::persist::manifest_key("m", 9), b"{\"model\": \"m\"").unwrap();
        let d = DurableAvailability::probe(&s, "m");
        assert!(!d.manifest, "torn manifest counted as available");
        assert_eq!(d.preferred_tier(), None);
        // ...and with an older DECODABLE manifest behind it, the probe
        // degrades to that one, exactly like the loader will
        s.put(&crate::persist::manifest_key("m", 5), &tiny_manifest(5, 4).encode())
            .unwrap();
        let d = DurableAvailability::probe(&s, "m");
        assert!(d.manifest);
        assert_eq!(d.manifest_step, Some(4));
    }

    #[test]
    fn probe_tie_break_tracks_contained_state_not_key_order() {
        let s = MemStorage::new();
        // manifest requested at step 40 but containing step-38 state
        // (async drain lag); legacy checkpoint at 39 is strictly newer
        s.put(&crate::persist::manifest_key("m", 40), &tiny_manifest(40, 38).encode())
            .unwrap();
        s.put(&step_key("m", 39), b"ckpt").unwrap();
        let d = DurableAvailability::probe(&s, "m");
        assert_eq!((d.manifest_step, d.legacy_step), (Some(38), Some(39)));
        assert_eq!(d.preferred_tier(), Some(DurableTier::Legacy), "legacy holds newer state");
        // vice versa: legacy at 37 -> the manifest tier serves
        s.delete(&step_key("m", 39)).unwrap();
        s.put(&step_key("m", 37), b"ckpt").unwrap();
        let d = DurableAvailability::probe(&s, "m");
        assert_eq!(d.preferred_tier(), Some(DurableTier::Manifest));
    }

    #[test]
    fn recovery_plan_predicts_and_counts_mispredictions() {
        let t = topo_2x4x3();
        let s = MemStorage::new();
        s.put(&crate::persist::manifest_key("m", 9), &tiny_manifest(9, 9).encode())
            .unwrap();
        // software failure (no dead nodes): in-memory predicted
        let plan = RecoveryPlan::probe(&t, &[], true, &s, "m");
        assert_eq!(plan.decision, RecoveryDecision::ResumeFromSmp);
        assert_eq!(plan.predicted(), Some(RecoveryPath::InMemory));
        // both nodes of SG0 dead: the manifest tier predicted up front
        let plan = RecoveryPlan::probe(&t, &[0, 3], true, &s, "m");
        assert_eq!(plan.predicted(), Some(RecoveryPath::Durable(DurableTier::Manifest)));
        let m = Metrics::new();
        plan.record_predicted(&m);
        assert_eq!(m.counter("recovery_plans"), 1);
        assert_eq!(m.counter("recovery_predicted_manifest"), 1);
        // actual == predicted: no misprediction
        plan.record_actual(&m, RecoveryPath::Durable(DurableTier::Manifest));
        assert_eq!(m.counter("recovery_mispredictions"), 0);
        // the loader crossed tiers (e.g. shards corrupt): counted
        plan.record_actual(&m, RecoveryPath::Durable(DurableTier::Legacy));
        assert_eq!(m.counter("recovery_mispredictions"), 1);
        // no REFT fabric: the plan degenerates to the durable leaf
        let plan = RecoveryPlan::durable_only(&s, "m");
        assert_eq!(
            plan.decision,
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Manifest }
        );
        // nothing durable, protection exceeded: fatal predicted
        let empty = MemStorage::new();
        let plan = RecoveryPlan::probe(&t, &[0, 3], true, &empty, "m");
        assert_eq!(plan.decision, RecoveryDecision::Fatal);
        assert_eq!(plan.predicted(), None);
    }

    #[test]
    fn shape_mismatch_reshapes_only_behind_the_knob() {
        let t = topo_2x4x3(); // 3 pp stages
        let mut s = vec![NodeStatus::Healthy; 6];
        // SG0 = {node0, node3}: protection exceeded
        s[0] = NodeStatus::Offline;
        s[3] = NodeStatus::Offline;
        let d = both_tiers(); // newest manifest persisted under 3 stages
        // same shape: the knob changes nothing
        assert_eq!(
            decide_elastic(&t, &s, true, d, 3, true),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Manifest }
        );
        // recovering at 2 stages, knob off: the pre-reshape verdict stands
        // (the loader will degrade or cross tiers, never redistribute)
        assert_eq!(
            decide_elastic(&t, &s, true, d, 2, false),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Manifest }
        );
        // knob on: the shape mismatch becomes the Reshape leaf
        assert_eq!(
            decide_elastic(&t, &s, true, d, 2, true),
            RecoveryDecision::Reshape { from_stages: 3, to_stages: 2 }
        );
        // the legacy tie-break outranks reshape: strictly newer inline
        // state serves from legacy exactly as before
        let legacy_newer = DurableAvailability {
            legacy_step: Some(11),
            ..both_tiers()
        };
        assert_eq!(
            decide_elastic(&t, &s, true, legacy_newer, 2, true),
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Legacy }
        );
    }

    #[test]
    fn probe_elastic_plans_reshape_and_predicts_manifest_tier() {
        let t = topo_2x4x3();
        let s = MemStorage::new();
        // a 1-stage manifest committed; this run is shaped for 3 stages
        s.put(&crate::persist::manifest_key("m", 9), &tiny_manifest(9, 9).encode())
            .unwrap();
        let plan = RecoveryPlan::probe_elastic(&t, &[0, 3], true, &s, "m", 3, true);
        assert_eq!(
            plan.decision,
            RecoveryDecision::Reshape { from_stages: 1, to_stages: 3 }
        );
        assert_eq!(
            plan.predicted(),
            Some(RecoveryPath::Durable(DurableTier::Manifest)),
            "a reshape serves from the manifest tier"
        );
        let m = Metrics::new();
        plan.record_predicted(&m);
        assert_eq!(m.counter("recovery_predicted_manifest"), 1);
        // a manifest-tier restore is NOT a misprediction of a reshape plan
        plan.record_actual(&m, RecoveryPath::Durable(DurableTier::Manifest));
        assert_eq!(m.counter("recovery_mispredictions"), 0);
        // knob off: same probe degrades to the shape-blind decision
        let plan = RecoveryPlan::probe_elastic(&t, &[0, 3], true, &s, "m", 3, false);
        assert_eq!(
            plan.decision,
            RecoveryDecision::LoadCheckpoint { tier: DurableTier::Manifest }
        );
    }
}
