//! # REFT — Reliable and Efficient in-memory Fault Tolerance
//!
//! A production-shaped reproduction of *"Reliable and Efficient In-Memory
//! Fault Tolerance of Large Language Model Pretraining"* (Wang et al., 2023)
//! as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the REFT coordinator: 3D-parallel training
//!   orchestration, sharded in-memory snapshotting, snapshot management
//!   processes (SMPs), RAIM5 erasure coding, checkpoint baselines
//!   (CheckFreq / TorchSnapshot), elastic failure recovery, and the
//!   hardware/failure simulator that stands in for the paper's V100 testbed.
//! * **Layer 2** — an OPT-style transformer written in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text per pipeline stage.
//! * **Layer 1** — Pallas kernels (flash attention, fused Adam) embedded in
//!   the Layer-2 HLO (`python/compile/kernels/`).
//!
//! Python never runs at training time: the [`runtime`] module loads the HLO
//! artifacts via the PJRT C API (`xla` crate) and executes them from rust.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to a bench target.

pub mod checkpoint;
pub mod collective;
pub mod config;
pub mod ec;
pub mod elastic;
pub mod hwsim;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod persist;
pub mod pipeline;
pub mod reliability;
pub mod runtime;
pub mod smp;
pub mod snapshot;
pub mod soak;
pub mod topology;
pub mod trainer;
pub mod util;

pub use config::RunConfig;
