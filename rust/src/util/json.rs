//! Minimal JSON parser + writer (serde is not in the vendored crate set).
//!
//! Two tiers:
//!
//! * **DOM** (`Json::parse` / `Display`): full JSON data model with the
//!   restrictions that suit our inputs — UTF-8 text, `\uXXXX` escapes decoded
//!   for the BMP (surrogate pairs supported), numbers parsed as f64. Used for
//!   config and anything low-volume.
//! * **Streaming** ([`JsonWriter`] / [`JsonReader`]): push serializer and pull
//!   parser that never build an intermediate tree, for the hot persist path
//!   (`PersistManifest` / `PartProgress`). Writer output is byte-identical to
//!   `Display` on the equivalent DOM value when keys are emitted in sorted
//!   order; integers stay exact over the full u64 range.

use std::collections::BTreeMap;
use std::fmt;

/// Largest integer an f64 represents exactly (2^53). The strict DOM integer
/// accessors refuse anything above it (an f64 round-trip could have silently
/// rounded such a value); `JsonReader::u64` parses digit runs natively and is
/// exact over the full u64 range.
const MAX_EXACT_INT: f64 = 9_007_199_254_740_992.0;

/// A parsed JSON value. Objects use a BTreeMap for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Exactly-representable unsigned integer. Rejects NaN/±inf, negatives,
    /// fractional values, and anything above 2^53 (where f64 stops being
    /// exact) instead of silently truncating like `as_f64() as u64` would.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n)
                if n.is_finite() && *n >= 0.0 && n.fract() == 0.0 && *n <= MAX_EXACT_INT =>
            {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_u64().and_then(|v| u32::try_from(v).ok())
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- required accessors (error messages name the key) -------------------

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field `{key}`"))
    }

    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid unsigned integer field `{key}`"))
    }

    pub fn req_u32(&self, key: &str) -> anyhow::Result<u32> {
        self.get(key)
            .and_then(Json::as_u32)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid u32 field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field `{key}`"))
    }

    // -- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `}`"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `]`"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// ---------------------------------------------------------------------------
// streaming writer / reader
// ---------------------------------------------------------------------------

/// Push-style JSON serializer that writes bytes directly into a buffer —
/// no intermediate `Json` tree. Emission is byte-identical to `Display` on
/// the equivalent DOM value *provided the caller emits object keys in
/// alphabetical order* (the DOM uses a BTreeMap, so its keys always come
/// out sorted). Integers go through `u64`, which never loses precision.
pub struct JsonWriter {
    buf: Vec<u8>,
    /// One entry per open container: `true` until the first element is written.
    stack: Vec<bool>,
    /// Set by `key`; the next value must not emit a comma.
    after_key: bool,
}

impl Default for JsonWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonWriter {
    pub fn new() -> JsonWriter {
        JsonWriter { buf: Vec::new(), stack: Vec::new(), after_key: false }
    }

    pub fn with_capacity(cap: usize) -> JsonWriter {
        JsonWriter { buf: Vec::with_capacity(cap), stack: Vec::new(), after_key: false }
    }

    /// Comma logic shared by every element: nothing after a key or for the
    /// first element of a container, `,` otherwise.
    fn sep(&mut self) {
        if self.after_key {
            self.after_key = false;
            return;
        }
        if let Some(first) = self.stack.last_mut() {
            if *first {
                *first = false;
            } else {
                self.buf.push(b',');
            }
        }
    }

    pub fn begin_obj(&mut self) {
        self.sep();
        self.buf.push(b'{');
        self.stack.push(true);
    }

    pub fn end_obj(&mut self) {
        self.stack.pop();
        self.buf.push(b'}');
    }

    pub fn begin_arr(&mut self) {
        self.sep();
        self.buf.push(b'[');
        self.stack.push(true);
    }

    pub fn end_arr(&mut self) {
        self.stack.pop();
        self.buf.push(b']');
    }

    pub fn key(&mut self, k: &str) {
        self.sep();
        escape_into(&mut self.buf, k);
        self.buf.push(b':');
        self.after_key = true;
    }

    pub fn u64(&mut self, v: u64) {
        self.sep();
        push_u64(&mut self.buf, v);
    }

    pub fn u32(&mut self, v: u32) {
        self.u64(v as u64);
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// f64 with the same formatting `Display` uses for `Json::Num`. Only
    /// needed for genuinely fractional values; counts should use `u64`.
    pub fn num(&mut self, n: f64) {
        self.sep();
        if n.fract() == 0.0 && n.abs() < 9e15 {
            let i = n as i64;
            if i < 0 {
                self.buf.push(b'-');
                push_u64(&mut self.buf, i.unsigned_abs());
            } else {
                push_u64(&mut self.buf, i as u64);
            }
        } else {
            use std::io::Write;
            let _ = write!(self.buf, "{n}");
        }
    }

    pub fn str(&mut self, s: &str) {
        self.sep();
        escape_into(&mut self.buf, s);
    }

    pub fn bool(&mut self, b: bool) {
        self.sep();
        self.buf.extend_from_slice(if b { b"true" } else { b"false" });
    }

    pub fn null(&mut self) {
        self.sep();
        self.buf.extend_from_slice(b"null");
    }

    /// Raw byte append (e.g. a trailing newline). Not part of the JSON value.
    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Decimal rendering without `format!` (20 digits covers u64::MAX).
fn push_u64(buf: &mut Vec<u8>, mut v: u64) {
    let mut tmp = [0u8; 20];
    let mut i = tmp.len();
    loop {
        i -= 1;
        tmp[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    buf.extend_from_slice(&tmp[i..]);
}

/// Byte-level twin of `write_escaped`: same escape set, same lowercase
/// `\u00xx` form for control characters, so writer output stays
/// byte-identical to `Display`.
fn escape_into(buf: &mut Vec<u8>, s: &str) {
    buf.push(b'"');
    for c in s.chars() {
        match c {
            '"' => buf.extend_from_slice(b"\\\""),
            '\\' => buf.extend_from_slice(b"\\\\"),
            '\n' => buf.extend_from_slice(b"\\n"),
            '\r' => buf.extend_from_slice(b"\\r"),
            '\t' => buf.extend_from_slice(b"\\t"),
            c if (c as u32) < 0x20 => {
                let v = c as u32;
                const HEX: &[u8; 16] = b"0123456789abcdef";
                buf.extend_from_slice(b"\\u00");
                buf.push(HEX[(v >> 4) as usize]);
                buf.push(HEX[(v & 0xF) as usize]);
            }
            c => {
                let mut utf8 = [0u8; 4];
                buf.extend_from_slice(c.encode_utf8(&mut utf8).as_bytes());
            }
        }
    }
    buf.push(b'"');
}

/// Pull-style incremental parser: walks the document in place without
/// building a `Json` tree. Integers parse straight from the digit run
/// (exact for the full u64 range — no f64 round-trip). Unknown fields can
/// be discarded with `skip_value`.
pub struct JsonReader<'a> {
    p: Parser<'a>,
    /// One entry per open container: `true` until its first element is read.
    first: Vec<bool>,
}

impl<'a> JsonReader<'a> {
    pub fn new(text: &'a str) -> JsonReader<'a> {
        JsonReader { p: Parser { b: text.as_bytes(), pos: 0 }, first: Vec::new() }
    }

    pub fn obj_begin(&mut self) -> Result<(), JsonError> {
        self.p.skip_ws();
        self.p.expect(b'{')?;
        self.first.push(true);
        Ok(())
    }

    /// Next key in the current object, or `None` at `}` (which is consumed).
    pub fn key(&mut self) -> Result<Option<String>, JsonError> {
        self.p.skip_ws();
        if self.p.peek() == Some(b'}') {
            self.p.pos += 1;
            self.first.pop();
            return Ok(None);
        }
        self.element_sep()?;
        self.p.skip_ws();
        let k = self.p.string()?;
        self.p.skip_ws();
        self.p.expect(b':')?;
        Ok(Some(k))
    }

    pub fn arr_begin(&mut self) -> Result<(), JsonError> {
        self.p.skip_ws();
        self.p.expect(b'[')?;
        self.first.push(true);
        Ok(())
    }

    /// `true` if another element follows; consumes `]` and returns `false`
    /// at the end of the array.
    pub fn arr_next(&mut self) -> Result<bool, JsonError> {
        self.p.skip_ws();
        if self.p.peek() == Some(b']') {
            self.p.pos += 1;
            self.first.pop();
            return Ok(false);
        }
        self.element_sep()?;
        Ok(true)
    }

    fn element_sep(&mut self) -> Result<(), JsonError> {
        match self.first.last_mut() {
            Some(first) if *first => {
                *first = false;
                Ok(())
            }
            Some(_) => {
                self.p.expect(b',')?;
                Ok(())
            }
            None => Err(self.p.err("element outside any container")),
        }
    }

    pub fn u64(&mut self) -> Result<u64, JsonError> {
        self.p.skip_ws();
        if self.p.peek() == Some(b'-') {
            return Err(self.p.err("unsigned integer expected, got negative"));
        }
        let start = self.p.pos;
        while matches!(self.p.peek(), Some(c) if c.is_ascii_digit()) {
            self.p.pos += 1;
        }
        if self.p.pos == start {
            return Err(self.p.err("expected an unsigned integer"));
        }
        if matches!(self.p.peek(), Some(b'.' | b'e' | b'E')) {
            return Err(self.p.err("integer expected, got a fractional number"));
        }
        let text = std::str::from_utf8(&self.p.b[start..self.p.pos]).unwrap();
        text.parse::<u64>().map_err(|_| self.p.err("integer out of u64 range"))
    }

    pub fn u32(&mut self) -> Result<u32, JsonError> {
        let v = self.u64()?;
        u32::try_from(v).map_err(|_| self.p.err("integer out of u32 range"))
    }

    pub fn usize(&mut self) -> Result<usize, JsonError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| self.p.err("integer out of usize range"))
    }

    pub fn str(&mut self) -> Result<String, JsonError> {
        self.p.skip_ws();
        self.p.string()
    }

    /// Discard the next value of any shape (forward compatibility for
    /// unknown manifest fields). This is the only reader path that may
    /// allocate a temporary tree; it never runs on fields we emit.
    pub fn skip_value(&mut self) -> Result<(), JsonError> {
        self.p.value().map(|_| ())
    }

    /// Assert end of document (trailing whitespace/newline allowed).
    pub fn end(&mut self) -> Result<(), JsonError> {
        self.p.skip_ws();
        if self.p.pos != self.p.b.len() {
            return Err(self.p.err("trailing data"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["a"]).as_arr().unwrap()[2].at(&["b"]).as_str(), Some("x"));
        assert_eq!(v.at(&["c"]), &Json::Null);
        assert_eq!(v.at(&["missing", "nope"]), &Json::Null);
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrips_through_display() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"num":-3}"#;
        let v = Json::parse(src).unwrap();
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn required_accessors_error_with_key_name() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.req_usize("a").unwrap(), 1);
        let e = v.req_str("zzz").unwrap_err().to_string();
        assert!(e.contains("zzz"));
    }

    #[test]
    fn strict_integer_accessors_reject_lossy_values() {
        assert_eq!(Json::Num(5.0).as_u64(), Some(5));
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        // 2^53 is the last exactly-representable integer
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_u64(), Some(9_007_199_254_740_992));
        assert_eq!(Json::Num(9_007_199_254_741_000.0).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(f64::NAN).as_u64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_u64(), None);
        assert_eq!(Json::Str("5".into()).as_u64(), None);
        // as_u32 additionally range-checks
        assert_eq!(Json::Num(4_294_967_295.0).as_u32(), Some(u32::MAX));
        assert_eq!(Json::Num(4_294_967_296.0).as_u32(), None);
        // as_usize now routes through the strict path: no truncation
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(-3.0).as_usize(), None);
        let v = Json::parse(r#"{"neg": -4, "frac": 2.5, "ok": 7}"#).unwrap();
        assert_eq!(v.req_u64("ok").unwrap(), 7);
        assert!(v.req_u64("neg").is_err());
        assert!(v.req_u64("frac").is_err());
        assert!(v.req_u32("missing").is_err());
    }

    #[test]
    fn writer_matches_display_byte_for_byte() {
        // Keys emitted alphabetically, exactly as the BTreeMap DOM sorts them.
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("arr");
        w.begin_arr();
        w.u64(1);
        w.num(2.5);
        w.str("s\n\"x\\\u{1}é😀");
        w.end_arr();
        w.key("b");
        w.bool(false);
        w.key("n");
        w.null();
        w.key("num");
        w.num(-3.0);
        w.key("z");
        w.begin_obj();
        w.end_obj();
        w.end_obj();
        let bytes = w.finish();
        let dom = Json::obj(vec![
            ("arr", Json::Arr(vec![Json::num(1.0), Json::num(2.5), Json::str("s\n\"x\\\u{1}é😀")])),
            ("b", Json::from(false)),
            ("n", Json::Null),
            ("num", Json::num(-3.0)),
            ("z", Json::obj(vec![])),
        ]);
        assert_eq!(String::from_utf8(bytes).unwrap(), dom.to_string());
    }

    #[test]
    fn writer_u64_exact_above_2_53() {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("big");
        w.u64(u64::MAX);
        w.end_obj();
        assert_eq!(
            String::from_utf8(w.finish()).unwrap(),
            format!("{{\"big\":{}}}", u64::MAX)
        );
    }

    #[test]
    fn reader_walks_objects_and_arrays() {
        let text = "{\"a\":[1,2,3],\"big\":18446744073709551615,\"s\":\"x\\ny\",\"skip\":{\"deep\":[null,true]}}\n";
        let mut r = JsonReader::new(text);
        r.obj_begin().unwrap();
        let mut seen = Vec::new();
        while let Some(k) = r.key().unwrap() {
            match k.as_str() {
                "a" => {
                    r.arr_begin().unwrap();
                    let mut sum = 0u64;
                    while r.arr_next().unwrap() {
                        sum += r.u64().unwrap();
                    }
                    assert_eq!(sum, 6);
                }
                "big" => assert_eq!(r.u64().unwrap(), u64::MAX),
                "s" => assert_eq!(r.str().unwrap(), "x\ny"),
                _ => r.skip_value().unwrap(),
            }
            seen.push(k);
        }
        r.end().unwrap();
        assert_eq!(seen, ["a", "big", "s", "skip"]);
    }

    #[test]
    fn reader_rejects_non_integers_and_garbage() {
        assert!(JsonReader::new("-5").u64().is_err());
        assert!(JsonReader::new("1.5").u64().is_err());
        assert!(JsonReader::new("1e3").u64().is_err());
        assert!(JsonReader::new("18446744073709551616").u64().is_err()); // u64::MAX + 1
        assert!(JsonReader::new("4294967296").u32().is_err());
        assert!(JsonReader::new("\"s\"").u64().is_err());
        let mut r = JsonReader::new("[1 1]");
        r.arr_begin().unwrap();
        assert!(r.arr_next().unwrap());
        r.u64().unwrap();
        assert!(r.arr_next().is_err()); // missing comma
        let mut r = JsonReader::new("{}x");
        r.obj_begin().unwrap();
        assert_eq!(r.key().unwrap(), None);
        assert!(r.end().is_err()); // trailing data
    }

    #[test]
    fn reader_empty_containers() {
        let mut r = JsonReader::new("{\"a\":[],\"o\":{}}\n");
        r.obj_begin().unwrap();
        assert_eq!(r.key().unwrap().as_deref(), Some("a"));
        r.arr_begin().unwrap();
        assert!(!r.arr_next().unwrap());
        assert_eq!(r.key().unwrap().as_deref(), Some("o"));
        r.obj_begin().unwrap();
        assert_eq!(r.key().unwrap(), None);
        assert_eq!(r.key().unwrap(), None); // outer object ends too
        r.end().unwrap();
    }
}
