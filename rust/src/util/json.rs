//! Minimal JSON parser + writer (serde is not in the vendored crate set).
//!
//! Supports the full JSON data model with the restrictions that suit our
//! inputs: UTF-8 text, `\uXXXX` escapes decoded for the BMP (surrogate pairs
//! supported), numbers parsed as f64 (exact for the integer ranges the
//! manifest uses: parameter counts < 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- required accessors (error messages name the key) -------------------

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field `{key}`"))
    }

    // -- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `}`"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `]`"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["a"]).as_arr().unwrap()[2].at(&["b"]).as_str(), Some("x"));
        assert_eq!(v.at(&["c"]), &Json::Null);
        assert_eq!(v.at(&["missing", "nope"]), &Json::Null);
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrips_through_display() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"num":-3}"#;
        let v = Json::parse(src).unwrap();
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn required_accessors_error_with_key_name() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.req_usize("a").unwrap(), 1);
        let e = v.req_str("zzz").unwrap_err().to_string();
        assert!(e.contains("zzz"));
    }
}
