//! Deterministic PRNG + distribution samplers (the vendored crate set has no
//! `rand`). xoshiro256++ seeded via SplitMix64 — fast, well-tested generator,
//! deterministic across platforms, which matters for reproducible failure
//! schedules and synthetic workloads.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the full state
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-rank / per-node RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        Rng::seed_from(a ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is fine here; bias
        // for n << 2^64 is negligible for simulation purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (mean 0, std 1).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal f32 with given std (parameter init path).
    #[inline]
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Weibull(shape c, scale lambda) sample — the paper's TTF model
    /// (Assumption 1): survival S(t) = exp(-(t/lambda)^c).
    pub fn weibull(&mut self, shape_c: f64, scale: f64) -> f64 {
        let u = self.f64_open();
        scale * (-u.ln()).powf(1.0 / shape_c)
    }

    /// Exponential(rate) sample (Weibull with c = 1).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64_open().ln() / rate
    }

    /// Fill a f32 slice with normals of the given std.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(std);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::seed_from(7);
        let mut s1 = a.fork(1);
        let mut s2 = a.fork(2);
        let x: Vec<u64> = (0..8).map(|_| s1.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| s2.next_u64()).collect();
        assert_ne!(x, y);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::seed_from(1);
        for _ in 0..10_000 {
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
            let g = r.f64_open();
            assert!(g > 0.0 && g <= 1.0);
            let n = r.below(17);
            assert!(n < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn weibull_matches_analytic_cdf() {
        // empirical survival at t = scale must be ~ exp(-1) for any shape
        let mut r = Rng::seed_from(9);
        for &c in &[0.7, 1.0, 1.5, 2.0] {
            let scale = 3.0;
            let n = 100_000;
            let surv = (0..n).filter(|_| r.weibull(c, scale) > scale).count() as f64 / n as f64;
            assert!(
                (surv - (-1.0f64).exp()).abs() < 0.01,
                "shape {c}: survival {surv}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from(11);
        let rate = 0.25;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean {mean}");
    }
}
