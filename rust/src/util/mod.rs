//! Small self-contained utilities the rest of the crate builds on.
//!
//! The build environment is offline with a minimal vendored crate set (no
//! serde / rand / tokio), so this module carries our own JSON codec and a
//! deterministic PRNG + distribution samplers. Both are tested here and used
//! pervasively: JSON for the artifact manifest / configs / metric dumps, the
//! PRNG for parameter init, data synthesis and failure injection.

pub mod json;
pub mod rng;

/// Format a byte count for humans (binary units).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in seconds adaptively (ns/µs/ms/s).
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(20 * 1024 * 1024 * 1024), "20.00 GiB");
    }

    #[test]
    fn human_secs_ranges() {
        assert!(human_secs(3e-9).ends_with("ns"));
        assert!(human_secs(5e-5).ends_with("µs"));
        assert!(human_secs(0.2).ends_with("ms"));
        assert!(human_secs(3.0).ends_with(" s"));
        assert!(human_secs(600.0).ends_with("min"));
    }
}
