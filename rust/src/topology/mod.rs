//! Cluster topology: the DP × TP × PP rank grid and REFT's sharding groups.
//!
//! Placement follows the paper (§2.1 Communication Types and Fig. 5): **TP is
//! intra-node** (it needs the fastest interconnect), **PP stages span nodes**,
//! and DP paths replicate that arrangement. A *sharding group* (SG) is the set
//! of nodes holding the same PP stage across all DP paths (§4.1
//! "Intra-Pipeline-Stage Sharding"): SG_s = { node(d, s) | d in 0..DP }.
//! The SG is both the unit of snapshot sharding (each member snapshots 1/|SG|
//! of the stage's bytes) and the RAIM5 parity domain (one parity per stripe,
//! tolerating one node loss per SG).

use anyhow::{bail, Result};

/// 3D parallelism degrees.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelPlan {
    pub dp: usize,
    pub tp: usize,
    pub pp: usize,
}

impl ParallelPlan {
    pub fn new(dp: usize, tp: usize, pp: usize) -> Self {
        ParallelPlan { dp, tp, pp }
    }

    pub fn world_size(&self) -> usize {
        self.dp * self.tp * self.pp
    }

    pub fn dp_only(dp: usize) -> Self {
        ParallelPlan { dp, tp: 1, pp: 1 }
    }
}

/// A global rank's coordinates in the 3D grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RankCoord {
    pub dp: usize,
    pub pp: usize,
    pub tp: usize,
}

/// Physical placement of one rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    pub node: usize,
    pub local_gpu: usize,
}

/// The realized topology: rank grid mapped onto nodes/GPUs.
#[derive(Debug, Clone)]
pub struct Topology {
    pub plan: ParallelPlan,
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// placement\[global_rank\] -> (node, local gpu)
    pub placement: Vec<Placement>,
}

impl Topology {
    /// Build the paper-style placement: TP ranks fill a node's GPUs first
    /// (TP intra-node), then PP stages advance across nodes, then DP paths
    /// tile the remainder of the cluster.
    ///
    /// Requires `tp <= gpus_per_node` and `gpus_per_node % tp == 0`.
    pub fn build(plan: ParallelPlan, nodes: usize, gpus_per_node: usize) -> Result<Topology> {
        if plan.tp > gpus_per_node {
            bail!(
                "tp={} exceeds gpus_per_node={} (TP must stay intra-node)",
                plan.tp,
                gpus_per_node
            );
        }
        if gpus_per_node % plan.tp != 0 {
            bail!("gpus_per_node={} not divisible by tp={}", gpus_per_node, plan.tp);
        }
        let total_gpus = nodes * gpus_per_node;
        if plan.world_size() > total_gpus {
            bail!(
                "world size {} exceeds cluster capacity {} ({} nodes x {} GPUs)",
                plan.world_size(),
                total_gpus,
                nodes,
                gpus_per_node
            );
        }
        // groups of `tp` GPUs are allocated in order: (dp, pp) pairs row-major,
        // pp fastest so a DP path occupies a contiguous run of nodes
        let tp_groups_per_node = gpus_per_node / plan.tp;
        let mut placement = vec![Placement { node: 0, local_gpu: 0 }; plan.world_size()];
        let mut group_idx = 0usize;
        for dp in 0..plan.dp {
            for pp in 0..plan.pp {
                let node = group_idx / tp_groups_per_node;
                let slot = group_idx % tp_groups_per_node;
                for tp in 0..plan.tp {
                    let rank = Self::rank_of(plan, RankCoord { dp, pp, tp });
                    placement[rank] = Placement { node, local_gpu: slot * plan.tp + tp };
                }
                group_idx += 1;
            }
        }
        Ok(Topology { plan, nodes, gpus_per_node, placement })
    }

    /// global rank = ((dp * PP) + pp) * TP + tp
    pub fn rank_of(plan: ParallelPlan, c: RankCoord) -> usize {
        (c.dp * plan.pp + c.pp) * plan.tp + c.tp
    }

    pub fn coord_of(&self, rank: usize) -> RankCoord {
        let tp = rank % self.plan.tp;
        let rest = rank / self.plan.tp;
        let pp = rest % self.plan.pp;
        let dp = rest / self.plan.pp;
        RankCoord { dp, pp, tp }
    }

    pub fn place(&self, c: RankCoord) -> Placement {
        self.placement[Self::rank_of(self.plan, c)]
    }

    /// Nodes hosting pipeline stage `pp` for DP path `dp` (the TP group's nodes).
    pub fn stage_nodes(&self, dp: usize, pp: usize) -> Vec<usize> {
        let mut ns: Vec<usize> = (0..self.plan.tp)
            .map(|tp| self.place(RankCoord { dp, pp, tp }).node)
            .collect();
        ns.dedup();
        ns
    }

    /// Sharding group s = all nodes hosting PP stage s across every DP path
    /// (paper Fig. 5: "all PP_0 nodes formulate SG_0").
    pub fn sharding_group(&self, pp: usize) -> ShardingGroup {
        let mut nodes = Vec::new();
        for dp in 0..self.plan.dp {
            for n in self.stage_nodes(dp, pp) {
                if !nodes.contains(&n) {
                    nodes.push(n);
                }
            }
        }
        ShardingGroup { stage: pp, nodes }
    }

    pub fn sharding_groups(&self) -> Vec<ShardingGroup> {
        (0..self.plan.pp).map(|s| self.sharding_group(s)).collect()
    }

    /// All global ranks placed on `node`.
    pub fn ranks_on_node(&self, node: usize) -> Vec<usize> {
        (0..self.plan.world_size())
            .filter(|&r| self.placement[r].node == node)
            .collect()
    }

    /// Number of nodes actually used by the plan.
    pub fn nodes_in_use(&self) -> usize {
        let mut seen = vec![false; self.nodes];
        for p in &self.placement {
            seen[p.node] = true;
        }
        seen.iter().filter(|&&s| s).count()
    }
}

/// The unit of REFT sharding + RAIM5 protection: nodes holding one PP stage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardingGroup {
    pub stage: usize,
    pub nodes: Vec<usize>,
}

impl ShardingGroup {
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_3d_example_placement() {
        // Fig. 3 setup: 2 DP x 4 TP x 3 PP on 6 nodes x 4 GPUs
        let t = Topology::build(ParallelPlan::new(2, 4, 3), 6, 4).unwrap();
        assert_eq!(t.plan.world_size(), 24);
        // TP stays intra-node: each (dp, pp) group occupies exactly one node
        for dp in 0..2 {
            for pp in 0..3 {
                assert_eq!(t.stage_nodes(dp, pp).len(), 1, "dp{dp} pp{pp}");
            }
        }
        // DP path 0 on nodes 0..3, DP path 1 on nodes 3..6
        assert_eq!(t.place(RankCoord { dp: 0, pp: 0, tp: 0 }).node, 0);
        assert_eq!(t.place(RankCoord { dp: 1, pp: 0, tp: 0 }).node, 3);
    }

    #[test]
    fn sharding_groups_cover_dp_paths() {
        let t = Topology::build(ParallelPlan::new(2, 4, 3), 6, 4).unwrap();
        let sgs = t.sharding_groups();
        assert_eq!(sgs.len(), 3);
        for (s, sg) in sgs.iter().enumerate() {
            assert_eq!(sg.stage, s);
            assert_eq!(sg.len(), 2, "one node per DP path in SG_{s}");
        }
        // SGs are disjoint here (each node hosts exactly one stage)
        let mut all: Vec<usize> = sgs.iter().flat_map(|g| g.nodes.clone()).collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 6);
    }

    #[test]
    fn dp_only_plan() {
        let t = Topology::build(ParallelPlan::dp_only(24), 6, 4).unwrap();
        assert_eq!(t.plan.world_size(), 24);
        let sg = t.sharding_group(0);
        assert_eq!(sg.len(), 6); // every node is in the single SG
        assert_eq!(t.ranks_on_node(0).len(), 4);
    }

    #[test]
    fn rank_coord_roundtrip() {
        let t = Topology::build(ParallelPlan::new(2, 2, 3), 6, 4).unwrap();
        for r in 0..t.plan.world_size() {
            assert_eq!(Topology::rank_of(t.plan, t.coord_of(r)), r);
        }
    }

    #[test]
    fn rejects_invalid_plans() {
        assert!(Topology::build(ParallelPlan::new(1, 8, 1), 2, 4).is_err()); // tp > gpus
        assert!(Topology::build(ParallelPlan::new(1, 3, 1), 2, 4).is_err()); // 4 % 3 != 0
        assert!(Topology::build(ParallelPlan::new(4, 4, 4), 2, 4).is_err()); // too big
    }

    #[test]
    fn strong_scaling_configs_fit_testbed() {
        // §6.1: PP in {1, 2, 4, 6} with TP=4, DP=1 on 6 nodes x 4 GPUs
        for pp in [1usize, 2, 4, 6] {
            let t = Topology::build(ParallelPlan::new(1, 4, pp), 6, 4).unwrap();
            assert_eq!(t.nodes_in_use(), pp);
            for s in 0..pp {
                assert_eq!(t.sharding_group(s).len(), 1);
            }
        }
    }
}
