//! Artifact manifest: the JSON contract `aot.py` emits describing each
//! exported model — stage layouts (name/shape/offset/init per tensor),
//! artifact paths per stage kind, and the model hyper-parameters.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One tensor inside a stage's flat parameter buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    /// "normal:<std>" | "zeros" | "ones"
    pub init: String,
}

/// Artifact paths of one stage (relative to the artifacts root).
#[derive(Debug, Clone, Default)]
pub struct StageArtifacts {
    /// kind -> path (kinds: fwd, bwd, fwdbwd, fwd_bwd, adam)
    pub by_kind: BTreeMap<String, String>,
}

impl StageArtifacts {
    pub fn get(&self, kind: &str) -> Result<&str> {
        self.by_kind
            .get(kind)
            .map(String::as_str)
            .ok_or_else(|| anyhow::anyhow!("no `{kind}` artifact for this stage"))
    }
}

/// One pipeline stage's metadata.
#[derive(Debug, Clone)]
pub struct StageMeta {
    pub index: usize,
    /// "first" | "mid" | "last" | "single"
    pub kind: String,
    pub layers: Vec<usize>,
    pub n_params: usize,
    pub artifacts: StageArtifacts,
    pub params: Vec<ParamMeta>,
}

/// Hyper-parameters of the exported model.
#[derive(Debug, Clone, Copy)]
pub struct ModelHyper {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub lr: f64,
}

/// A parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    pub hyper: ModelHyper,
    pub n_stages: usize,
    pub total_params: usize,
    pub stages: Vec<StageMeta>,
    /// whole-model artifacts for pure-DP runs (fwd_bwd + adam), if exported
    pub full: Option<StageMeta>,
}

impl Manifest {
    /// Load `artifacts/<model>/manifest.json`.
    pub fn load(artifacts_root: impl AsRef<Path>, model: &str) -> Result<Manifest> {
        let path = artifacts_root.as_ref().join(model).join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let cfg = j.get("config").context("manifest missing `config`")?;
        let hyper = ModelHyper {
            vocab: cfg.req_usize("vocab")?,
            d_model: cfg.req_usize("d_model")?,
            n_layers: cfg.req_usize("n_layers")?,
            n_heads: cfg.req_usize("n_heads")?,
            d_ff: cfg.req_usize("d_ff")?,
            seq: cfg.req_usize("seq")?,
            batch: cfg.req_usize("batch")?,
            lr: cfg.req_f64("lr")?,
        };
        let stages = j
            .req_arr("stages")?
            .iter()
            .map(parse_stage)
            .collect::<Result<Vec<_>>>()?;
        let full = match j.get("full") {
            Some(f) if f != &Json::Null => Some(parse_full(f)?),
            _ => None,
        };
        let m = Manifest {
            model: j.req_str("model")?.to_string(),
            hyper,
            n_stages: j.req_usize("n_stages")?,
            total_params: j.req_usize("total_params")?,
            stages,
            full,
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural invariants the rest of the system relies on.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.stages.len() == self.n_stages, "stage count mismatch");
        let sum: usize = self.stages.iter().map(|s| s.n_params).sum();
        anyhow::ensure!(
            sum == self.total_params,
            "stage params {} != total {}",
            sum,
            self.total_params
        );
        for st in &self.stages {
            let mut off = 0usize;
            for p in &st.params {
                anyhow::ensure!(
                    p.offset == off,
                    "stage {} param {} offset {} != {}",
                    st.index,
                    p.name,
                    p.offset,
                    off
                );
                let sz: usize = p.shape.iter().product();
                anyhow::ensure!(sz == p.size, "param {} size mismatch", p.name);
                off += p.size;
            }
            anyhow::ensure!(
                off == st.n_params,
                "stage {} layout sums to {} != {}",
                st.index,
                off,
                st.n_params
            );
        }
        if let Some(full) = &self.full {
            anyhow::ensure!(
                full.n_params == self.total_params,
                "full layout {} != total {}",
                full.n_params,
                self.total_params
            );
        }
        Ok(())
    }

    pub fn stage(&self, i: usize) -> &StageMeta {
        &self.stages[i]
    }

    /// Stage sizes in parameters (for sharding plans).
    pub fn stage_sizes(&self) -> Vec<usize> {
        self.stages.iter().map(|s| s.n_params).collect()
    }
}

fn parse_params(arr: &[Json]) -> Result<Vec<ParamMeta>> {
    arr.iter()
        .map(|p| {
            Ok(ParamMeta {
                name: p.req_str("name")?.to_string(),
                shape: p
                    .req_arr("shape")?
                    .iter()
                    .map(|d| d.as_usize().context("bad shape dim"))
                    .collect::<Result<Vec<_>>>()?,
                offset: p.req_usize("offset")?,
                size: p.req_usize("size")?,
                init: p.req_str("init")?.to_string(),
            })
        })
        .collect()
}

fn parse_artifacts(j: &Json) -> Result<StageArtifacts> {
    let mut by_kind = BTreeMap::new();
    let obj = j.as_obj().context("artifacts not an object")?;
    for (kind, v) in obj {
        let path = v.req_str("path")?;
        // manifest paths may be relative to repo root ("../artifacts/...")
        // or artifacts-root-relative; normalize to `<model>/<file>`
        let norm = normalize_artifact_path(path);
        by_kind.insert(kind.clone(), norm);
    }
    Ok(StageArtifacts { by_kind })
}

/// Keep only the trailing `<model>/<file>` components.
fn normalize_artifact_path(p: &str) -> String {
    let parts: Vec<&str> = p.split('/').filter(|s| !s.is_empty() && *s != "." && *s != "..").collect();
    if parts.len() >= 2 {
        // drop any leading "artifacts" prefix
        let tail = &parts[parts.len() - 2..];
        if parts.len() >= 3 || parts[0] != "artifacts" {
            return tail.join("/");
        }
    }
    parts.join("/")
}

fn parse_stage(j: &Json) -> Result<StageMeta> {
    Ok(StageMeta {
        index: j.req_usize("index")?,
        kind: j.req_str("kind")?.to_string(),
        layers: j
            .req_arr("layers")?
            .iter()
            .map(|l| l.as_usize().context("bad layer"))
            .collect::<Result<Vec<_>>>()?,
        n_params: j.req_usize("n_params")?,
        artifacts: parse_artifacts(j.get("artifacts").context("missing artifacts")?)?,
        params: parse_params(j.req_arr("params")?)?,
    })
}

fn parse_full(j: &Json) -> Result<StageMeta> {
    Ok(StageMeta {
        index: 0,
        kind: "full".into(),
        layers: Vec::new(),
        n_params: j.req_usize("n_params")?,
        artifacts: parse_artifacts(j.get("artifacts").context("missing artifacts")?)?,
        params: parse_params(j.req_arr("params")?)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": "demo",
      "config": {"vocab": 256, "d_model": 64, "n_layers": 2, "n_heads": 4,
                 "d_ff": 256, "seq": 32, "batch": 2, "lr": 0.001},
      "n_stages": 2,
      "total_params": 30,
      "stages": [
        {"index": 0, "kind": "first", "layers": [0], "n_params": 10,
         "artifacts": {"fwd": {"path": "../artifacts/demo/stage0_fwd.hlo.txt", "bytes": 10}},
         "params": [{"name": "a", "shape": [2, 5], "offset": 0, "size": 10, "init": "normal:0.02"}]},
        {"index": 1, "kind": "last", "layers": [1], "n_params": 20,
         "artifacts": {"fwdbwd": {"path": "demo/stage1_fwdbwd.hlo.txt", "bytes": 10}},
         "params": [{"name": "b", "shape": [20], "offset": 0, "size": 20, "init": "zeros"}]}
      ]
    }"#;

    #[test]
    fn parses_and_validates() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model, "demo");
        assert_eq!(m.n_stages, 2);
        assert_eq!(m.hyper.d_model, 64);
        assert_eq!(m.stage(0).params[0].shape, vec![2, 5]);
        assert!(m.full.is_none());
    }

    #[test]
    fn artifact_paths_normalized() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(
            m.stage(0).artifacts.get("fwd").unwrap(),
            "demo/stage0_fwd.hlo.txt"
        );
        assert_eq!(
            m.stage(1).artifacts.get("fwdbwd").unwrap(),
            "demo/stage1_fwdbwd.hlo.txt"
        );
        assert!(m.stage(0).artifacts.get("bwd").is_err());
    }

    #[test]
    fn rejects_inconsistent_layout() {
        let bad = SAMPLE.replace("\"total_params\": 30", "\"total_params\": 31");
        assert!(Manifest::parse(&bad).is_err());
        let bad2 = SAMPLE.replace("\"offset\": 0, \"size\": 20", "\"offset\": 1, \"size\": 20");
        assert!(Manifest::parse(&bad2).is_err());
    }

    #[test]
    fn normalize_path_variants() {
        assert_eq!(normalize_artifact_path("../artifacts/m/f.txt"), "m/f.txt");
        assert_eq!(normalize_artifact_path("artifacts/m/f.txt"), "m/f.txt");
        assert_eq!(normalize_artifact_path("m/f.txt"), "m/f.txt");
    }
}
