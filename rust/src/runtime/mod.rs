//! PJRT runtime: load AOT HLO-text artifacts and execute them from rust.
//!
//! The interchange contract with `python/compile/aot.py`:
//! * artifacts are HLO **text** (`HloModuleProto::from_text_file` reassigns
//!   instruction ids, sidestepping the 64-bit-id protos jax >= 0.5 emits that
//!   xla_extension 0.5.1 rejects);
//! * every computation was lowered with `return_tuple=True`, so results come
//!   back as one tuple literal we decompose;
//! * parameters are passed positionally in the manifest's declared order.
//!
//! Python never runs here — this is the request-path side.

pub mod manifest;

pub use manifest::{Manifest, ParamMeta, StageArtifacts, StageMeta};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A PJRT CPU engine holding compiled executables keyed by artifact path.
pub struct Engine {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    root: PathBuf,
}

impl Engine {
    /// Create a CPU engine rooted at the artifacts directory.
    pub fn cpu(artifacts_root: impl Into<PathBuf>) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: HashMap::new(), root: artifacts_root.into() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&mut self, rel_path: impl AsRef<Path>) -> Result<&xla::PjRtLoadedExecutable> {
        let full = self.root.join(rel_path.as_ref());
        if !self.cache.contains_key(&full) {
            let proto = xla::HloModuleProto::from_text_file(&full)
                .with_context(|| format!("parsing HLO text {}", full.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", full.display()))?;
            self.cache.insert(full.clone(), exe);
        }
        Ok(&self.cache[&full])
    }

    /// Execute a loaded artifact on literal inputs; returns the decomposed
    /// output tuple.
    ///
    /// NOTE: the vendored `xla` crate's `execute` leaks the *input* device
    /// buffers (`buffer.release()` in the C shim is never freed), so this
    /// entry point is fine for tests/one-shots but NOT for training loops —
    /// use [`Engine::run_inputs`] there, which goes through owned
    /// `PjRtBuffer`s + `execute_b` and is leak-free (§Perf iteration log).
    pub fn run(
        &mut self,
        rel_path: impl AsRef<Path>,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(rel_path.as_ref())?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", rel_path.as_ref().display()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple().context("decomposing result tuple")
    }

    /// Leak-free execution from host slices: inputs are uploaded as owned
    /// `PjRtBuffer`s (dropped after the call), outputs come back as literals.
    pub fn run_inputs(
        &mut self,
        rel_path: impl AsRef<Path>,
        inputs: &[In<'_>],
    ) -> Result<Vec<xla::Literal>> {
        // upload inputs first (cache borrow rules: load() borrows &mut self)
        let mut bufs = Vec::with_capacity(inputs.len());
        for inp in inputs {
            let buf = match inp {
                In::F32(data, dims) => self
                    .client
                    .buffer_from_host_buffer::<f32>(data, dims, None),
                In::I32(data, dims) => self
                    .client
                    .buffer_from_host_buffer::<i32>(data, dims, None),
            }
            .context("uploading input buffer")?;
            bufs.push(buf);
        }
        let exe = self.load(rel_path.as_ref())?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .with_context(|| format!("executing {}", rel_path.as_ref().display()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple().context("decomposing result tuple")
    }

    /// Number of artifacts currently compiled.
    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}

/// A borrowed host-side input for [`Engine::run_inputs`].
pub enum In<'a> {
    F32(&'a [f32], Vec<usize>),
    I32(&'a [i32], Vec<usize>),
}

impl<'a> In<'a> {
    pub fn f32(data: &'a [f32], dims: &[usize]) -> Self {
        In::F32(data, dims.to_vec())
    }

    pub fn i32(data: &'a [i32], dims: &[usize]) -> Self {
        In::I32(data, dims.to_vec())
    }
}

// ---------------------------------------------------------------------------
// literal conversion helpers
// ---------------------------------------------------------------------------

/// Flat f32 slice -> literal of the given shape.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} != len {}", dims, data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Flat i32 slice -> literal of the given shape.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} != len {}", dims, data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// 1-element f32 literal (e.g. the Adam step scalar input `f32[1]`).
pub fn lit_f32_scalar_vec(v: f32) -> xla::Literal {
    xla::Literal::vec1(&[v])
}

/// Literal -> Vec<f32>.
pub fn vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Scalar f32 out of a literal (loss outputs are rank-0).
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }
}
