//! Durable checkpoint manifests — the atomic-commit unit of the
//! persistence engine.
//!
//! Layout in the [`Storage`] key namespace (one sub-namespace per model):
//!
//! ```text
//! {model}/persist/step-{step:012}/shard-{stage:03}-{node:03}             shard blobs
//! {model}/persist/step-{step:012}/shard-{stage:03}-{node:03}/part-{k:05} multipart part-objects
//! {model}/manifest/step-{step:012}                                       the manifest
//! ```
//!
//! Commit protocol (crash-consistent by construction):
//!
//! 1. the writer workers upload every shard blob of the round — a large
//!    shard lands as `part-{k}` objects with per-part CRCs, so a crashed
//!    upload resumes from the last durable part instead of starting over;
//! 2. only after **all** shards have landed is the manifest written — a
//!    single `put` of a small JSON document (`DirStorage` makes the put
//!    itself atomic via write-then-rename);
//! 3. readers resolve "latest" over *manifest* keys only, so a crash
//!    anywhere before step 2 leaves the previous manifest as latest and the
//!    orphaned shard blobs/parts invisible (the retention GC sweeps them).
//!
//! The manifest records every shard's key, byte range, and CRC32 — plus the
//! per-part keys/CRCs for multipart shards — so a restore can verify the
//! durable copy end to end before trusting it.
//!
//! Loading is a **parallel sharded gather** ([`load_manifest_payload`]):
//! scoped threads fetch + CRC-verify shards concurrently and stitch them
//! directly into the pre-allocated stage buffers, mirroring the in-memory
//! parallel restore. Verification is **fused into the fetch**
//! (`Storage::get_into_checksummed`): each chunk is hashed while it is
//! cache-warm from the copy, so restore touches every byte exactly once;
//! multipart shards get their whole-shard CRC from the per-part CRCs via
//! GF(2) `combine` without another byte pass. The pre-parallel serial loop
//! is kept as [`load_manifest_payload_serial`] (parallel-vs-serial
//! baseline/oracle) and the pre-fusion leaf as
//! [`load_manifest_payload_separate`] (fused-vs-separate baseline/oracle)
//! for `benches/hotpath.rs` and the tests.
//!
//! Manifests and sidecars encode/decode through the **streaming** JSON
//! writer/reader (`util::json::{JsonWriter, JsonReader}`) — no intermediate
//! DOM tree on the per-commit path. The DOM codecs are retained as
//! `encode_dom`/`decode_dom`, the byte- and value-identity oracles.

use std::collections::BTreeSet;

use anyhow::{anyhow, Context, Result};

use crate::checkpoint::Storage;
use crate::util::json::{Json, JsonReader, JsonWriter};

/// Key of one persisted shard blob.
pub fn shard_key(model: &str, step: u64, stage: usize, node: usize) -> String {
    format!("{model}/persist/step-{step:012}/shard-{stage:03}-{node:03}")
}

/// Key of one durable part-object of a multipart shard upload.
pub fn part_key(model: &str, step: u64, stage: usize, node: usize, part: usize) -> String {
    format!("{model}/persist/step-{step:012}/shard-{stage:03}-{node:03}/part-{part:05}")
}

/// Key of the multipart-progress sidecar of one shard: the `(len, crc)` of
/// every part that has actually landed, maintained by the writer as parts
/// upload, so a resumed attempt can verify durable parts with **O(parts)
/// metadata reads** instead of reading every part's bytes back.
pub fn part_meta_key(model: &str, step: u64, stage: usize, node: usize) -> String {
    format!("{}/meta", shard_key(model, step, stage, node))
}

/// Prefix of every shard blob **and** part-object of `model` (the step
/// digits follow).
pub fn shard_prefix(model: &str) -> String {
    format!("{model}/persist/step-")
}

/// Key of the manifest committed for `step`.
pub fn manifest_key(model: &str, step: u64) -> String {
    format!("{model}/manifest/step-{step:012}")
}

/// Prefix of every manifest of `model` (zero-padded steps sort numerically).
pub fn manifest_prefix(model: &str) -> String {
    format!("{model}/manifest/step-")
}

/// Parse the step number out of a key under `prefix` (manifest keys end in
/// the digits; shard and part keys continue with `/shard-...` after them).
pub fn step_of_key(key: &str, prefix: &str) -> Option<u64> {
    let rest = key.strip_prefix(prefix)?;
    let digits: &str = &rest[..rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len())];
    if digits.is_empty() {
        return None;
    }
    digits.parse().ok()
}

/// One durable part-object of a multipart shard: its key, length, and CRC.
/// The per-part CRC is what makes a crashed upload resumable — a retry can
/// verify a part that already landed and skip re-uploading it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartEntry {
    pub key: String,
    pub len: u64,
    pub crc32: u32,
}

/// One shard's entry in a manifest: where its bytes live and how to verify
/// them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardEntry {
    /// the single-blob key (no blob exists under it when `parts` is
    /// non-empty — the bytes live in the part-objects instead)
    pub key: String,
    pub stage: usize,
    pub node: usize,
    /// byte offset into the stage's FT payload
    pub offset: u64,
    pub len: u64,
    /// CRC of the whole shard payload (also covered part-by-part for
    /// multipart shards). For a **delta** shard this covers the full
    /// *reconstructed* shard — base bytes with the extents patched in — so
    /// restore verifies the chain end to end, not just the shipped bytes.
    pub crc32: u32,
    /// sparse layout, only meaningful inside a **delta** manifest (one whose
    /// top-level `base_step` is set): the shard-local `(start, len)` byte
    /// ranges the blob at `key` (or the parts) contains, concatenated in
    /// order, to be patched over the base round's shard. Empty in a delta
    /// manifest = the shard did not change — **no blob exists at all** and
    /// restore just re-verifies the base bytes against `crc32`. In a full
    /// (base) manifest this list is always empty and the blob holds every
    /// byte of the shard.
    pub extents: Vec<(u64, u64)>,
    /// multipart layout; empty = the shard is one blob at `key`
    pub parts: Vec<PartEntry>,
}

impl ShardEntry {
    /// Every storage key that may hold this shard's bytes or bookkeeping.
    /// The single-blob key is always included — deletes are idempotent, and
    /// an earlier crashed attempt at the same step may have left a
    /// whole-blob upload behind even when the committed layout is multipart
    /// (or vice versa) — as is the multipart-progress sidecar, so a retired
    /// version takes its resume metadata with it.
    pub fn storage_keys(&self) -> Vec<String> {
        let mut keys = vec![self.key.clone(), format!("{}/meta", self.key)];
        keys.extend(self.parts.iter().map(|p| p.key.clone()));
        keys
    }
}

/// The multipart-progress sidecar body: part index → `(len, crc32)` of the
/// parts that have durably landed for one shard upload. Written after each
/// part put (a tiny JSON document), read once at the start of a resumed
/// attempt. A part recorded here was put *before* the record — so a
/// matching `(len, crc)` plus `exists()` proves the durable part holds
/// exactly these bytes, with no read-back. Absent or torn sidecars degrade
/// to "nothing reusable" (conservative re-upload), never to corruption.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PartProgress {
    pub parts: std::collections::BTreeMap<usize, (u64, u32)>,
}

impl PartProgress {
    /// Streaming single-pass encode: bytes go straight into the output
    /// buffer, no intermediate `Json` tree. Byte-identical to
    /// [`PartProgress::encode_dom`] (the retained oracle) — keys are
    /// emitted in the sorted order the DOM's BTreeMap would produce.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = JsonWriter::with_capacity(16 + self.parts.len() * 48);
        w.begin_obj();
        w.key("parts");
        w.begin_arr();
        for (&k, &(len, crc)) in &self.parts {
            w.begin_obj();
            w.key("crc32");
            w.u32(crc);
            w.key("k");
            w.usize(k);
            w.key("len");
            w.u64(len);
            w.end_obj();
        }
        w.end_arr();
        w.end_obj();
        w.raw(b"\n");
        w.finish()
    }

    /// DOM-tree encode — the pre-streaming spelling, retained as the
    /// byte-identity oracle the tests compare [`PartProgress::encode`]
    /// against.
    pub fn encode_dom(&self) -> Vec<u8> {
        let parts = Json::Arr(
            self.parts
                .iter()
                .map(|(&k, &(len, crc))| {
                    Json::obj(vec![
                        ("k", Json::from(k)),
                        ("len", Json::num(len as f64)),
                        ("crc32", Json::num(crc as f64)),
                    ])
                })
                .collect(),
        );
        format!("{}\n", Json::obj(vec![("parts", parts)])).into_bytes()
    }

    /// Streaming incremental decode: walks the document in place, parsing
    /// counts straight from the digit runs (exact over the full u64 range,
    /// negatives/fractions rejected). Unknown fields are skipped.
    pub fn decode(bytes: &[u8]) -> Result<PartProgress> {
        let text = std::str::from_utf8(bytes).context("part sidecar is not utf-8")?;
        let mut r = JsonReader::new(text);
        let mut parts = std::collections::BTreeMap::new();
        r.obj_begin()?;
        while let Some(top) = r.key()? {
            if top == "parts" {
                r.arr_begin()?;
                while r.arr_next()? {
                    r.obj_begin()?;
                    let (mut k, mut len, mut crc) = (None, None, None);
                    while let Some(f) = r.key()? {
                        match f.as_str() {
                            "k" => k = Some(r.usize()?),
                            "len" => len = Some(r.u64()?),
                            "crc32" => crc = Some(r.u32()?),
                            _ => r.skip_value()?,
                        }
                    }
                    parts.insert(
                        k.ok_or_else(|| anyhow!("part record missing `k`"))?,
                        (
                            len.ok_or_else(|| anyhow!("part record missing `len`"))?,
                            crc.ok_or_else(|| anyhow!("part record missing `crc32`"))?,
                        ),
                    );
                }
            } else {
                r.skip_value()?;
            }
        }
        r.end()?;
        Ok(PartProgress { parts })
    }

    /// DOM-tree decode — retained as the value-identity oracle for
    /// [`PartProgress::decode`]. Uses the strict integer accessors, so it
    /// rejects the same lossy values the streaming reader does.
    pub fn decode_dom(bytes: &[u8]) -> Result<PartProgress> {
        let text = std::str::from_utf8(bytes).context("part sidecar is not utf-8")?;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("part sidecar: {e}"))?;
        let mut parts = std::collections::BTreeMap::new();
        for p in j.req_arr("parts")? {
            parts.insert(p.req_usize("k")?, (p.req_u64("len")?, p.req_u32("crc32")?));
        }
        Ok(PartProgress { parts })
    }

    /// Load the sidecar at `key`; absent or undecodable → empty progress.
    pub fn load(storage: &dyn Storage, key: &str) -> PartProgress {
        storage
            .get(key)
            .ok()
            .and_then(|b| PartProgress::decode(&b).ok())
            .unwrap_or_default()
    }

    /// Is part `k` durably landed with exactly these bytes?
    pub fn matches(&self, k: usize, len: u64, crc: u32) -> bool {
        self.parts.get(&k) == Some(&(len, crc))
    }

    pub fn record(&mut self, k: usize, len: u64, crc: u32) {
        self.parts.insert(k, (len, crc));
    }

    /// The recorded `(len, crc)` of part `k`, if it has durably landed.
    pub fn get(&self, k: usize) -> Option<(u64, u32)> {
        self.parts.get(&k).copied()
    }

    pub fn len(&self) -> usize {
        self.parts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parts.is_empty()
    }
}

/// One parallelism-neutral atom of a manifest: a contiguous byte range of
/// the **global payload stream** (every stage payload concatenated in stage
/// order) and the shard blob that holds it. Together the atoms form a
/// tensor-range index over the checkpoint that is independent of the
/// dp/tp/pp split it was persisted under — the reshape pass
/// (`persist::reshape`) plans byte-range fetches per *target* shard against
/// this index, so any committed round can be regathered into a different
/// stage shape.
///
/// `start` is the global-stream offset (`sum(stage_bytes[..stage]) +
/// shard.offset`); `len` and `key` mirror the owning shard. The index is
/// redundant with the shard list for manifests this crate wrote (and
/// [`PersistManifest::atom_index`] derives it on the fly for version-0
/// manifests, so old manifests reshape too) — carrying it explicitly
/// versions the layout contract on the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AtomEntry {
    pub stage: usize,
    /// byte offset into the global payload stream (stages concatenated)
    pub start: u64,
    pub len: u64,
    /// the shard blob holding these bytes (its first byte is `start`)
    pub key: String,
}

/// A committed durable checkpoint: the cluster-wide record that every shard
/// of one in-memory snapshot round landed in storage.
#[derive(Debug, Clone, PartialEq)]
pub struct PersistManifest {
    pub model: String,
    /// the step at which this persist was *requested* (names the keys)
    pub step: u64,
    /// the in-memory snapshot version this durable copy was drained from
    pub version: u64,
    /// the step whose state this durable copy actually contains — with the
    /// asynchronous save path the drained round can be older than the
    /// enqueue step, so cross-tier "which is newer" comparisons must use
    /// this, not `step`
    pub snapshot_step: u64,
    /// per-stage payload sizes (restore pre-allocates from these)
    pub stage_bytes: Vec<u64>,
    pub shards: Vec<ShardEntry>,
    /// `Some(step)` makes this a **delta** manifest: shards with `extents`
    /// patch over the payload reconstructed from the manifest committed at
    /// `step` (which may itself chain further back). `None` is a full
    /// (base) manifest — the only kind prior wire formats could express,
    /// and the field is omitted from the encoding in that case so base
    /// manifests stay byte-identical to them.
    pub base_step: Option<u64>,
    /// the parallelism-neutral tensor-range index (base manifests only;
    /// deltas inherit their base's). Omitted from the encoding when empty,
    /// so pre-atom manifests decode and re-encode byte-identically;
    /// [`PersistManifest::atom_index`] derives the equivalent index from
    /// the shard tiling when absent.
    pub atoms: Vec<AtomEntry>,
}

impl PersistManifest {
    /// Streaming single-pass encode — the per-commit hot path. No
    /// intermediate `Json` tree; keys are emitted in the sorted order the
    /// DOM's BTreeMap would produce, so the output is byte-identical to
    /// [`PersistManifest::encode_dom`] (the retained oracle) and the wire
    /// format is unchanged from PR 3/4 — including omitting `parts` for
    /// single-blob shards.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = JsonWriter::with_capacity(
            128 + self.shards.len() * 192 + self.atoms.len() * 96,
        );
        w.begin_obj();
        // "atoms" then "base_step" sort before every other top-level key;
        // both are omitted when absent so pre-atom base manifests stay
        // byte-identical to the old format
        if !self.atoms.is_empty() {
            w.key("atoms");
            w.begin_arr();
            for a in &self.atoms {
                w.begin_obj();
                w.key("key");
                w.str(&a.key);
                w.key("len");
                w.u64(a.len);
                w.key("stage");
                w.usize(a.stage);
                w.key("start");
                w.u64(a.start);
                w.end_obj();
            }
            w.end_arr();
        }
        if let Some(b) = self.base_step {
            w.key("base_step");
            w.u64(b);
        }
        w.key("model");
        w.str(&self.model);
        w.key("shards");
        w.begin_arr();
        for s in &self.shards {
            w.begin_obj();
            w.key("crc32");
            w.u32(s.crc32);
            if !s.extents.is_empty() {
                // flat [start0, len0, start1, len1, ...] — half the braces
                // of an object per extent on what can be a long list
                w.key("extents");
                w.begin_arr();
                for &(start, len) in &s.extents {
                    w.u64(start);
                    w.u64(len);
                }
                w.end_arr();
            }
            w.key("key");
            w.str(&s.key);
            w.key("len");
            w.u64(s.len);
            w.key("node");
            w.usize(s.node);
            w.key("offset");
            w.u64(s.offset);
            if !s.parts.is_empty() {
                w.key("parts");
                w.begin_arr();
                for p in &s.parts {
                    w.begin_obj();
                    w.key("crc32");
                    w.u32(p.crc32);
                    w.key("key");
                    w.str(&p.key);
                    w.key("len");
                    w.u64(p.len);
                    w.end_obj();
                }
                w.end_arr();
            }
            w.key("stage");
            w.usize(s.stage);
            w.end_obj();
        }
        w.end_arr();
        w.key("snapshot_step");
        w.u64(self.snapshot_step);
        w.key("stage_bytes");
        w.begin_arr();
        for &b in &self.stage_bytes {
            w.u64(b);
        }
        w.end_arr();
        w.key("step");
        w.u64(self.step);
        w.key("version");
        w.u64(self.version);
        w.end_obj();
        w.raw(b"\n");
        w.finish()
    }

    /// DOM-tree encode — the pre-streaming spelling, retained as the
    /// byte-identity oracle for [`PersistManifest::encode`].
    pub fn encode_dom(&self) -> Vec<u8> {
        let shards = Json::Arr(
            self.shards
                .iter()
                .map(|s| {
                    let mut fields = vec![
                        ("key", Json::str(s.key.clone())),
                        ("stage", Json::from(s.stage)),
                        ("node", Json::from(s.node)),
                        ("offset", Json::num(s.offset as f64)),
                        ("len", Json::num(s.len as f64)),
                        ("crc32", Json::num(s.crc32 as f64)),
                    ];
                    if !s.extents.is_empty() {
                        fields.push((
                            "extents",
                            Json::Arr(
                                s.extents
                                    .iter()
                                    .flat_map(|&(start, len)| {
                                        [Json::num(start as f64), Json::num(len as f64)]
                                    })
                                    .collect(),
                            ),
                        ));
                    }
                    // single-blob shards keep the PR-3 wire format exactly;
                    // only multipart shards carry the extra field
                    if !s.parts.is_empty() {
                        fields.push((
                            "parts",
                            Json::Arr(
                                s.parts
                                    .iter()
                                    .map(|p| {
                                        Json::obj(vec![
                                            ("key", Json::str(p.key.clone())),
                                            ("len", Json::num(p.len as f64)),
                                            ("crc32", Json::num(p.crc32 as f64)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ));
                    }
                    Json::obj(fields)
                })
                .collect(),
        );
        let mut top = vec![
            ("model", Json::str(self.model.clone())),
            ("step", Json::num(self.step as f64)),
            ("version", Json::num(self.version as f64)),
            ("snapshot_step", Json::num(self.snapshot_step as f64)),
            (
                "stage_bytes",
                Json::Arr(self.stage_bytes.iter().map(|&b| Json::num(b as f64)).collect()),
            ),
            ("shards", shards),
        ];
        if let Some(b) = self.base_step {
            top.push(("base_step", Json::num(b as f64)));
        }
        if !self.atoms.is_empty() {
            top.push((
                "atoms",
                Json::Arr(
                    self.atoms
                        .iter()
                        .map(|a| {
                            Json::obj(vec![
                                ("key", Json::str(a.key.clone())),
                                ("stage", Json::from(a.stage)),
                                ("start", Json::num(a.start as f64)),
                                ("len", Json::num(a.len as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        let j = Json::obj(top);
        format!("{j}\n").into_bytes()
    }

    /// Streaming incremental decode: no intermediate tree, counts and key
    /// components parsed straight from the digit runs (exact over the full
    /// u64 range; negatives, fractions, and NaN are rejected instead of
    /// being silently truncated as the old `req_f64(...) as u64` did).
    /// Field order independent; unknown fields are skipped.
    pub fn decode(bytes: &[u8]) -> Result<PersistManifest> {
        let text = std::str::from_utf8(bytes).context("manifest is not utf-8")?;
        let mut r = JsonReader::new(text);
        let mut model = None;
        let mut step = None;
        let mut version = None;
        let mut snapshot_step = None;
        let mut stage_bytes = None;
        let mut shards = None;
        let mut base_step = None;
        let mut atoms = Vec::new();
        r.obj_begin()?;
        while let Some(top) = r.key()? {
            match top.as_str() {
                "model" => model = Some(r.str()?),
                "step" => step = Some(r.u64()?),
                "version" => version = Some(r.u64()?),
                "snapshot_step" => snapshot_step = Some(r.u64()?),
                "base_step" => base_step = Some(r.u64()?),
                "atoms" => {
                    r.arr_begin()?;
                    while r.arr_next()? {
                        atoms.push(decode_atom(&mut r)?);
                    }
                }
                "stage_bytes" => {
                    let mut v = Vec::new();
                    r.arr_begin()?;
                    while r.arr_next()? {
                        v.push(r.u64()?);
                    }
                    stage_bytes = Some(v);
                }
                "shards" => {
                    let mut v = Vec::new();
                    r.arr_begin()?;
                    while r.arr_next()? {
                        v.push(decode_shard(&mut r)?);
                    }
                    shards = Some(v);
                }
                _ => r.skip_value()?,
            }
        }
        r.end()?;
        Ok(PersistManifest {
            model: model.ok_or_else(|| anyhow!("manifest missing `model`"))?,
            step: step.ok_or_else(|| anyhow!("manifest missing `step`"))?,
            version: version.ok_or_else(|| anyhow!("manifest missing `version`"))?,
            snapshot_step: snapshot_step
                .ok_or_else(|| anyhow!("manifest missing `snapshot_step`"))?,
            stage_bytes: stage_bytes.ok_or_else(|| anyhow!("manifest missing `stage_bytes`"))?,
            shards: shards.ok_or_else(|| anyhow!("manifest missing `shards`"))?,
            base_step,
            atoms,
        })
    }

    /// DOM-tree decode — retained as the value-identity oracle for
    /// [`PersistManifest::decode`]. Uses the strict integer accessors
    /// (`req_u64`/`req_u32`), so it rejects the same lossy values the
    /// streaming reader does.
    pub fn decode_dom(bytes: &[u8]) -> Result<PersistManifest> {
        let text = std::str::from_utf8(bytes).context("manifest is not utf-8")?;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let model = j.req_str("model")?.to_string();
        let step = j.req_u64("step")?;
        let version = j.req_u64("version")?;
        let snapshot_step = j.req_u64("snapshot_step")?;
        let base_step = match j.get("base_step") {
            Some(v) => Some(v.as_u64().context("invalid base_step")?),
            None => None,
        };
        let stage_bytes = j
            .req_arr("stage_bytes")?
            .iter()
            .map(|v| v.as_u64().context("invalid stage_bytes entry"))
            .collect::<Result<Vec<u64>>>()?;
        let mut shards = Vec::new();
        for s in j.req_arr("shards")? {
            let mut parts = Vec::new();
            if let Some(arr) = s.get("parts").and_then(Json::as_arr) {
                for p in arr {
                    parts.push(PartEntry {
                        key: p.req_str("key")?.to_string(),
                        len: p.req_u64("len")?,
                        crc32: p.req_u32("crc32")?,
                    });
                }
            }
            let mut extents = Vec::new();
            if let Some(arr) = s.get("extents").and_then(Json::as_arr) {
                let flat = arr
                    .iter()
                    .map(|v| v.as_u64().context("invalid extents entry"))
                    .collect::<Result<Vec<u64>>>()?;
                anyhow::ensure!(flat.len() % 2 == 0, "extents list has an odd length");
                extents = flat.chunks_exact(2).map(|c| (c[0], c[1])).collect();
            }
            shards.push(ShardEntry {
                key: s.req_str("key")?.to_string(),
                stage: s.req_usize("stage")?,
                node: s.req_usize("node")?,
                offset: s.req_u64("offset")?,
                len: s.req_u64("len")?,
                crc32: s.req_u32("crc32")?,
                extents,
                parts,
            });
        }
        let mut atoms = Vec::new();
        if let Some(arr) = j.get("atoms").and_then(Json::as_arr) {
            for a in arr {
                atoms.push(AtomEntry {
                    stage: a.req_usize("stage")?,
                    start: a.req_u64("start")?,
                    len: a.req_u64("len")?,
                    key: a.req_str("key")?.to_string(),
                });
            }
        }
        Ok(PersistManifest {
            model,
            step,
            version,
            snapshot_step,
            stage_bytes,
            shards,
            base_step,
            atoms,
        })
    }

    /// The parallelism-neutral tensor-range index of this manifest: the
    /// declared `atoms` when present (validated against the shard tiling),
    /// otherwise **derived** from the shards — so version-0 manifests,
    /// which never carried the index, reshape exactly like new ones. The
    /// result tiles the global payload stream contiguously, ascending.
    pub fn atom_index(&self) -> Result<Vec<AtomEntry>> {
        let derived = derive_atoms(&self.stage_bytes, &self.shards)?;
        if self.atoms.is_empty() {
            return Ok(derived);
        }
        let mut declared = self.atoms.clone();
        declared.sort_by_key(|a| a.start);
        anyhow::ensure!(
            declared == derived,
            "manifest at step {} declares an atom index inconsistent with \
             its shard tiling",
            self.step
        );
        Ok(declared)
    }
}

/// Derive the atom index of a **full** manifest from its shard tiling:
/// one atom per shard, `start` = the shard's offset into the global
/// payload stream (stage payloads concatenated in stage order). Fails on
/// manifests whose shards do not tile the stages exactly.
pub fn derive_atoms(stage_bytes: &[u64], shards: &[ShardEntry]) -> Result<Vec<AtomEntry>> {
    let mut prefix = vec![0u64; stage_bytes.len()];
    let mut acc = 0u64;
    for (i, &b) in stage_bytes.iter().enumerate() {
        prefix[i] = acc;
        acc += b;
    }
    let mut order: Vec<usize> = (0..shards.len()).collect();
    order.sort_by_key(|&i| (shards[i].stage, shards[i].offset));
    let mut atoms = Vec::with_capacity(shards.len());
    let mut cursor = 0u64;
    for &i in &order {
        let s = &shards[i];
        anyhow::ensure!(
            s.stage < stage_bytes.len(),
            "shard `{}` names stage {} out of range",
            s.key,
            s.stage
        );
        let start = prefix[s.stage] + s.offset;
        anyhow::ensure!(
            start == cursor && s.offset + s.len <= stage_bytes[s.stage],
            "shards do not tile the payload stream at byte {cursor} \
             (shard `{}`)",
            s.key
        );
        atoms.push(AtomEntry { stage: s.stage, start, len: s.len, key: s.key.clone() });
        cursor = start + s.len;
    }
    anyhow::ensure!(
        cursor == acc,
        "shards cover {cursor} of {acc} payload-stream bytes"
    );
    Ok(atoms)
}

/// One shard object from the streaming reader (cursor just past its `{`'s
/// predecessor — `obj_begin` is called here).
fn decode_shard(r: &mut JsonReader<'_>) -> Result<ShardEntry> {
    r.obj_begin()?;
    let mut key = None;
    let mut stage = None;
    let mut node = None;
    let mut offset = None;
    let mut len = None;
    let mut crc32 = None;
    let mut extents = Vec::new();
    let mut parts = Vec::new();
    while let Some(f) = r.key()? {
        match f.as_str() {
            "key" => key = Some(r.str()?),
            "stage" => stage = Some(r.usize()?),
            "node" => node = Some(r.usize()?),
            "offset" => offset = Some(r.u64()?),
            "len" => len = Some(r.u64()?),
            "crc32" => crc32 = Some(r.u32()?),
            "extents" => {
                let mut flat = Vec::new();
                r.arr_begin()?;
                while r.arr_next()? {
                    flat.push(r.u64()?);
                }
                anyhow::ensure!(flat.len() % 2 == 0, "extents list has an odd length");
                extents = flat.chunks_exact(2).map(|c| (c[0], c[1])).collect();
            }
            "parts" => {
                r.arr_begin()?;
                while r.arr_next()? {
                    parts.push(decode_part(r)?);
                }
            }
            _ => r.skip_value()?,
        }
    }
    Ok(ShardEntry {
        key: key.ok_or_else(|| anyhow!("shard missing `key`"))?,
        stage: stage.ok_or_else(|| anyhow!("shard missing `stage`"))?,
        node: node.ok_or_else(|| anyhow!("shard missing `node`"))?,
        offset: offset.ok_or_else(|| anyhow!("shard missing `offset`"))?,
        len: len.ok_or_else(|| anyhow!("shard missing `len`"))?,
        crc32: crc32.ok_or_else(|| anyhow!("shard missing `crc32`"))?,
        extents,
        parts,
    })
}

fn decode_part(r: &mut JsonReader<'_>) -> Result<PartEntry> {
    r.obj_begin()?;
    let mut key = None;
    let mut len = None;
    let mut crc32 = None;
    while let Some(f) = r.key()? {
        match f.as_str() {
            "key" => key = Some(r.str()?),
            "len" => len = Some(r.u64()?),
            "crc32" => crc32 = Some(r.u32()?),
            _ => r.skip_value()?,
        }
    }
    Ok(PartEntry {
        key: key.ok_or_else(|| anyhow!("part missing `key`"))?,
        len: len.ok_or_else(|| anyhow!("part missing `len`"))?,
        crc32: crc32.ok_or_else(|| anyhow!("part missing `crc32`"))?,
    })
}

fn decode_atom(r: &mut JsonReader<'_>) -> Result<AtomEntry> {
    r.obj_begin()?;
    let mut key = None;
    let mut stage = None;
    let mut start = None;
    let mut len = None;
    while let Some(f) = r.key()? {
        match f.as_str() {
            "key" => key = Some(r.str()?),
            "stage" => stage = Some(r.usize()?),
            "start" => start = Some(r.u64()?),
            "len" => len = Some(r.u64()?),
            _ => r.skip_value()?,
        }
    }
    Ok(AtomEntry {
        stage: stage.ok_or_else(|| anyhow!("atom missing `stage`"))?,
        start: start.ok_or_else(|| anyhow!("atom missing `start`"))?,
        len: len.ok_or_else(|| anyhow!("atom missing `len`"))?,
        key: key.ok_or_else(|| anyhow!("atom missing `key`"))?,
    })
}

/// Every committed step of `model`, ascending.
pub fn persisted_steps(storage: &dyn Storage, model: &str) -> Vec<u64> {
    let prefix = manifest_prefix(model);
    let mut steps: Vec<u64> = storage
        .list()
        .into_iter()
        .filter_map(|k| step_of_key(&k, &prefix))
        .collect();
    steps.sort_unstable();
    steps.dedup();
    steps
}

/// Fetch one manifest shard directly into `out` (pre-carved to `entry.len`
/// bytes), verifying as it goes. The CRC is **fused** into the fetch
/// (`Storage::get_into_checksummed`): the backend hashes each chunk while
/// it is cache-warm from the copy, so restore touches every byte once
/// instead of copy-then-rehash. Multipart shards additionally fold the
/// per-part CRCs into a whole-shard CRC via GF(2) `combine` (O(log len)
/// per part, no byte pass) and check it against the recorded `crc32` —
/// per-part checks alone cannot catch a parts list whose entries were
/// reordered consistently with their blobs. The shared leaf of the serial
/// and the parallel loader, so byte-for-byte semantics cannot diverge.
pub(crate) fn fetch_shard_into(storage: &dyn Storage, s: &ShardEntry, out: &mut [u8]) -> Result<()> {
    anyhow::ensure!(
        out.len() as u64 == s.len,
        "shard `{}` buffer is {} bytes, manifest says {}",
        s.key,
        out.len(),
        s.len
    );
    if s.parts.is_empty() {
        let crc = storage
            .get_into_checksummed(&s.key, out)
            .with_context(|| format!("shard `{}` missing or mis-sized", s.key))?;
        anyhow::ensure!(
            crc == s.crc32,
            "shard `{}` CRC mismatch — durable copy corrupt",
            s.key
        );
        return Ok(());
    }
    let covered: u64 = s.parts.iter().map(|p| p.len).sum();
    anyhow::ensure!(
        covered == s.len,
        "shard `{}` parts cover {covered} of {} bytes",
        s.key,
        s.len
    );
    let mut off = 0usize;
    let mut whole = crc32fast::Hasher::new();
    for p in &s.parts {
        let end = off + p.len as usize;
        let slice = &mut out[off..end];
        let crc = storage
            .get_into_checksummed(&p.key, slice)
            .with_context(|| format!("part `{}` missing or mis-sized", p.key))?;
        anyhow::ensure!(
            crc == p.crc32,
            "part `{}` CRC mismatch — durable copy corrupt",
            p.key
        );
        whole.combine(&crc32fast::Hasher::new_with_initial_len(crc, p.len));
        off = end;
    }
    anyhow::ensure!(
        whole.finalize() == s.crc32,
        "shard `{}` whole-shard CRC mismatch — parts list truncated or reordered",
        s.key
    );
    Ok(())
}

/// The pre-fusion leaf: plain `get_into` followed by a separate
/// `crc32fast::hash` pass over the bytes just moved (for multipart shards,
/// one pass per part plus a naive whole-shard pass — the shard-level check
/// spelled without `combine`). Retained as the semantics oracle for the
/// tests and the measured "separate hash pass" baseline of the
/// `crc_fused_restore` section of `benches/hotpath.rs`.
fn fetch_shard_into_separate(storage: &dyn Storage, s: &ShardEntry, out: &mut [u8]) -> Result<()> {
    anyhow::ensure!(
        out.len() as u64 == s.len,
        "shard `{}` buffer is {} bytes, manifest says {}",
        s.key,
        out.len(),
        s.len
    );
    if s.parts.is_empty() {
        storage
            .get_into(&s.key, out)
            .with_context(|| format!("shard `{}` missing or mis-sized", s.key))?;
        anyhow::ensure!(
            crc32fast::hash(out) == s.crc32,
            "shard `{}` CRC mismatch — durable copy corrupt",
            s.key
        );
        return Ok(());
    }
    let covered: u64 = s.parts.iter().map(|p| p.len).sum();
    anyhow::ensure!(
        covered == s.len,
        "shard `{}` parts cover {covered} of {} bytes",
        s.key,
        s.len
    );
    let mut off = 0usize;
    for p in &s.parts {
        let end = off + p.len as usize;
        let slice = &mut out[off..end];
        storage
            .get_into(&p.key, slice)
            .with_context(|| format!("part `{}` missing or mis-sized", p.key))?;
        anyhow::ensure!(
            crc32fast::hash(slice) == p.crc32,
            "part `{}` CRC mismatch — durable copy corrupt",
            p.key
        );
        off = end;
    }
    anyhow::ensure!(
        crc32fast::hash(out) == s.crc32,
        "shard `{}` whole-shard CRC mismatch — parts list truncated or reordered",
        s.key
    );
    Ok(())
}

/// Validate that `man`'s shards tile every stage payload exactly (no gap,
/// no overlap, no overrun) and return the shard indices in (stage, offset)
/// order — the order both loaders carve the output buffers in.
fn tiling_order(man: &PersistManifest) -> Result<Vec<usize>> {
    let mut order: Vec<usize> = (0..man.shards.len()).collect();
    order.sort_by_key(|&i| (man.shards[i].stage, man.shards[i].offset));
    let mut cursor: Vec<u64> = vec![0; man.stage_bytes.len()];
    for &i in &order {
        let s = &man.shards[i];
        anyhow::ensure!(
            s.stage < man.stage_bytes.len(),
            "shard `{}` names stage {} out of range",
            s.key,
            s.stage
        );
        anyhow::ensure!(
            s.offset == cursor[s.stage],
            "stage {} is not tiled contiguously at byte {} (shard `{}`)",
            s.stage,
            cursor[s.stage],
            s.key
        );
        cursor[s.stage] = s.offset + s.len;
        anyhow::ensure!(
            cursor[s.stage] <= man.stage_bytes[s.stage],
            "shard `{}` overruns its stage",
            s.key
        );
    }
    for (stage, (&need, &got)) in man.stage_bytes.iter().zip(&cursor).enumerate() {
        anyhow::ensure!(
            got == need,
            "stage {stage} under-covered: {got} of {need} bytes in the manifest"
        );
    }
    Ok(order)
}

/// Gather threads per manifest load. The gather is latency-bound (remote
/// gets), not compute-bound, so the cap is independent of the core count.
const LOAD_WORKERS: usize = 8;

/// Default bound on delta hops at restore, used by callers with no
/// `FtConfig` in hand (`load_latest`, the bench oracles). Kept at the
/// historical hard cap so those paths behave exactly as before the bound
/// became configurable. Callers that know the configured budget pass
/// `ft.delta_chain_max` through the `*_bounded` entry points instead.
pub const DEFAULT_CHAIN_BUDGET: u64 = 64;

/// Resolve the base→…→`man` manifest chain, base (a full manifest) first.
/// Every link must strictly decrease the step (no cycles), keep the stage
/// shape, and resolve to a committed manifest; the walk follows at most
/// `chain_budget` links (so `chain_budget + 1` manifests total, the base
/// included — the "+1 for the base" of `ft.delta_chain_max`). The engine
/// re-bases every `delta_chain_max` commits, so a longer walk means
/// corrupt or cyclic links — fail loudly instead of spinning.
pub(crate) fn load_chain(
    storage: &dyn Storage,
    man: &PersistManifest,
    chain_budget: u64,
) -> Result<Vec<PersistManifest>> {
    let mut chain = vec![man.clone()];
    while let Some(base) = chain.last().expect("non-empty").base_step {
        anyhow::ensure!(
            (chain.len() as u64) <= chain_budget,
            "delta chain from step {} exceeds {chain_budget} links",
            man.step
        );
        let cur = chain.last().expect("non-empty");
        anyhow::ensure!(
            base < cur.step,
            "delta manifest at step {} links forward to base {base}",
            cur.step
        );
        let bytes = storage
            .get(&manifest_key(&man.model, base))
            .with_context(|| format!("base manifest for step {base} is gone"))?;
        let prev = PersistManifest::decode(&bytes)?;
        anyhow::ensure!(
            prev.stage_bytes == man.stage_bytes,
            "base manifest at step {base} has a different stage shape"
        );
        chain.push(prev);
    }
    chain.reverse();
    Ok(chain)
}

/// Apply one delta manifest over the payload reconstructed so far: every
/// shard fetches only its extent bytes (nothing at all when unchanged) and
/// patches them in place, then verifies the whole reconstructed shard
/// against the recorded CRC.
fn apply_manifest_into(
    storage: &dyn Storage,
    man: &PersistManifest,
    stages: &mut [Vec<u8>],
) -> Result<()> {
    let order = tiling_order(man)?;
    anyhow::ensure!(
        stages.len() == man.stage_bytes.len()
            && stages.iter().zip(&man.stage_bytes).all(|(s, &b)| s.len() as u64 == b),
        "delta-chain buffers do not match the manifest's stage shape"
    );
    for &i in &order {
        let s = &man.shards[i];
        let (a, b) = (s.offset as usize, (s.offset + s.len) as usize);
        apply_delta_into(storage, s, &mut stages[s.stage][a..b])?;
    }
    Ok(())
}

/// Fetch a delta shard's extent blob (single or multipart) and patch it over
/// `out`, which holds the shard as reconstructed up to the previous chain
/// link. The recorded `crc32` covers the **patched** shard, so corruption of
/// the shipped bytes, the base bytes, or the extent list itself is caught
/// here before the chain result is trusted.
fn apply_delta_into(storage: &dyn Storage, s: &ShardEntry, out: &mut [u8]) -> Result<()> {
    anyhow::ensure!(
        out.len() as u64 == s.len,
        "shard `{}` buffer is {} bytes, manifest says {}",
        s.key,
        out.len(),
        s.len
    );
    let mut prev_end = 0u64;
    let mut delta_len = 0u64;
    for &(start, len) in &s.extents {
        anyhow::ensure!(
            start >= prev_end && len > 0 && start.checked_add(len).is_some_and(|e| e <= s.len),
            "shard `{}` extents must be ascending, non-empty, non-overlapping \
             and within the shard",
            s.key
        );
        prev_end = start + len;
        delta_len += len;
    }
    let mut blob = vec![0u8; delta_len as usize];
    if delta_len == 0 {
        // unchanged shard: no blob was ever uploaded; just re-verify below
    } else if s.parts.is_empty() {
        // no independent blob CRC is recorded for a single-blob delta — the
        // whole-shard check below covers those bytes
        storage
            .get_into(&s.key, &mut blob)
            .with_context(|| format!("delta shard `{}` missing or mis-sized", s.key))?;
    } else {
        let covered: u64 = s.parts.iter().map(|p| p.len).sum();
        anyhow::ensure!(
            covered == delta_len,
            "delta shard `{}` parts cover {covered} of {delta_len} extent bytes",
            s.key
        );
        let mut off = 0usize;
        for p in &s.parts {
            let end = off + p.len as usize;
            let crc = storage
                .get_into_checksummed(&p.key, &mut blob[off..end])
                .with_context(|| format!("part `{}` missing or mis-sized", p.key))?;
            anyhow::ensure!(
                crc == p.crc32,
                "part `{}` CRC mismatch — durable copy corrupt",
                p.key
            );
            off = end;
        }
    }
    let mut off = 0usize;
    for &(start, len) in &s.extents {
        out[start as usize..(start + len) as usize]
            .copy_from_slice(&blob[off..off + len as usize]);
        off += len as usize;
    }
    anyhow::ensure!(
        crc32fast::hash(out) == s.crc32,
        "shard `{}` reconstruction CRC mismatch — delta chain corrupt",
        s.key
    );
    Ok(())
}

/// Fail loudly on the shapes the full-manifest fast paths cannot serve: a
/// delta shard without a `base_step` link, or vice versa.
fn ensure_full_manifest(man: &PersistManifest) -> Result<()> {
    anyhow::ensure!(
        man.shards.iter().all(|s| s.extents.is_empty()),
        "manifest at step {} has delta shards but no base_step link",
        man.step
    );
    Ok(())
}

/// Fetch and verify one manifest's full payload — every shard present,
/// length- and CRC-clean, tiling each stage payload exactly — as a
/// **parallel sharded gather**: the stage buffers are pre-allocated and
/// carved into disjoint per-shard slices, then scoped worker threads fetch
/// and CRC-verify shards concurrently, stitching each directly into place
/// (mirroring the parallel in-memory restore; this is the checkpoint-
/// fallback restart path, where the serial NFS-shaped read loop dominated).
/// A **delta** manifest (`base_step` set) is reconstructed by walking its
/// chain to the base full manifest, parallel-gathering that, and applying
/// each subsequent delta in order — every patched shard verified against its
/// recorded whole-shard CRC before the result is trusted.
pub fn load_manifest_payload(
    storage: &dyn Storage,
    man: &PersistManifest,
) -> Result<Vec<Vec<u8>>> {
    load_manifest_payload_bounded(storage, man, DEFAULT_CHAIN_BUDGET)
}

/// [`load_manifest_payload`] with the delta-chain walk bounded to the
/// **configured** budget (`ft.delta_chain_max` delta hops plus the base)
/// instead of the historical [`DEFAULT_CHAIN_BUDGET`] hard cap — the entry
/// point the trainers use, so the restore walk and the engine's re-base
/// cadence cannot drift apart.
pub fn load_manifest_payload_bounded(
    storage: &dyn Storage,
    man: &PersistManifest,
    chain_budget: u64,
) -> Result<Vec<Vec<u8>>> {
    if man.base_step.is_none() {
        ensure_full_manifest(man)?;
        return load_manifest_payload_with(storage, man, fetch_shard_into);
    }
    let chain = load_chain(storage, man, chain_budget)?;
    ensure_full_manifest(&chain[0])?;
    let mut stages = load_manifest_payload_with(storage, &chain[0], fetch_shard_into)?;
    for link in &chain[1..] {
        apply_manifest_into(storage, link, &mut stages)?;
    }
    Ok(stages)
}

/// The parallel gather over the **pre-fusion leaf** (separate hash pass per
/// shard/part plus a naive whole-shard pass for multipart). Same carving,
/// same thread layout, same verification outcome as
/// [`load_manifest_payload`] — only the number of times each byte is
/// touched differs, which is exactly what the `crc_fused_restore` bench
/// section measures.
pub fn load_manifest_payload_separate(
    storage: &dyn Storage,
    man: &PersistManifest,
) -> Result<Vec<Vec<u8>>> {
    // a bench-only baseline: full manifests only, by design
    anyhow::ensure!(man.base_step.is_none(), "separate-pass loader cannot walk delta chains");
    ensure_full_manifest(man)?;
    load_manifest_payload_with(storage, man, fetch_shard_into_separate)
}

/// The shared parallel-gather harness, parameterized over the fetch leaf so
/// the production path and the kept baseline cannot drift structurally.
fn load_manifest_payload_with(
    storage: &dyn Storage,
    man: &PersistManifest,
    leaf: impl Fn(&dyn Storage, &ShardEntry, &mut [u8]) -> Result<()> + Sync,
) -> Result<Vec<Vec<u8>>> {
    let order = tiling_order(man)?;
    let mut out: Vec<Vec<u8>> =
        man.stage_bytes.iter().map(|&b| vec![0u8; b as usize]).collect();
    // carve every stage buffer into disjoint per-shard &mut slices; the
    // tiling order walks each stage front to back so split_at_mut suffices
    let mut work: Vec<(usize, &mut [u8])> = Vec::with_capacity(order.len());
    {
        let mut rests: Vec<&mut [u8]> = out.iter_mut().map(Vec::as_mut_slice).collect();
        for &i in &order {
            let s = &man.shards[i];
            let rest = std::mem::take(&mut rests[s.stage]);
            let (head, tail) = rest.split_at_mut(s.len as usize);
            work.push((i, head));
            rests[s.stage] = tail;
        }
    }
    let workers = work.len().clamp(1, LOAD_WORKERS);
    let chunk = work.len().div_ceil(workers).max(1);
    let mut results: Vec<Result<()>> = Vec::with_capacity(workers);
    let leaf = &leaf;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for batch in work.chunks_mut(chunk) {
            handles.push(scope.spawn(move || -> Result<()> {
                for (i, slice) in batch.iter_mut() {
                    leaf(storage, &man.shards[*i], slice)?;
                }
                Ok(())
            }));
        }
        for h in handles {
            results.push(
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("manifest gather thread panicked"))),
            );
        }
    });
    for r in results {
        r?;
    }
    Ok(out)
}

/// The pre-parallel serial loader: one shard (and one part) at a time, over
/// the same fused leaf as the parallel path. Kept as the measured baseline
/// for the `manifest_load_parallel_vs_serial` section of
/// `benches/hotpath.rs` and as the byte-identity oracle the parallel-path
/// tests compare against.
pub fn load_manifest_payload_serial(
    storage: &dyn Storage,
    man: &PersistManifest,
) -> Result<Vec<Vec<u8>>> {
    let chain = match man.base_step {
        None => {
            ensure_full_manifest(man)?;
            vec![man.clone()]
        }
        Some(_) => {
            let chain = load_chain(storage, man, DEFAULT_CHAIN_BUDGET)?;
            ensure_full_manifest(&chain[0])?;
            chain
        }
    };
    let base = &chain[0];
    let order = tiling_order(base)?;
    let mut out: Vec<Vec<u8>> =
        base.stage_bytes.iter().map(|&b| vec![0u8; b as usize]).collect();
    for &i in &order {
        let s = &base.shards[i];
        let (a, b) = (s.offset as usize, (s.offset + s.len) as usize);
        fetch_shard_into(storage, s, &mut out[s.stage][a..b])?;
    }
    for link in &chain[1..] {
        apply_manifest_into(storage, link, &mut out)?;
    }
    Ok(out)
}

/// Manifests that failed `PersistManifest::decode` during recovery
/// resolution — a brownout-torn newest manifest silently degrading
/// recovery to an older round used to leave zero signal; this counter (and
/// the paired `manifest_torn` obs instant, corr id = the manifest's step)
/// is that signal. Process-global because resolution runs before any
/// `Metrics` registry is in scope on the restart path.
static MANIFEST_TORN: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Total torn (undecodable) manifests skipped by recovery resolution since
/// process start.
pub fn manifest_torn_count() -> u64 {
    MANIFEST_TORN.load(std::sync::atomic::Ordering::Relaxed)
}

/// Record one torn manifest skip: bump the process-global counter and emit
/// the `manifest_torn` instant event with the manifest's step as the
/// correlation id.
pub(crate) fn note_torn_manifest(step: u64) {
    MANIFEST_TORN.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    crate::obs::instant(crate::obs::cat::PERSIST, "manifest_torn", step, 0);
}

/// The newest manifest of `model` that satisfies `accept` and whose every
/// shard loads and verifies. Older manifests are tried in turn, so a
/// corrupt, partially GC-ed, or shape-incompatible newer one degrades,
/// never blocks, recovery — but a manifest that fails to *decode* (torn by
/// a brownout mid-put) is counted and traced on the way past, never
/// skipped silently.
fn load_latest_matching(
    storage: &dyn Storage,
    model: &str,
    chain_budget: u64,
    accept: impl Fn(&PersistManifest) -> bool,
) -> Option<(PersistManifest, Vec<Vec<u8>>)> {
    let steps = persisted_steps(storage, model);
    for &step in steps.iter().rev() {
        let Ok(bytes) = storage.get(&manifest_key(model, step)) else {
            continue;
        };
        let Ok(man) = PersistManifest::decode(&bytes) else {
            note_torn_manifest(step);
            continue;
        };
        if !accept(&man) {
            continue;
        }
        if let Ok(stages) = load_manifest_payload_bounded(storage, &man, chain_budget) {
            return Some((man, stages));
        }
    }
    None
}

/// Resolve the newest **complete** durable checkpoint of `model`. Shard
/// blobs without a manifest (a crash between upload and commit) are
/// invisible here by construction.
pub fn load_latest(
    storage: &dyn Storage,
    model: &str,
) -> Result<Option<(PersistManifest, Vec<Vec<u8>>)>> {
    Ok(load_latest_matching(storage, model, DEFAULT_CHAIN_BUDGET, |_| true))
}

/// Does `legacy_key` name a strictly newer inline checkpoint than a
/// manifest containing `snapshot_step`? The two **steps** are compared
/// numerically — the old rendered-string comparison
/// (`step_key(model, snapshot_step) < legacy_key`) inherited the
/// model-component sensitivity the CAUTION in `checkpoint::storage` warns
/// about (a legacy key of a *different* model compares against the model
/// prefix, not the step) and broke past zero-pad width overflow (a 13-digit
/// step sorts *before* a 12-digit one). A legacy key whose step cannot be
/// parsed for this model never outranks a verified manifest.
pub(crate) fn legacy_is_newer(model: &str, snapshot_step: u64, legacy_key: &str) -> bool {
    let prefix = format!("{model}/step-");
    match step_of_key(legacy_key, &prefix) {
        Some(legacy_step) => legacy_step > snapshot_step,
        None => false,
    }
}

/// The trainers' case-3 (protection exceeded) durable-tier resolution: the
/// newest complete manifest holding exactly `stages` stage payloads — a
/// manifest persisted under a different parallelism layout is skipped, so
/// it degrades to older manifests or the legacy tier instead of aborting
/// recovery. Returns `None` when no manifest qualifies or when
/// `legacy_key` names a strictly newer inline checkpoint (the comparison
/// uses the manifest's `snapshot_step` — the state it actually contains —
/// against the step parsed out of the legacy key, numerically).
pub fn resolve_for_recovery(
    storage: &dyn Storage,
    model: &str,
    stages: usize,
    legacy_key: Option<&str>,
) -> Option<(PersistManifest, Vec<Vec<u8>>)> {
    resolve_for_recovery_bounded(storage, model, stages, legacy_key, DEFAULT_CHAIN_BUDGET)
}

/// [`resolve_for_recovery`] with the delta-chain walk bounded to the
/// configured `ft.delta_chain_max` budget.
pub fn resolve_for_recovery_bounded(
    storage: &dyn Storage,
    model: &str,
    stages: usize,
    legacy_key: Option<&str>,
    chain_budget: u64,
) -> Option<(PersistManifest, Vec<Vec<u8>>)> {
    let hit =
        load_latest_matching(storage, model, chain_budget, |m| m.stage_bytes.len() == stages)?;
    if let Some(k) = legacy_key {
        if legacy_is_newer(model, hit.0.snapshot_step, k) {
            return None;
        }
    }
    Some(hit)
}

/// Delete shard blobs and part-objects whose step has no committed manifest
/// and is older than `before_step` — the debris of crashed or aborted
/// persist jobs. Blobs at or past `before_step` may belong to an in-flight
/// upload and are left alone. Returns the number of blobs deleted.
pub fn sweep_orphan_shards(storage: &dyn Storage, model: &str, before_step: u64) -> usize {
    let manifested: BTreeSet<u64> = persisted_steps(storage, model).into_iter().collect();
    let keys = storage.list();
    sweep_orphans_in(storage, model, &manifested, before_step, &keys)
}

/// The sweep over an already-taken listing snapshot (`keys`), so callers
/// that just listed the store (the per-commit GC) don't pay another full
/// scan. `manifested` is the set of steps that had a committed manifest in
/// that same snapshot.
pub fn sweep_orphans_in(
    storage: &dyn Storage,
    model: &str,
    manifested: &BTreeSet<u64>,
    before_step: u64,
    keys: &[String],
) -> usize {
    let prefix = shard_prefix(model);
    let mut deleted = 0;
    for key in keys {
        if let Some(step) = step_of_key(key, &prefix) {
            if step < before_step
                && !manifested.contains(&step)
                && storage.delete(key).is_ok()
            {
                deleted += 1;
            }
        }
    }
    deleted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::MemStorage;

    fn sample() -> PersistManifest {
        PersistManifest {
            model: "m".into(),
            step: 40,
            version: 7,
            snapshot_step: 38,
            stage_bytes: vec![10, 6],
            shards: vec![
                ShardEntry {
                    key: shard_key("m", 40, 0, 0),
                    stage: 0,
                    node: 0,
                    offset: 0,
                    len: 6,
                    crc32: crc32fast::hash(&[1; 6]),
                    extents: vec![],
                    parts: vec![],
                },
                ShardEntry {
                    key: shard_key("m", 40, 0, 1),
                    stage: 0,
                    node: 1,
                    offset: 6,
                    len: 4,
                    crc32: crc32fast::hash(&[2; 4]),
                    extents: vec![],
                    parts: vec![],
                },
                ShardEntry {
                    key: shard_key("m", 40, 1, 0),
                    stage: 1,
                    node: 0,
                    offset: 0,
                    len: 6,
                    crc32: crc32fast::hash(&[3; 6]),
                    extents: vec![],
                    parts: vec![],
                },
            ],
            base_step: None,
            atoms: vec![],
        }
    }

    fn put_shards(s: &MemStorage, man: &PersistManifest) {
        s.put(&man.shards[0].key, &[1; 6]).unwrap();
        s.put(&man.shards[1].key, &[2; 4]).unwrap();
        s.put(&man.shards[2].key, &[3; 6]).unwrap();
    }

    /// A manifest whose second shard is multipart (two parts), with the
    /// part blobs landed in `s`.
    fn multipart_sample(s: &MemStorage) -> PersistManifest {
        let mut man = sample();
        let body: Vec<u8> = (0..4u8).collect();
        man.shards[1].crc32 = crc32fast::hash(&body);
        man.shards[1].parts = vec![
            PartEntry {
                key: part_key("m", 40, 0, 1, 0),
                len: 3,
                crc32: crc32fast::hash(&body[..3]),
            },
            PartEntry {
                key: part_key("m", 40, 0, 1, 1),
                len: 1,
                crc32: crc32fast::hash(&body[3..]),
            },
        ];
        s.put(&man.shards[0].key, &[1; 6]).unwrap();
        s.put(&man.shards[1].parts[0].key, &body[..3]).unwrap();
        s.put(&man.shards[1].parts[1].key, &body[3..]).unwrap();
        s.put(&man.shards[2].key, &[3; 6]).unwrap();
        man
    }

    #[test]
    fn manifest_roundtrip() {
        let m = sample();
        let back = PersistManifest::decode(&m.encode()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn streaming_codec_matches_dom_oracle() {
        let s = MemStorage::new();
        for man in [sample(), multipart_sample(&s)] {
            // byte identity: the streaming writer emits exactly what the
            // BTreeMap-backed DOM Display would
            assert_eq!(man.encode(), man.encode_dom());
            // value identity: both decoders read both encodings to the
            // same manifest
            assert_eq!(PersistManifest::decode(&man.encode()).unwrap(), man);
            assert_eq!(PersistManifest::decode_dom(&man.encode()).unwrap(), man);
        }
        let mut p = PartProgress::default();
        p.record(0, 4096, 0xDEAD_BEEF);
        p.record(7, 1, 0);
        for p in [PartProgress::default(), p] {
            assert_eq!(p.encode(), p.encode_dom());
            assert_eq!(PartProgress::decode(&p.encode()).unwrap(), p);
            assert_eq!(PartProgress::decode_dom(&p.encode()).unwrap(), p);
        }
    }

    #[test]
    fn streaming_codec_exact_above_2_53() {
        // the DOM's f64 numbers round above 2^53; the streaming codec
        // parses/prints digit runs and must stay exact to u64::MAX
        let man = PersistManifest {
            model: "m".into(),
            step: u64::MAX,
            version: (1 << 53) + 1,
            snapshot_step: u64::MAX - 1,
            stage_bytes: vec![(1 << 60) + 3],
            shards: vec![],
            base_step: Some((1 << 53) + 7),
            atoms: vec![],
        };
        let back = PersistManifest::decode(&man.encode()).unwrap();
        assert_eq!(back, man, "no precision loss through the streaming codec");
        // the strict DOM decoder refuses rather than silently rounding
        assert!(PersistManifest::decode_dom(&man.encode()).is_err());
    }

    #[test]
    fn decode_rejects_lossy_integers() {
        let good = String::from_utf8(sample().encode()).unwrap();
        // a negative or fractional count must fail BOTH decoders instead of
        // being truncated by `as u64` (the old bug)
        let neg = good.replace("\"step\":40", "\"step\":-40");
        assert_ne!(neg, good);
        assert!(PersistManifest::decode(neg.as_bytes()).is_err());
        assert!(PersistManifest::decode_dom(neg.as_bytes()).is_err());
        let frac = good.replace("\"step\":40", "\"step\":40.5");
        assert!(PersistManifest::decode(frac.as_bytes()).is_err());
        assert!(PersistManifest::decode_dom(frac.as_bytes()).is_err());
        // crc32 must fit u32 (prefixing digits makes every crc huge)
        let wide = good.replace("\"crc32\":", "\"crc32\":4294967296");
        assert!(PersistManifest::decode(wide.as_bytes()).is_err());
        assert!(PersistManifest::decode_dom(wide.as_bytes()).is_err());
    }

    #[test]
    fn multipart_manifest_roundtrip_and_load() {
        let s = MemStorage::new();
        let man = multipart_sample(&s);
        let back = PersistManifest::decode(&man.encode()).unwrap();
        assert_eq!(back, man, "parts survive the wire format");
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        let (_, stages) = load_latest(&s, "m").unwrap().unwrap();
        let mut expect0 = vec![1u8; 6];
        expect0.extend(0..4u8);
        assert_eq!(stages[0], expect0, "parts stitched in order");
        assert_eq!(stages[1], vec![3u8; 6]);
        // serial oracle agrees byte for byte
        assert_eq!(load_manifest_payload_serial(&s, &man).unwrap(), stages);
    }

    #[test]
    fn loaders_reject_consistently_reordered_parts() {
        // Swap the two part ENTRIES of the multipart shard but leave the
        // part blobs in place: every per-part CRC still matches its entry
        // and the covered length is unchanged, so only the whole-shard
        // check (GF(2) combine on the fused path, the naive extra hash pass
        // on the separate path) can catch that the stitched bytes are in
        // the wrong order.
        let s = MemStorage::new();
        let mut man = multipart_sample(&s);
        man.shards[1].parts.swap(0, 1);
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        let e = load_manifest_payload(&s, &man).unwrap_err().to_string();
        assert!(e.contains("whole-shard"), "fused path names the shard-level check: {e}");
        assert!(load_manifest_payload_separate(&s, &man).is_err());
        assert!(load_manifest_payload_serial(&s, &man).is_err());
        assert!(load_latest(&s, "m").unwrap().is_none());
    }

    #[test]
    fn separate_loader_is_byte_identical_oracle() {
        // fused production path and the kept pre-fusion baseline agree byte
        // for byte on both single-blob and multipart manifests
        let s = MemStorage::new();
        let man = multipart_sample(&s);
        let fused = load_manifest_payload(&s, &man).unwrap();
        assert_eq!(load_manifest_payload_separate(&s, &man).unwrap(), fused);
        assert_eq!(load_manifest_payload_serial(&s, &man).unwrap(), fused);
        let s2 = MemStorage::new();
        let man2 = sample();
        put_shards(&s2, &man2);
        assert_eq!(
            load_manifest_payload(&s2, &man2).unwrap(),
            load_manifest_payload_separate(&s2, &man2).unwrap()
        );
        // and both reject the same corruption
        s2.put(&man2.shards[0].key, &[7; 6]).unwrap();
        assert!(load_manifest_payload(&s2, &man2).is_err());
        assert!(load_manifest_payload_separate(&s2, &man2).is_err());
    }

    #[test]
    fn multipart_load_verifies_per_part_crc() {
        let s = MemStorage::new();
        let man = multipart_sample(&s);
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        // corrupt the second part in place (same length, different bytes)
        s.put(&man.shards[1].parts[1].key, &[0xEE]).unwrap();
        assert!(load_manifest_payload(&s, &man).is_err());
        assert!(load_manifest_payload_serial(&s, &man).is_err());
        assert!(load_latest(&s, "m").unwrap().is_none());
    }

    #[test]
    fn storage_keys_cover_blob_parts_and_sidecar() {
        let s = MemStorage::new();
        let man = multipart_sample(&s);
        let keys = man.shards[0].storage_keys();
        assert_eq!(keys, vec![
            man.shards[0].key.clone(),
            format!("{}/meta", man.shards[0].key),
        ]);
        let keys = man.shards[1].storage_keys();
        assert_eq!(keys.len(), 4);
        assert!(keys.contains(&man.shards[1].key));
        assert!(keys.contains(&man.shards[1].parts[0].key));
        assert!(keys.contains(&part_meta_key("m", 40, 0, 1)), "sidecar swept with its version");
    }

    #[test]
    fn part_progress_roundtrip_and_conservative_load() {
        let mut p = PartProgress::default();
        p.record(0, 4096, 0xDEAD_BEEF);
        p.record(3, 128, 7);
        let back = PartProgress::decode(&p.encode()).unwrap();
        assert_eq!(back, p);
        assert!(back.matches(0, 4096, 0xDEAD_BEEF));
        assert!(!back.matches(0, 4096, 1), "crc mismatch rejected");
        assert!(!back.matches(1, 4096, 7), "unrecorded part rejected");
        // absent or torn sidecars degrade to empty, never error
        let s = MemStorage::new();
        assert_eq!(PartProgress::load(&s, "missing"), PartProgress::default());
        s.put("torn", b"{nope").unwrap();
        assert_eq!(PartProgress::load(&s, "torn"), PartProgress::default());
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(PersistManifest::decode(b"{").is_err());
        assert!(PersistManifest::decode(b"{\"model\": \"m\"}").is_err());
        assert!(PersistManifest::decode(&[0xFF, 0xFE]).is_err());
    }

    #[test]
    fn step_parsing_from_keys() {
        assert_eq!(
            step_of_key(&manifest_key("m", 123), &manifest_prefix("m")),
            Some(123)
        );
        assert_eq!(
            step_of_key(&shard_key("m", 55, 2, 3), &shard_prefix("m")),
            Some(55)
        );
        // part-objects parse to the same step as their shard
        assert_eq!(
            step_of_key(&part_key("m", 55, 2, 3, 7), &shard_prefix("m")),
            Some(55)
        );
        // other models / legacy checkpoint keys don't parse
        assert_eq!(step_of_key("other/manifest/step-000000000001", &manifest_prefix("m")), None);
        assert_eq!(step_of_key("m/step-000000000001", &manifest_prefix("m")), None);
    }

    #[test]
    fn load_latest_requires_complete_shards() {
        let s = MemStorage::new();
        let man = sample();
        // manifest committed but one shard missing (GC race / corruption):
        // must be skipped, not returned torn
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        s.delete(&man.shards[1].key).unwrap();
        assert!(load_latest(&s, "m").unwrap().is_none());
        // with every shard back, it loads and stitches
        put_shards(&s, &man);
        let (back, stages) = load_latest(&s, "m").unwrap().unwrap();
        assert_eq!(back.step, 40);
        assert_eq!(stages[0], {
            let mut v = vec![1u8; 6];
            v.extend_from_slice(&[2; 4]);
            v
        });
        assert_eq!(stages[1], vec![3u8; 6]);
    }

    #[test]
    fn load_latest_verifies_crc() {
        let s = MemStorage::new();
        let man = sample();
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        // corrupt one shard in place
        s.put(&man.shards[2].key, &[9; 6]).unwrap();
        assert!(load_latest(&s, "m").unwrap().is_none());
    }

    #[test]
    fn parallel_load_matches_serial_oracle() {
        let s = MemStorage::new();
        let man = sample();
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        assert_eq!(
            load_manifest_payload(&s, &man).unwrap(),
            load_manifest_payload_serial(&s, &man).unwrap()
        );
    }

    #[test]
    fn loaders_reject_non_tiling_manifests() {
        let s = MemStorage::new();
        let mut man = sample();
        put_shards(&s, &man);
        // overlap: shard 1 claims offset 4 instead of 6 (gap at the tail)
        man.shards[1].offset = 4;
        assert!(load_manifest_payload(&s, &man).is_err());
        assert!(load_manifest_payload_serial(&s, &man).is_err());
    }

    #[test]
    fn newest_complete_manifest_wins_over_torn_newer() {
        let s = MemStorage::new();
        let man = sample();
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        // a newer manifest exists but its shards never landed (crash after
        // the commit of an empty round is impossible, but a corrupt blob
        // store can produce this): fall back to step 40
        let mut newer = sample();
        newer.step = 60;
        for sh in &mut newer.shards {
            sh.key = shard_key("m", 60, sh.stage, sh.node);
        }
        s.put(&manifest_key("m", 60), &newer.encode()).unwrap();
        let (back, _) = load_latest(&s, "m").unwrap().unwrap();
        assert_eq!(back.step, 40);
    }

    #[test]
    fn recovery_resolution_filters_shape_and_respects_newer_legacy() {
        use crate::checkpoint::storage::step_key;
        let s = MemStorage::new();
        let man = sample(); // 2 stages, snapshot_step 38
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);

        // shape filter: a 1-stage run must NOT restore the 2-stage manifest
        assert!(resolve_for_recovery(&s, "m", 1, None).is_none());
        let (hit, stages) = resolve_for_recovery(&s, "m", 2, None).unwrap();
        assert_eq!(hit.step, 40);
        assert_eq!(stages.len(), 2);

        // cross-tier tie-break uses the CONTAINED step (38), not the
        // request step (40): a legacy checkpoint at 39 is newer state
        let legacy_newer = step_key("m", 39);
        assert!(resolve_for_recovery(&s, "m", 2, Some(legacy_newer.as_str())).is_none());
        let legacy_older = step_key("m", 37);
        assert!(resolve_for_recovery(&s, "m", 2, Some(legacy_older.as_str())).is_some());
    }

    /// A committed base round at step 40 plus a delta round at step 44:
    /// shard 0 patched at bytes 1..3, shard 1 (stage 0) unchanged (no blob),
    /// shard 2 (stage 1) patched at its first and last byte.
    fn delta_sample(s: &MemStorage) -> (PersistManifest, PersistManifest) {
        let base = sample();
        put_shards(s, &base);
        s.put(&manifest_key("m", 40), &base.encode()).unwrap();
        let mut d = sample();
        d.step = 44;
        d.snapshot_step = 44;
        d.base_step = Some(40);
        for sh in &mut d.shards {
            sh.key = shard_key("m", 44, sh.stage, sh.node);
        }
        d.shards[0].extents = vec![(1, 2)];
        d.shards[0].crc32 = crc32fast::hash(&[1, 9, 9, 1, 1, 1]);
        s.put(&d.shards[0].key, &[9, 9]).unwrap();
        // shards[1] stays at the base bytes: empty extents, no blob at all
        d.shards[2].extents = vec![(0, 1), (5, 1)];
        d.shards[2].crc32 = crc32fast::hash(&[7, 3, 3, 3, 3, 8]);
        s.put(&d.shards[2].key, &[7, 8]).unwrap();
        s.put(&manifest_key("m", 44), &d.encode()).unwrap();
        (base, d)
    }

    #[test]
    fn base_manifest_wire_format_is_unchanged() {
        // full manifests must stay byte-compatible with the pre-delta,
        // pre-atom format
        let text = String::from_utf8(sample().encode()).unwrap();
        assert!(!text.contains("base_step"));
        assert!(!text.contains("extents"));
        assert!(!text.contains("atoms"));
    }

    #[test]
    fn delta_manifest_codec_roundtrip_matches_dom() {
        let s = MemStorage::new();
        let (_, d) = delta_sample(&s);
        assert_eq!(d.encode(), d.encode_dom());
        assert_eq!(PersistManifest::decode(&d.encode()).unwrap(), d);
        assert_eq!(PersistManifest::decode_dom(&d.encode()).unwrap(), d);
    }

    #[test]
    fn delta_chain_load_reconstructs_patched_payload() {
        let s = MemStorage::new();
        let (_, d) = delta_sample(&s);
        let (hit, stages) = load_latest(&s, "m").unwrap().unwrap();
        assert_eq!(hit.step, 44);
        let mut expect0 = vec![1, 9, 9, 1, 1, 1];
        expect0.extend_from_slice(&[2; 4]);
        assert_eq!(stages[0], expect0);
        assert_eq!(stages[1], vec![7, 3, 3, 3, 3, 8]);
        assert_eq!(
            load_manifest_payload_serial(&s, &d).unwrap(),
            stages,
            "serial oracle walks the chain to the same bytes"
        );
    }

    #[test]
    fn corrupt_delta_falls_back_to_the_base_round() {
        let s = MemStorage::new();
        let (_, d) = delta_sample(&s);
        // same length, wrong bytes: only the reconstruction CRC can see it
        s.put(&d.shards[0].key, &[9, 8]).unwrap();
        let (hit, stages) = load_latest(&s, "m").unwrap().unwrap();
        assert_eq!(hit.step, 40, "torn delta degrades to the base, never blocks");
        assert_eq!(stages[1], vec![3u8; 6]);
    }

    #[test]
    fn unchanged_shards_are_still_verified() {
        let s = MemStorage::new();
        let (_, mut d) = delta_sample(&s);
        // claim the unchanged shard reconstructs to different bytes: the
        // re-verify over the base bytes must refuse the chain
        d.shards[1].crc32 ^= 1;
        s.put(&manifest_key("m", 44), &d.encode()).unwrap();
        assert!(load_manifest_payload(&s, &d).is_err());
        assert_eq!(load_latest(&s, "m").unwrap().unwrap().0.step, 40);
    }

    #[test]
    fn chain_walk_rejects_forward_links_missing_bases_and_orphan_deltas() {
        let s = MemStorage::new();
        let (_, mut d) = delta_sample(&s);
        d.base_step = Some(50); // forward link (cycle bait)
        assert!(load_manifest_payload(&s, &d).is_err());
        d.base_step = Some(30); // no manifest ever committed there
        assert!(load_manifest_payload(&s, &d).is_err());
        // extents without a base_step link are malformed, not "full"
        let mut orphan = sample();
        orphan.shards[0].extents = vec![(0, 1)];
        assert!(load_manifest_payload(&s, &orphan).is_err());
        // overlapping extents are refused before any byte is trusted
        let (_, mut bad) = delta_sample(&s);
        bad.shards[2].extents = vec![(0, 3), (2, 2)];
        assert!(load_manifest_payload(&s, &bad).is_err());
    }

    #[test]
    fn orphan_sweep_ignores_manifested_and_inflight_steps() {
        let s = MemStorage::new();
        let man = sample();
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        // orphans from a crashed persist at step 20 (a blob and a part), and
        // an in-flight upload at step 50
        s.put(&shard_key("m", 20, 0, 0), &[0; 4]).unwrap();
        s.put(&part_key("m", 20, 0, 1, 0), &[0; 4]).unwrap();
        s.put(&shard_key("m", 50, 0, 0), &[0; 4]).unwrap();
        let deleted = sweep_orphan_shards(&s, "m", 45);
        assert_eq!(deleted, 2);
        assert!(!s.exists(&shard_key("m", 20, 0, 0)), "orphan blob swept");
        assert!(!s.exists(&part_key("m", 20, 0, 1, 0)), "orphan part swept");
        assert!(s.exists(&shard_key("m", 50, 0, 0)), "in-flight kept");
        assert!(s.exists(&man.shards[0].key), "manifested kept");
    }

    #[test]
    fn atom_codec_roundtrip_matches_dom() {
        let mut man = sample();
        man.atoms = derive_atoms(&man.stage_bytes, &man.shards).unwrap();
        assert_eq!(man.encode(), man.encode_dom(), "atoms byte-identical to DOM");
        assert_eq!(PersistManifest::decode(&man.encode()).unwrap(), man);
        assert_eq!(PersistManifest::decode_dom(&man.encode()).unwrap(), man);
    }

    #[test]
    fn atom_index_derives_for_version0_and_validates_declared() {
        // a version-0 manifest (no atoms on the wire) derives the index
        let man = sample();
        let derived = man.atom_index().unwrap();
        assert_eq!(
            derived,
            vec![
                AtomEntry { stage: 0, start: 0, len: 6, key: man.shards[0].key.clone() },
                AtomEntry { stage: 0, start: 6, len: 4, key: man.shards[1].key.clone() },
                AtomEntry { stage: 1, start: 10, len: 6, key: man.shards[2].key.clone() },
            ]
        );
        // a declared index that matches the tiling is accepted as-is
        let mut with = man.clone();
        with.atoms = derived.clone();
        assert_eq!(with.atom_index().unwrap(), derived);
        // a declared index inconsistent with the shard tiling is refused
        with.atoms[1].len = 3;
        assert!(with.atom_index().is_err());
        // and a manifest whose shards don't tile cannot produce an index
        let mut gap = man;
        gap.shards[1].offset = 7;
        assert!(gap.atom_index().is_err());
    }

    #[test]
    fn legacy_tie_break_is_numeric_not_lexicographic() {
        use crate::checkpoint::storage::step_key;
        let s = MemStorage::new();
        let man = sample(); // model "m", snapshot_step 38
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);

        // zero-pad width overflow: a 13-digit legacy step renders to a key
        // that sorts BEFORE every 12-digit step_key, so the old string
        // compare concluded "manifest newer" — numerically 10^12 > 38 and
        // the legacy checkpoint must win
        let overflow = step_key("m", 1_000_000_000_005);
        assert!(
            step_key("m", 38).as_str() > overflow.as_str(),
            "precondition: the overflowing key sorts backwards"
        );
        assert!(resolve_for_recovery(&s, "m", 2, Some(overflow.as_str())).is_none());

        // a foreign model's legacy key: "z/..." sorts after every "m/..."
        // key, so the old compare deferred to it unconditionally — it names
        // no state of THIS model and the manifest must serve
        let foreign = step_key("z", 1);
        assert!(
            step_key("m", 38).as_str() < foreign.as_str(),
            "precondition: the foreign key sorts as newer"
        );
        assert!(resolve_for_recovery(&s, "m", 2, Some(foreign.as_str())).is_some());
    }

    /// A chain of `hops` empty-extent delta links over the `sample()` base:
    /// no extent blobs exist (unchanged shards fetch nothing), so the chain
    /// is cheap to build at any length and every link still re-verifies the
    /// base bytes against the recorded CRCs.
    fn put_empty_delta_chain(s: &MemStorage, hops: u64) -> PersistManifest {
        let base = sample();
        put_shards(s, &base);
        s.put(&manifest_key("m", 40), &base.encode()).unwrap();
        let mut head = base.clone();
        for h in 1..=hops {
            let mut d = base.clone();
            d.step = 40 + h;
            d.snapshot_step = 40 + h;
            d.base_step = Some(40 + h - 1);
            for sh in &mut d.shards {
                sh.key = shard_key("m", 40 + h, sh.stage, sh.node);
            }
            s.put(&manifest_key("m", 40 + h), &d.encode()).unwrap();
            head = d;
        }
        head
    }

    #[test]
    fn chain_walk_bound_follows_the_configured_budget() {
        // boundary: exactly `delta_chain_max` hops loads under a budget of
        // `delta_chain_max`, and one past it is rejected — the walk bound
        // derives from the knob, not a hard-coded constant
        let delta_chain_max = 8u64;
        let s = MemStorage::new();
        let head = put_empty_delta_chain(&s, delta_chain_max);
        let loaded =
            load_manifest_payload_bounded(&s, &head, delta_chain_max).unwrap();
        assert_eq!(loaded[0][..6], [1u8; 6], "chain at the bound reconstructs");
        // one hop past the budget: reject, don't walk on
        assert!(load_manifest_payload_bounded(&s, &head, delta_chain_max - 1).is_err());
        let s2 = MemStorage::new();
        let over = put_empty_delta_chain(&s2, delta_chain_max + 1);
        let e = load_manifest_payload_bounded(&s2, &over, delta_chain_max)
            .unwrap_err()
            .to_string();
        assert!(e.contains("exceeds"), "over-budget chain fails loudly: {e}");
        // the default budget still carries the historical 64-hop cap
        let s3 = MemStorage::new();
        let legacy = put_empty_delta_chain(&s3, DEFAULT_CHAIN_BUDGET);
        assert!(load_manifest_payload(&s3, &legacy).is_ok());
        let s4 = MemStorage::new();
        let past = put_empty_delta_chain(&s4, DEFAULT_CHAIN_BUDGET + 1);
        assert!(load_manifest_payload(&s4, &past).is_err());
    }

    #[test]
    fn torn_manifest_skip_is_counted() {
        let s = MemStorage::new();
        let man = sample();
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        // a newer manifest torn mid-put by a brownout: truncated JSON
        s.put(&manifest_key("m", 50), b"{\"model\": \"m\"").unwrap();
        let before = manifest_torn_count();
        let (hit, _) = load_latest(&s, "m").unwrap().unwrap();
        assert_eq!(hit.step, 40, "torn newest degrades to the older round");
        assert!(
            manifest_torn_count() >= before + 1,
            "the skip must leave a signal"
        );
    }
}
