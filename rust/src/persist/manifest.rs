//! Durable checkpoint manifests — the atomic-commit unit of the
//! persistence engine.
//!
//! Layout in the [`Storage`] key namespace (one sub-namespace per model):
//!
//! ```text
//! {model}/persist/step-{step:012}/shard-{stage:03}-{node:03}             shard blobs
//! {model}/persist/step-{step:012}/shard-{stage:03}-{node:03}/part-{k:05} multipart part-objects
//! {model}/manifest/step-{step:012}                                       the manifest
//! ```
//!
//! Commit protocol (crash-consistent by construction):
//!
//! 1. the writer workers upload every shard blob of the round — a large
//!    shard lands as `part-{k}` objects with per-part CRCs, so a crashed
//!    upload resumes from the last durable part instead of starting over;
//! 2. only after **all** shards have landed is the manifest written — a
//!    single `put` of a small JSON document (`DirStorage` makes the put
//!    itself atomic via write-then-rename);
//! 3. readers resolve "latest" over *manifest* keys only, so a crash
//!    anywhere before step 2 leaves the previous manifest as latest and the
//!    orphaned shard blobs/parts invisible (the retention GC sweeps them).
//!
//! The manifest records every shard's key, byte range, and CRC32 — plus the
//! per-part keys/CRCs for multipart shards — so a restore can verify the
//! durable copy end to end before trusting it.
//!
//! Loading is a **parallel sharded gather** ([`load_manifest_payload`]):
//! scoped threads fetch + CRC-verify shards concurrently and stitch them
//! directly into the pre-allocated stage buffers (`Storage::get_into`, no
//! intermediate allocation), mirroring the in-memory parallel restore. The
//! pre-parallel serial loop is kept as
//! [`load_manifest_payload_serial`] — the measured baseline for
//! `benches/hotpath.rs` and the byte-identity oracle in the tests.

use std::collections::BTreeSet;

use anyhow::{anyhow, Context, Result};

use crate::checkpoint::Storage;
use crate::util::json::Json;

/// Key of one persisted shard blob.
pub fn shard_key(model: &str, step: u64, stage: usize, node: usize) -> String {
    format!("{model}/persist/step-{step:012}/shard-{stage:03}-{node:03}")
}

/// Key of one durable part-object of a multipart shard upload.
pub fn part_key(model: &str, step: u64, stage: usize, node: usize, part: usize) -> String {
    format!("{model}/persist/step-{step:012}/shard-{stage:03}-{node:03}/part-{part:05}")
}

/// Key of the multipart-progress sidecar of one shard: the `(len, crc)` of
/// every part that has actually landed, maintained by the writer as parts
/// upload, so a resumed attempt can verify durable parts with **O(parts)
/// metadata reads** instead of reading every part's bytes back.
pub fn part_meta_key(model: &str, step: u64, stage: usize, node: usize) -> String {
    format!("{}/meta", shard_key(model, step, stage, node))
}

/// Prefix of every shard blob **and** part-object of `model` (the step
/// digits follow).
pub fn shard_prefix(model: &str) -> String {
    format!("{model}/persist/step-")
}

/// Key of the manifest committed for `step`.
pub fn manifest_key(model: &str, step: u64) -> String {
    format!("{model}/manifest/step-{step:012}")
}

/// Prefix of every manifest of `model` (zero-padded steps sort numerically).
pub fn manifest_prefix(model: &str) -> String {
    format!("{model}/manifest/step-")
}

/// Parse the step number out of a key under `prefix` (manifest keys end in
/// the digits; shard and part keys continue with `/shard-...` after them).
pub fn step_of_key(key: &str, prefix: &str) -> Option<u64> {
    let rest = key.strip_prefix(prefix)?;
    let digits: &str = &rest[..rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len())];
    if digits.is_empty() {
        return None;
    }
    digits.parse().ok()
}

/// One durable part-object of a multipart shard: its key, length, and CRC.
/// The per-part CRC is what makes a crashed upload resumable — a retry can
/// verify a part that already landed and skip re-uploading it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartEntry {
    pub key: String,
    pub len: u64,
    pub crc32: u32,
}

/// One shard's entry in a manifest: where its bytes live and how to verify
/// them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardEntry {
    /// the single-blob key (no blob exists under it when `parts` is
    /// non-empty — the bytes live in the part-objects instead)
    pub key: String,
    pub stage: usize,
    pub node: usize,
    /// byte offset into the stage's FT payload
    pub offset: u64,
    pub len: u64,
    /// CRC of the whole shard payload (also covered part-by-part for
    /// multipart shards)
    pub crc32: u32,
    /// multipart layout; empty = the shard is one blob at `key`
    pub parts: Vec<PartEntry>,
}

impl ShardEntry {
    /// Every storage key that may hold this shard's bytes or bookkeeping.
    /// The single-blob key is always included — deletes are idempotent, and
    /// an earlier crashed attempt at the same step may have left a
    /// whole-blob upload behind even when the committed layout is multipart
    /// (or vice versa) — as is the multipart-progress sidecar, so a retired
    /// version takes its resume metadata with it.
    pub fn storage_keys(&self) -> Vec<String> {
        let mut keys = vec![self.key.clone(), format!("{}/meta", self.key)];
        keys.extend(self.parts.iter().map(|p| p.key.clone()));
        keys
    }
}

/// The multipart-progress sidecar body: part index → `(len, crc32)` of the
/// parts that have durably landed for one shard upload. Written after each
/// part put (a tiny JSON document), read once at the start of a resumed
/// attempt. A part recorded here was put *before* the record — so a
/// matching `(len, crc)` plus `exists()` proves the durable part holds
/// exactly these bytes, with no read-back. Absent or torn sidecars degrade
/// to "nothing reusable" (conservative re-upload), never to corruption.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PartProgress {
    pub parts: std::collections::BTreeMap<usize, (u64, u32)>,
}

impl PartProgress {
    pub fn encode(&self) -> Vec<u8> {
        let parts = Json::Arr(
            self.parts
                .iter()
                .map(|(&k, &(len, crc))| {
                    Json::obj(vec![
                        ("k", Json::from(k)),
                        ("len", Json::num(len as f64)),
                        ("crc32", Json::num(crc as f64)),
                    ])
                })
                .collect(),
        );
        format!("{}\n", Json::obj(vec![("parts", parts)])).into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<PartProgress> {
        let text = std::str::from_utf8(bytes).context("part sidecar is not utf-8")?;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("part sidecar: {e}"))?;
        let mut parts = std::collections::BTreeMap::new();
        for p in j.req_arr("parts")? {
            parts.insert(
                p.req_usize("k")?,
                (p.req_f64("len")? as u64, p.req_f64("crc32")? as u32),
            );
        }
        Ok(PartProgress { parts })
    }

    /// Load the sidecar at `key`; absent or undecodable → empty progress.
    pub fn load(storage: &dyn Storage, key: &str) -> PartProgress {
        storage
            .get(key)
            .ok()
            .and_then(|b| PartProgress::decode(&b).ok())
            .unwrap_or_default()
    }

    /// Is part `k` durably landed with exactly these bytes?
    pub fn matches(&self, k: usize, len: u64, crc: u32) -> bool {
        self.parts.get(&k) == Some(&(len, crc))
    }

    pub fn record(&mut self, k: usize, len: u64, crc: u32) {
        self.parts.insert(k, (len, crc));
    }
}

/// A committed durable checkpoint: the cluster-wide record that every shard
/// of one in-memory snapshot round landed in storage.
#[derive(Debug, Clone, PartialEq)]
pub struct PersistManifest {
    pub model: String,
    /// the step at which this persist was *requested* (names the keys)
    pub step: u64,
    /// the in-memory snapshot version this durable copy was drained from
    pub version: u64,
    /// the step whose state this durable copy actually contains — with the
    /// asynchronous save path the drained round can be older than the
    /// enqueue step, so cross-tier "which is newer" comparisons must use
    /// this, not `step`
    pub snapshot_step: u64,
    /// per-stage payload sizes (restore pre-allocates from these)
    pub stage_bytes: Vec<u64>,
    pub shards: Vec<ShardEntry>,
}

impl PersistManifest {
    pub fn encode(&self) -> Vec<u8> {
        let shards = Json::Arr(
            self.shards
                .iter()
                .map(|s| {
                    let mut fields = vec![
                        ("key", Json::str(s.key.clone())),
                        ("stage", Json::from(s.stage)),
                        ("node", Json::from(s.node)),
                        ("offset", Json::num(s.offset as f64)),
                        ("len", Json::num(s.len as f64)),
                        ("crc32", Json::num(s.crc32 as f64)),
                    ];
                    // single-blob shards keep the PR-3 wire format exactly;
                    // only multipart shards carry the extra field
                    if !s.parts.is_empty() {
                        fields.push((
                            "parts",
                            Json::Arr(
                                s.parts
                                    .iter()
                                    .map(|p| {
                                        Json::obj(vec![
                                            ("key", Json::str(p.key.clone())),
                                            ("len", Json::num(p.len as f64)),
                                            ("crc32", Json::num(p.crc32 as f64)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ));
                    }
                    Json::obj(fields)
                })
                .collect(),
        );
        let j = Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("step", Json::num(self.step as f64)),
            ("version", Json::num(self.version as f64)),
            ("snapshot_step", Json::num(self.snapshot_step as f64)),
            (
                "stage_bytes",
                Json::Arr(self.stage_bytes.iter().map(|&b| Json::num(b as f64)).collect()),
            ),
            ("shards", shards),
        ]);
        format!("{j}\n").into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<PersistManifest> {
        let text = std::str::from_utf8(bytes).context("manifest is not utf-8")?;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let model = j.req_str("model")?.to_string();
        let step = j.req_f64("step")? as u64;
        let version = j.req_f64("version")? as u64;
        let snapshot_step = j.req_f64("snapshot_step")? as u64;
        let stage_bytes = j
            .req_arr("stage_bytes")?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|f| f as u64)
                    .context("invalid stage_bytes entry")
            })
            .collect::<Result<Vec<u64>>>()?;
        let mut shards = Vec::new();
        for s in j.req_arr("shards")? {
            let mut parts = Vec::new();
            if let Some(arr) = s.get("parts").and_then(Json::as_arr) {
                for p in arr {
                    parts.push(PartEntry {
                        key: p.req_str("key")?.to_string(),
                        len: p.req_f64("len")? as u64,
                        crc32: p.req_f64("crc32")? as u32,
                    });
                }
            }
            shards.push(ShardEntry {
                key: s.req_str("key")?.to_string(),
                stage: s.req_usize("stage")?,
                node: s.req_usize("node")?,
                offset: s.req_f64("offset")? as u64,
                len: s.req_f64("len")? as u64,
                crc32: s.req_f64("crc32")? as u32,
                parts,
            });
        }
        Ok(PersistManifest { model, step, version, snapshot_step, stage_bytes, shards })
    }
}

/// Every committed step of `model`, ascending.
pub fn persisted_steps(storage: &dyn Storage, model: &str) -> Vec<u64> {
    let prefix = manifest_prefix(model);
    let mut steps: Vec<u64> = storage
        .list()
        .into_iter()
        .filter_map(|k| step_of_key(&k, &prefix))
        .collect();
    steps.sort_unstable();
    steps.dedup();
    steps
}

/// Fetch one manifest shard directly into `out` (pre-carved to `entry.len`
/// bytes), verifying the per-part CRCs (multipart) or the whole-shard CRC
/// (single blob). The shared leaf of both the serial and the parallel
/// loader, so byte-for-byte semantics cannot diverge between them.
fn fetch_shard_into(storage: &dyn Storage, s: &ShardEntry, out: &mut [u8]) -> Result<()> {
    anyhow::ensure!(
        out.len() as u64 == s.len,
        "shard `{}` buffer is {} bytes, manifest says {}",
        s.key,
        out.len(),
        s.len
    );
    if s.parts.is_empty() {
        storage
            .get_into(&s.key, out)
            .with_context(|| format!("shard `{}` missing or mis-sized", s.key))?;
        anyhow::ensure!(
            crc32fast::hash(out) == s.crc32,
            "shard `{}` CRC mismatch — durable copy corrupt",
            s.key
        );
        return Ok(());
    }
    let covered: u64 = s.parts.iter().map(|p| p.len).sum();
    anyhow::ensure!(
        covered == s.len,
        "shard `{}` parts cover {covered} of {} bytes",
        s.key,
        s.len
    );
    let mut off = 0usize;
    for p in &s.parts {
        let end = off + p.len as usize;
        let slice = &mut out[off..end];
        storage
            .get_into(&p.key, slice)
            .with_context(|| format!("part `{}` missing or mis-sized", p.key))?;
        anyhow::ensure!(
            crc32fast::hash(slice) == p.crc32,
            "part `{}` CRC mismatch — durable copy corrupt",
            p.key
        );
        off = end;
    }
    Ok(())
}

/// Validate that `man`'s shards tile every stage payload exactly (no gap,
/// no overlap, no overrun) and return the shard indices in (stage, offset)
/// order — the order both loaders carve the output buffers in.
fn tiling_order(man: &PersistManifest) -> Result<Vec<usize>> {
    let mut order: Vec<usize> = (0..man.shards.len()).collect();
    order.sort_by_key(|&i| (man.shards[i].stage, man.shards[i].offset));
    let mut cursor: Vec<u64> = vec![0; man.stage_bytes.len()];
    for &i in &order {
        let s = &man.shards[i];
        anyhow::ensure!(
            s.stage < man.stage_bytes.len(),
            "shard `{}` names stage {} out of range",
            s.key,
            s.stage
        );
        anyhow::ensure!(
            s.offset == cursor[s.stage],
            "stage {} is not tiled contiguously at byte {} (shard `{}`)",
            s.stage,
            cursor[s.stage],
            s.key
        );
        cursor[s.stage] = s.offset + s.len;
        anyhow::ensure!(
            cursor[s.stage] <= man.stage_bytes[s.stage],
            "shard `{}` overruns its stage",
            s.key
        );
    }
    for (stage, (&need, &got)) in man.stage_bytes.iter().zip(&cursor).enumerate() {
        anyhow::ensure!(
            got == need,
            "stage {stage} under-covered: {got} of {need} bytes in the manifest"
        );
    }
    Ok(order)
}

/// Gather threads per manifest load. The gather is latency-bound (remote
/// gets), not compute-bound, so the cap is independent of the core count.
const LOAD_WORKERS: usize = 8;

/// Fetch and verify one manifest's full payload — every shard present,
/// length- and CRC-clean, tiling each stage payload exactly — as a
/// **parallel sharded gather**: the stage buffers are pre-allocated and
/// carved into disjoint per-shard slices, then scoped worker threads fetch
/// and CRC-verify shards concurrently, stitching each directly into place
/// (mirroring the parallel in-memory restore; this is the checkpoint-
/// fallback restart path, where the serial NFS-shaped read loop dominated).
pub fn load_manifest_payload(
    storage: &dyn Storage,
    man: &PersistManifest,
) -> Result<Vec<Vec<u8>>> {
    let order = tiling_order(man)?;
    let mut out: Vec<Vec<u8>> =
        man.stage_bytes.iter().map(|&b| vec![0u8; b as usize]).collect();
    // carve every stage buffer into disjoint per-shard &mut slices; the
    // tiling order walks each stage front to back so split_at_mut suffices
    let mut work: Vec<(usize, &mut [u8])> = Vec::with_capacity(order.len());
    {
        let mut rests: Vec<&mut [u8]> = out.iter_mut().map(Vec::as_mut_slice).collect();
        for &i in &order {
            let s = &man.shards[i];
            let rest = std::mem::take(&mut rests[s.stage]);
            let (head, tail) = rest.split_at_mut(s.len as usize);
            work.push((i, head));
            rests[s.stage] = tail;
        }
    }
    let workers = work.len().clamp(1, LOAD_WORKERS);
    let chunk = work.len().div_ceil(workers).max(1);
    let mut results: Vec<Result<()>> = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for batch in work.chunks_mut(chunk) {
            handles.push(scope.spawn(move || -> Result<()> {
                for (i, slice) in batch.iter_mut() {
                    fetch_shard_into(storage, &man.shards[*i], slice)?;
                }
                Ok(())
            }));
        }
        for h in handles {
            results.push(
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("manifest gather thread panicked"))),
            );
        }
    });
    for r in results {
        r?;
    }
    Ok(out)
}

/// The pre-parallel serial loader: one shard (and one part) at a time.
/// Kept as the measured baseline for the `manifest_load_parallel_vs_serial`
/// section of `benches/hotpath.rs` and as the byte-identity oracle the
/// parallel-path tests compare against.
pub fn load_manifest_payload_serial(
    storage: &dyn Storage,
    man: &PersistManifest,
) -> Result<Vec<Vec<u8>>> {
    let order = tiling_order(man)?;
    let mut out: Vec<Vec<u8>> =
        man.stage_bytes.iter().map(|&b| vec![0u8; b as usize]).collect();
    for &i in &order {
        let s = &man.shards[i];
        let (a, b) = (s.offset as usize, (s.offset + s.len) as usize);
        fetch_shard_into(storage, s, &mut out[s.stage][a..b])?;
    }
    Ok(out)
}

/// The newest manifest of `model` that satisfies `accept` and whose every
/// shard loads and verifies. Older manifests are tried in turn, so a
/// corrupt, partially GC-ed, or shape-incompatible newer one degrades,
/// never blocks, recovery.
fn load_latest_matching(
    storage: &dyn Storage,
    model: &str,
    accept: impl Fn(&PersistManifest) -> bool,
) -> Option<(PersistManifest, Vec<Vec<u8>>)> {
    let steps = persisted_steps(storage, model);
    for &step in steps.iter().rev() {
        let Ok(bytes) = storage.get(&manifest_key(model, step)) else {
            continue;
        };
        let Ok(man) = PersistManifest::decode(&bytes) else {
            continue;
        };
        if !accept(&man) {
            continue;
        }
        if let Ok(stages) = load_manifest_payload(storage, &man) {
            return Some((man, stages));
        }
    }
    None
}

/// Resolve the newest **complete** durable checkpoint of `model`. Shard
/// blobs without a manifest (a crash between upload and commit) are
/// invisible here by construction.
pub fn load_latest(
    storage: &dyn Storage,
    model: &str,
) -> Result<Option<(PersistManifest, Vec<Vec<u8>>)>> {
    Ok(load_latest_matching(storage, model, |_| true))
}

/// The trainers' case-3 (protection exceeded) durable-tier resolution: the
/// newest complete manifest holding exactly `stages` stage payloads — a
/// manifest persisted under a different parallelism layout is skipped, so
/// it degrades to older manifests or the legacy tier instead of aborting
/// recovery. Returns `None` when no manifest qualifies or when
/// `legacy_key` names a strictly newer inline checkpoint (the comparison
/// uses the manifest's `snapshot_step` — the state it actually contains —
/// against the zero-padded legacy `step_key`).
pub fn resolve_for_recovery(
    storage: &dyn Storage,
    model: &str,
    stages: usize,
    legacy_key: Option<&str>,
) -> Option<(PersistManifest, Vec<Vec<u8>>)> {
    let hit = load_latest_matching(storage, model, |m| m.stage_bytes.len() == stages)?;
    if let Some(k) = legacy_key {
        if crate::checkpoint::storage::step_key(model, hit.0.snapshot_step).as_str() < k {
            return None;
        }
    }
    Some(hit)
}

/// Delete shard blobs and part-objects whose step has no committed manifest
/// and is older than `before_step` — the debris of crashed or aborted
/// persist jobs. Blobs at or past `before_step` may belong to an in-flight
/// upload and are left alone. Returns the number of blobs deleted.
pub fn sweep_orphan_shards(storage: &dyn Storage, model: &str, before_step: u64) -> usize {
    let manifested: BTreeSet<u64> = persisted_steps(storage, model).into_iter().collect();
    let keys = storage.list();
    sweep_orphans_in(storage, model, &manifested, before_step, &keys)
}

/// The sweep over an already-taken listing snapshot (`keys`), so callers
/// that just listed the store (the per-commit GC) don't pay another full
/// scan. `manifested` is the set of steps that had a committed manifest in
/// that same snapshot.
pub fn sweep_orphans_in(
    storage: &dyn Storage,
    model: &str,
    manifested: &BTreeSet<u64>,
    before_step: u64,
    keys: &[String],
) -> usize {
    let prefix = shard_prefix(model);
    let mut deleted = 0;
    for key in keys {
        if let Some(step) = step_of_key(key, &prefix) {
            if step < before_step
                && !manifested.contains(&step)
                && storage.delete(key).is_ok()
            {
                deleted += 1;
            }
        }
    }
    deleted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::MemStorage;

    fn sample() -> PersistManifest {
        PersistManifest {
            model: "m".into(),
            step: 40,
            version: 7,
            snapshot_step: 38,
            stage_bytes: vec![10, 6],
            shards: vec![
                ShardEntry {
                    key: shard_key("m", 40, 0, 0),
                    stage: 0,
                    node: 0,
                    offset: 0,
                    len: 6,
                    crc32: crc32fast::hash(&[1; 6]),
                    parts: vec![],
                },
                ShardEntry {
                    key: shard_key("m", 40, 0, 1),
                    stage: 0,
                    node: 1,
                    offset: 6,
                    len: 4,
                    crc32: crc32fast::hash(&[2; 4]),
                    parts: vec![],
                },
                ShardEntry {
                    key: shard_key("m", 40, 1, 0),
                    stage: 1,
                    node: 0,
                    offset: 0,
                    len: 6,
                    crc32: crc32fast::hash(&[3; 6]),
                    parts: vec![],
                },
            ],
        }
    }

    fn put_shards(s: &MemStorage, man: &PersistManifest) {
        s.put(&man.shards[0].key, &[1; 6]).unwrap();
        s.put(&man.shards[1].key, &[2; 4]).unwrap();
        s.put(&man.shards[2].key, &[3; 6]).unwrap();
    }

    /// A manifest whose second shard is multipart (two parts), with the
    /// part blobs landed in `s`.
    fn multipart_sample(s: &MemStorage) -> PersistManifest {
        let mut man = sample();
        let body: Vec<u8> = (0..4u8).collect();
        man.shards[1].crc32 = crc32fast::hash(&body);
        man.shards[1].parts = vec![
            PartEntry {
                key: part_key("m", 40, 0, 1, 0),
                len: 3,
                crc32: crc32fast::hash(&body[..3]),
            },
            PartEntry {
                key: part_key("m", 40, 0, 1, 1),
                len: 1,
                crc32: crc32fast::hash(&body[3..]),
            },
        ];
        s.put(&man.shards[0].key, &[1; 6]).unwrap();
        s.put(&man.shards[1].parts[0].key, &body[..3]).unwrap();
        s.put(&man.shards[1].parts[1].key, &body[3..]).unwrap();
        s.put(&man.shards[2].key, &[3; 6]).unwrap();
        man
    }

    #[test]
    fn manifest_roundtrip() {
        let m = sample();
        let back = PersistManifest::decode(&m.encode()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn multipart_manifest_roundtrip_and_load() {
        let s = MemStorage::new();
        let man = multipart_sample(&s);
        let back = PersistManifest::decode(&man.encode()).unwrap();
        assert_eq!(back, man, "parts survive the wire format");
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        let (_, stages) = load_latest(&s, "m").unwrap().unwrap();
        let mut expect0 = vec![1u8; 6];
        expect0.extend(0..4u8);
        assert_eq!(stages[0], expect0, "parts stitched in order");
        assert_eq!(stages[1], vec![3u8; 6]);
        // serial oracle agrees byte for byte
        assert_eq!(load_manifest_payload_serial(&s, &man).unwrap(), stages);
    }

    #[test]
    fn multipart_load_verifies_per_part_crc() {
        let s = MemStorage::new();
        let man = multipart_sample(&s);
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        // corrupt the second part in place (same length, different bytes)
        s.put(&man.shards[1].parts[1].key, &[0xEE]).unwrap();
        assert!(load_manifest_payload(&s, &man).is_err());
        assert!(load_manifest_payload_serial(&s, &man).is_err());
        assert!(load_latest(&s, "m").unwrap().is_none());
    }

    #[test]
    fn storage_keys_cover_blob_parts_and_sidecar() {
        let s = MemStorage::new();
        let man = multipart_sample(&s);
        let keys = man.shards[0].storage_keys();
        assert_eq!(keys, vec![
            man.shards[0].key.clone(),
            format!("{}/meta", man.shards[0].key),
        ]);
        let keys = man.shards[1].storage_keys();
        assert_eq!(keys.len(), 4);
        assert!(keys.contains(&man.shards[1].key));
        assert!(keys.contains(&man.shards[1].parts[0].key));
        assert!(keys.contains(&part_meta_key("m", 40, 0, 1)), "sidecar swept with its version");
    }

    #[test]
    fn part_progress_roundtrip_and_conservative_load() {
        let mut p = PartProgress::default();
        p.record(0, 4096, 0xDEAD_BEEF);
        p.record(3, 128, 7);
        let back = PartProgress::decode(&p.encode()).unwrap();
        assert_eq!(back, p);
        assert!(back.matches(0, 4096, 0xDEAD_BEEF));
        assert!(!back.matches(0, 4096, 1), "crc mismatch rejected");
        assert!(!back.matches(1, 4096, 7), "unrecorded part rejected");
        // absent or torn sidecars degrade to empty, never error
        let s = MemStorage::new();
        assert_eq!(PartProgress::load(&s, "missing"), PartProgress::default());
        s.put("torn", b"{nope").unwrap();
        assert_eq!(PartProgress::load(&s, "torn"), PartProgress::default());
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(PersistManifest::decode(b"{").is_err());
        assert!(PersistManifest::decode(b"{\"model\": \"m\"}").is_err());
        assert!(PersistManifest::decode(&[0xFF, 0xFE]).is_err());
    }

    #[test]
    fn step_parsing_from_keys() {
        assert_eq!(
            step_of_key(&manifest_key("m", 123), &manifest_prefix("m")),
            Some(123)
        );
        assert_eq!(
            step_of_key(&shard_key("m", 55, 2, 3), &shard_prefix("m")),
            Some(55)
        );
        // part-objects parse to the same step as their shard
        assert_eq!(
            step_of_key(&part_key("m", 55, 2, 3, 7), &shard_prefix("m")),
            Some(55)
        );
        // other models / legacy checkpoint keys don't parse
        assert_eq!(step_of_key("other/manifest/step-000000000001", &manifest_prefix("m")), None);
        assert_eq!(step_of_key("m/step-000000000001", &manifest_prefix("m")), None);
    }

    #[test]
    fn load_latest_requires_complete_shards() {
        let s = MemStorage::new();
        let man = sample();
        // manifest committed but one shard missing (GC race / corruption):
        // must be skipped, not returned torn
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        s.delete(&man.shards[1].key).unwrap();
        assert!(load_latest(&s, "m").unwrap().is_none());
        // with every shard back, it loads and stitches
        put_shards(&s, &man);
        let (back, stages) = load_latest(&s, "m").unwrap().unwrap();
        assert_eq!(back.step, 40);
        assert_eq!(stages[0], {
            let mut v = vec![1u8; 6];
            v.extend_from_slice(&[2; 4]);
            v
        });
        assert_eq!(stages[1], vec![3u8; 6]);
    }

    #[test]
    fn load_latest_verifies_crc() {
        let s = MemStorage::new();
        let man = sample();
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        // corrupt one shard in place
        s.put(&man.shards[2].key, &[9; 6]).unwrap();
        assert!(load_latest(&s, "m").unwrap().is_none());
    }

    #[test]
    fn parallel_load_matches_serial_oracle() {
        let s = MemStorage::new();
        let man = sample();
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        assert_eq!(
            load_manifest_payload(&s, &man).unwrap(),
            load_manifest_payload_serial(&s, &man).unwrap()
        );
    }

    #[test]
    fn loaders_reject_non_tiling_manifests() {
        let s = MemStorage::new();
        let mut man = sample();
        put_shards(&s, &man);
        // overlap: shard 1 claims offset 4 instead of 6 (gap at the tail)
        man.shards[1].offset = 4;
        assert!(load_manifest_payload(&s, &man).is_err());
        assert!(load_manifest_payload_serial(&s, &man).is_err());
    }

    #[test]
    fn newest_complete_manifest_wins_over_torn_newer() {
        let s = MemStorage::new();
        let man = sample();
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        // a newer manifest exists but its shards never landed (crash after
        // the commit of an empty round is impossible, but a corrupt blob
        // store can produce this): fall back to step 40
        let mut newer = sample();
        newer.step = 60;
        for sh in &mut newer.shards {
            sh.key = shard_key("m", 60, sh.stage, sh.node);
        }
        s.put(&manifest_key("m", 60), &newer.encode()).unwrap();
        let (back, _) = load_latest(&s, "m").unwrap().unwrap();
        assert_eq!(back.step, 40);
    }

    #[test]
    fn recovery_resolution_filters_shape_and_respects_newer_legacy() {
        use crate::checkpoint::storage::step_key;
        let s = MemStorage::new();
        let man = sample(); // 2 stages, snapshot_step 38
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);

        // shape filter: a 1-stage run must NOT restore the 2-stage manifest
        assert!(resolve_for_recovery(&s, "m", 1, None).is_none());
        let (hit, stages) = resolve_for_recovery(&s, "m", 2, None).unwrap();
        assert_eq!(hit.step, 40);
        assert_eq!(stages.len(), 2);

        // cross-tier tie-break uses the CONTAINED step (38), not the
        // request step (40): a legacy checkpoint at 39 is newer state
        let legacy_newer = step_key("m", 39);
        assert!(resolve_for_recovery(&s, "m", 2, Some(legacy_newer.as_str())).is_none());
        let legacy_older = step_key("m", 37);
        assert!(resolve_for_recovery(&s, "m", 2, Some(legacy_older.as_str())).is_some());
    }

    #[test]
    fn orphan_sweep_ignores_manifested_and_inflight_steps() {
        let s = MemStorage::new();
        let man = sample();
        s.put(&manifest_key("m", 40), &man.encode()).unwrap();
        put_shards(&s, &man);
        // orphans from a crashed persist at step 20 (a blob and a part), and
        // an in-flight upload at step 50
        s.put(&shard_key("m", 20, 0, 0), &[0; 4]).unwrap();
        s.put(&part_key("m", 20, 0, 1, 0), &[0; 4]).unwrap();
        s.put(&shard_key("m", 50, 0, 0), &[0; 4]).unwrap();
        let deleted = sweep_orphan_shards(&s, "m", 45);
        assert_eq!(deleted, 2);
        assert!(!s.exists(&shard_key("m", 20, 0, 0)), "orphan blob swept");
        assert!(!s.exists(&part_key("m", 20, 0, 1, 0)), "orphan part swept");
        assert!(s.exists(&shard_key("m", 50, 0, 0)), "in-flight kept");
        assert!(s.exists(&man.shards[0].key), "manifested kept");
    }
}
