//! The background persistence engine: SMP-driven drain of completed
//! in-memory snapshot rounds to durable storage, **off the training
//! thread** (paper §6.1: "an SMP-driven persist to cloud that never blocks
//! training").
//!
//! Shape of the subsystem:
//!
//! * the trainer's persist cadence point is an [`PersistEngine::enqueue`] —
//!   O(nodes) channel-handle clones, no payload bytes — mirroring the L1
//!   philosophy of the snapshot save path;
//! * one engine thread owns the job queue; for each job it fans out **one
//!   writer worker per node** (scoped threads) that pulls that node's clean
//!   shards straight from its SMP (`GetClean` — readers only ever see
//!   promoted versions, so a torn round is unobservable) and streams them to
//!   storage under a shared bytes/sec [`Throttle`], the L2 counterpart:
//!   persist I/O cannot starve training bandwidth;
//! * commit is all-or-nothing: the cluster-wide manifest is written only
//!   after **every** shard landed (see [`super::manifest`]); any worker
//!   failure — dead SMP, snapshot-version skew across nodes, storage error —
//!   drops the whole job, leaving the previous manifest as `latest` and the
//!   partial blobs for the GC sweep;
//! * after each commit the retention policy runs ([`super::retention`]).
//!
//! [`PersistEngine::flush`] is the only blocking call and exists for
//! shutdown (and tests): it barriers on the queue, not on any in-band step.

use std::collections::BTreeSet;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::checkpoint::Storage;
use crate::config::PersistConfig;
use crate::smp::SmpMsg;
use crate::snapshot::SnapshotPlan;

use super::manifest::{manifest_key, shard_key, PersistManifest, ShardEntry};
use super::retention::{run_gc, RetentionPolicy};

/// Global bytes/sec pacing shared by every writer worker: reserving a
/// transfer slot advances a single cluster-wide clock, so the sum of all
/// concurrent uploads never exceeds the configured budget.
#[derive(Debug)]
pub struct Throttle {
    bytes_per_sec: f64,
    next_free: Mutex<Option<Instant>>,
}

impl Throttle {
    /// `bytes_per_sec == 0` disables pacing entirely.
    pub fn new(bytes_per_sec: u64) -> Throttle {
        Throttle { bytes_per_sec: bytes_per_sec as f64, next_free: Mutex::new(None) }
    }

    /// Reserve a slot for `bytes` and sleep until it has drained at the
    /// configured rate. Returns the seconds slept.
    pub fn consume(&self, bytes: usize) -> f64 {
        if self.bytes_per_sec <= 0.0 || bytes == 0 {
            return 0.0;
        }
        let dur = Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
        let now = Instant::now();
        let until = {
            let mut g = self.next_free.lock().unwrap();
            let start = g.map_or(now, |t: Instant| t.max(now));
            let until = start + dur;
            *g = Some(until);
            until
        };
        let wait = until.saturating_duration_since(now);
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        wait.as_secs_f64()
    }
}

/// Counters the trainers fold into their run metrics and the tests assert.
#[derive(Debug, Clone, Default)]
pub struct PersistStats {
    pub jobs_enqueued: u64,
    pub manifests_committed: u64,
    /// jobs dropped without a manifest (dead SMP, version skew across
    /// nodes, no clean snapshot yet, storage error)
    pub jobs_aborted: u64,
    /// shard payload bytes landed under a committed manifest
    pub persisted_bytes: u64,
    pub gc_manifests_deleted: u64,
    pub gc_blobs_deleted: u64,
    /// cumulative seconds writer workers slept in the throttle
    pub throttle_wait_s: f64,
    pub last_commit_step: Option<u64>,
    pub last_commit_version: Option<u64>,
    /// wall-clock of the most recent committed job (fetch → manifest + GC)
    pub last_job_secs: f64,
    pub last_error: Option<String>,
}

enum EngineMsg {
    Job {
        step: u64,
        sources: Vec<Option<Sender<SmpMsg>>>,
        /// recent snapshot-version → capture-step pairs, so the committed
        /// manifest can record the step its drained round actually
        /// contains (`snapshot_step`)
        version_steps: Vec<(u64, u64)>,
    },
    Flush(Sender<()>),
    Shutdown,
}

/// Handle to the running engine thread. Dropping it drains the queue
/// (queued jobs still commit) and joins the thread.
pub struct PersistEngine {
    tx: Sender<EngineMsg>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<Mutex<PersistStats>>,
}

impl PersistEngine {
    pub fn start(
        model: impl Into<String>,
        storage: Arc<dyn Storage>,
        plan: SnapshotPlan,
        cfg: PersistConfig,
    ) -> PersistEngine {
        let model = model.into();
        let stats = Arc::new(Mutex::new(PersistStats::default()));
        let (tx, rx): (Sender<EngineMsg>, Receiver<EngineMsg>) = channel();
        let thread_stats = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("persist-engine".into())
            .spawn(move || {
                let throttle = Throttle::new(cfg.throttle_bytes_per_sec);
                while let Ok(msg) = rx.recv() {
                    match msg {
                        EngineMsg::Job { step, sources, version_steps } => run_job(
                            &model,
                            storage.as_ref(),
                            &plan,
                            &cfg,
                            &throttle,
                            &thread_stats,
                            step,
                            sources,
                            &version_steps,
                        ),
                        EngineMsg::Flush(ack) => {
                            // queue order means every earlier job is done
                            let _ = ack.send(());
                        }
                        EngineMsg::Shutdown => break,
                    }
                }
            })
            .expect("spawning persistence engine thread");
        PersistEngine { tx, handle: Some(handle), stats }
    }

    /// Hand the engine a persist request and return immediately. The job
    /// drains whatever consistent clean snapshot round the SMPs serve at
    /// fetch time (with the async save path that can be one round behind
    /// the just-enqueued snapshot — still a complete, promoted round).
    /// `sources` are per-node SMP inbox handles (`None` = node offline),
    /// captured at enqueue time so elastic replacements are picked up.
    /// `version_steps` maps recent snapshot versions to their capture
    /// steps (may be empty — the manifest's `snapshot_step` then falls
    /// back to the enqueue step).
    pub fn enqueue(
        &self,
        step: u64,
        sources: Vec<Option<Sender<SmpMsg>>>,
        version_steps: Vec<(u64, u64)>,
    ) -> Result<()> {
        self.stats.lock().unwrap().jobs_enqueued += 1;
        self.tx
            .send(EngineMsg::Job { step, sources, version_steps })
            .map_err(|_| anyhow::anyhow!("persistence engine is gone"))
    }

    /// Block until every job enqueued so far has committed or aborted. The
    /// shutdown barrier — the training loop never calls this mid-run.
    pub fn flush(&self) -> Result<()> {
        let (ack_tx, ack_rx) = channel();
        self.tx
            .send(EngineMsg::Flush(ack_tx))
            .map_err(|_| anyhow::anyhow!("persistence engine is gone"))?;
        ack_rx.recv().context("persistence engine died mid-flush")
    }

    pub fn stats(&self) -> PersistStats {
        self.stats.lock().unwrap().clone()
    }

    /// The two scalars the cadence scheduler needs — no `PersistStats`
    /// clone (and no `last_error` String allocation) on the training
    /// thread's per-step path.
    pub fn commit_meta(&self) -> (u64, f64) {
        let g = self.stats.lock().unwrap();
        (g.manifests_committed, g.last_job_secs)
    }
}

impl Drop for PersistEngine {
    fn drop(&mut self) {
        let _ = self.tx.send(EngineMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One writer worker: pull every clean shard this node owns from its SMP
/// and stream it to storage under the shared throttle. Returns the snapshot
/// version served, the manifest entries, bytes moved, and throttle wait.
fn write_node(
    model: &str,
    storage: &dyn Storage,
    plan: &SnapshotPlan,
    cfg: &PersistConfig,
    throttle: &Throttle,
    step: u64,
    node: usize,
    source: Option<Sender<SmpMsg>>,
) -> Result<(u64, Vec<ShardEntry>, u64, f64)> {
    let source =
        source.with_context(|| format!("node {node} is offline — cannot persist"))?;
    let mut version: Option<u64> = None;
    let mut entries = Vec::new();
    let mut total = 0u64;
    let mut waited = 0f64;
    for shard in plan.shards_for_node(node) {
        // Fig. 6 consistency: GetClean only ever serves promoted rounds, so
        // the durable copy can never observe a torn snapshot
        let (v, bytes) = crate::smp::get_clean_via(&source, shard.stage)
            .map_err(|e| anyhow::anyhow!("node {node}: {e}"))?
            .with_context(|| {
                format!("no clean snapshot for stage {} on node {node} yet", shard.stage)
            })?;
        anyhow::ensure!(
            bytes.len() as u64 == shard.len(),
            "clean shard on node {node} is {} bytes, plan says {}",
            bytes.len(),
            shard.len()
        );
        match version {
            Some(prev) => anyhow::ensure!(
                prev == v,
                "node {node} serves mixed clean versions {prev} / {v}"
            ),
            None => version = Some(v),
        }
        // throttled streaming upload: pace chunk by chunk so persist I/O
        // stays inside its bandwidth budget, then land the blob in one
        // atomic put
        for piece in bytes.chunks(cfg.chunk_bytes.max(1)) {
            waited += throttle.consume(piece.len());
        }
        let key = shard_key(model, step, shard.stage, node);
        let crc = crc32fast::hash(&bytes);
        storage
            .put(&key, &bytes)
            .with_context(|| format!("uploading `{key}`"))?;
        total += bytes.len() as u64;
        entries.push(ShardEntry {
            key,
            stage: shard.stage,
            node,
            offset: shard.range.start,
            len: shard.len(),
            crc32: crc,
        });
    }
    let version =
        version.with_context(|| format!("node {node} holds no planned shards"))?;
    Ok((version, entries, total, waited))
}

#[allow(clippy::too_many_arguments)]
fn run_job(
    model: &str,
    storage: &dyn Storage,
    plan: &SnapshotPlan,
    cfg: &PersistConfig,
    throttle: &Throttle,
    stats: &Mutex<PersistStats>,
    step: u64,
    mut sources: Vec<Option<Sender<SmpMsg>>>,
    version_steps: &[(u64, u64)],
) {
    let t0 = Instant::now();
    let nodes: BTreeSet<usize> = plan.shards.iter().map(|s| s.node).collect();
    let mut results: Vec<Result<(u64, Vec<ShardEntry>, u64, f64)>> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for &node in &nodes {
            let source = sources.get_mut(node).and_then(|s| s.take());
            handles.push(scope.spawn(move || {
                write_node(model, storage, plan, cfg, throttle, step, node, source)
            }));
        }
        for h in handles {
            results.push(
                h.join()
                    .unwrap_or_else(|_| Err(anyhow::anyhow!("writer worker panicked"))),
            );
        }
    });

    // all-or-nothing: any worker failure or cross-node version skew drops
    // the job without a manifest — the previous manifest stays `latest` and
    // the partial blobs wait for the GC sweep
    let mut entries = Vec::new();
    let mut versions: BTreeSet<u64> = BTreeSet::new();
    let mut total_bytes = 0u64;
    let mut wait_s = 0f64;
    let mut error: Option<String> = None;
    for r in results {
        match r {
            Ok((v, es, bytes, wait)) => {
                versions.insert(v);
                total_bytes += bytes;
                wait_s += wait;
                entries.extend(es);
            }
            Err(e) => error = Some(format!("{e:#}")),
        }
    }
    if error.is_none() && versions.len() != 1 {
        error = Some(format!("snapshot version skew across nodes: {versions:?}"));
    }
    if let Some(e) = error {
        let mut g = stats.lock().unwrap();
        g.throttle_wait_s += wait_s;
        g.jobs_aborted += 1;
        g.last_error = Some(e);
        return;
    }

    let version = versions.into_iter().next().expect("checked above");
    entries.sort_by(|a, b| (a.stage, a.offset).cmp(&(b.stage, b.offset)));
    // the step whose state the drained round actually contains: with async
    // snapshots the promoted round can be older than the enqueue step, and
    // recovery's cross-tier tie-break must not overstate it
    let snapshot_step = version_steps
        .iter()
        .rev()
        .find(|(v, _)| *v == version)
        .map(|&(_, s)| s)
        .unwrap_or(step);
    let manifest = PersistManifest {
        model: model.to_string(),
        step,
        version,
        snapshot_step,
        stage_bytes: plan.stage_bytes.clone(),
        shards: entries,
    };
    let committed = storage.put(&manifest_key(model, step), &manifest.encode());
    let gc = if committed.is_ok() {
        let policy = RetentionPolicy { keep_last: cfg.keep_last, keep_every: cfg.keep_every };
        Some(run_gc(storage, model, &policy))
    } else {
        None
    };

    let mut g = stats.lock().unwrap();
    g.throttle_wait_s += wait_s;
    match committed {
        Ok(()) => {
            g.manifests_committed += 1;
            g.persisted_bytes += total_bytes;
            g.last_commit_step = Some(step);
            g.last_commit_version = Some(version);
            g.last_job_secs = t0.elapsed().as_secs_f64();
            match gc {
                Some(Ok(report)) => {
                    g.gc_manifests_deleted += report.manifests_deleted as u64;
                    g.gc_blobs_deleted += report.blobs_deleted as u64;
                }
                Some(Err(e)) => g.last_error = Some(format!("gc: {e:#}")),
                None => {}
            }
        }
        Err(e) => {
            g.jobs_aborted += 1;
            g.last_error = Some(format!("manifest commit: {e:#}"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throttle_disabled_never_sleeps() {
        let t = Throttle::new(0);
        let t0 = Instant::now();
        assert_eq!(t.consume(1 << 30), 0.0);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn throttle_paces_to_the_budget() {
        // 1 MiB/s budget, 128 KiB transferred -> at least ~125 ms of pacing
        let t = Throttle::new(1 << 20);
        let t0 = Instant::now();
        let mut waited = 0.0;
        for _ in 0..4 {
            waited += t.consume(32 * 1024);
        }
        assert!(
            t0.elapsed() >= Duration::from_millis(100),
            "elapsed {:?}",
            t0.elapsed()
        );
        assert!(waited > 0.05, "waited {waited}");
    }
}
