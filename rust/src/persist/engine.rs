//! The background persistence engine: SMP-driven drain of completed
//! in-memory snapshot rounds to durable storage, **off the training
//! thread** (paper §6.1: "an SMP-driven persist to cloud that never blocks
//! training").
//!
//! Shape of the subsystem:
//!
//! * the trainer's persist cadence point is an [`PersistEngine::enqueue`] —
//!   O(nodes) channel-handle clones, no payload bytes — mirroring the L1
//!   philosophy of the snapshot save path;
//! * the engine is a **multi-job pipeline**: a dispatcher thread owns the
//!   queue and keeps up to `pipeline_jobs` jobs in their fetch/upload phase
//!   concurrently, so job N+1's SMP fetches overlap job N's uploads (the
//!   lazy-async overlap DataStates-LLM exploits on the save side). Within a
//!   job, one writer worker per node (scoped threads) pulls that node's
//!   clean shards from its SMP (`GetClean` — readers only ever see promoted
//!   versions, so a torn round is unobservable), prefetching the next shard
//!   while the current one uploads;
//! * pacing is **per-node**: the cluster bytes/sec budget is split into
//!   independent local budgets ([`NodeThrottles`], sum preserved), so one
//!   slow or backlogged node's reservations never stall the other writers'
//!   clocks — persist I/O still cannot starve training bandwidth, but a
//!   straggler can no longer serialize the whole cluster behind it;
//! * large shards upload as **resumable multipart** part-objects with
//!   per-part CRCs, fanned across a bounded in-node worker pool
//!   (`persist.multipart_streams`) that keeps several part RTTs in flight
//!   per writer while the node's throttle lane still enforces its bytes/sec
//!   budget; a crash mid-shard resumes from the last durable part instead
//!   of re-uploading the whole shard (see [`super::manifest`]). CRCs are
//!   fused into the storage write loop (`put_checksummed`) and the
//!   whole-shard CRC comes from GF(2) `combine` — each byte is touched
//!   exactly once on the way out;
//! * with `persist.delta_extent_bytes > 0` the engine keeps the extent
//!   tables of the last committed round ([`BaseRound`]) and ships each
//!   shard as a **sparse delta**: only the extents whose content hash
//!   changed since that round are concatenated into the blob, and the
//!   manifest links back via `base_step` (chain reconstruction lives in
//!   [`super::manifest`]). A full base is forced on the first round, after
//!   `persist.delta_chain_max` chained deltas, when a sibling job's commit
//!   supersedes the cached base mid-flight, and when every shard changed
//!   end to end anyway (the round then collapses back to a base so restore
//!   chains never grow for nothing);
//! * commit is all-or-nothing **and in enqueue order**: a commit turnstile
//!   serializes the manifest writes, so overlapped jobs can never commit
//!   out of order and `latest` advances monotonically — in *content* too: a
//!   job whose drained snapshot round is older than an already-committed
//!   round aborts at its turn instead of publishing stale state under a
//!   newer step; any worker failure —
//!   dead SMP, snapshot-version skew across nodes, storage error — drops
//!   the whole job, leaving the previous manifest as `latest` and the
//!   partial blobs/parts for the GC sweep;
//! * after each commit the retention policy runs ([`super::retention`]),
//!   inside the turnstile so GC passes never race each other.
//!
//! [`PersistEngine::flush`] is the only blocking call and exists for
//! shutdown (and tests): it barriers on the queue, not on any in-band step.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::checkpoint::Storage;
use crate::config::PersistConfig;
use crate::obs;
use crate::smp::SmpMsg;
use crate::snapshot::plan::NodeShard;
use crate::snapshot::{ExtentTable, SnapshotPlan};

use super::manifest::{
    manifest_key, part_key, part_meta_key, shard_key, PartEntry, PartProgress,
    PersistManifest, ShardEntry,
};
use super::retention::{run_gc, RetentionPolicy};

/// Bytes/sec pacing for one writer lane: reserving a transfer slot advances
/// a single clock, so the sum of concurrent reservations on this lane never
/// exceeds its budget.
#[derive(Debug)]
pub struct Throttle {
    bytes_per_sec: f64,
    next_free: Mutex<Option<Instant>>,
}

impl Throttle {
    /// `bytes_per_sec == 0` disables pacing entirely.
    pub fn new(bytes_per_sec: u64) -> Throttle {
        Throttle { bytes_per_sec: bytes_per_sec as f64, next_free: Mutex::new(None) }
    }

    /// Reserve a slot for `bytes` and sleep until it has drained at the
    /// configured rate. Returns the seconds slept.
    pub fn consume(&self, bytes: usize) -> f64 {
        if self.bytes_per_sec <= 0.0 || bytes == 0 {
            return 0.0;
        }
        let dur = Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
        let now = Instant::now();
        let until = {
            let mut g = self.next_free.lock().unwrap();
            let start = g.map_or(now, |t: Instant| t.max(now));
            let until = start + dur;
            *g = Some(until);
            until
        };
        let wait = until.saturating_duration_since(now);
        if !wait.is_zero() {
            obs::instant(obs::cat::PERSIST, "throttle_stall", 0, wait.as_micros() as u64);
            std::thread::sleep(wait);
        }
        wait.as_secs_f64()
    }
}

/// Per-node upload pacing: the cluster bytes/sec budget split into one
/// independent [`Throttle`] lane per node (sum preserved — the integer
/// remainder is spread one byte/sec at a time over the first lanes), so a
/// slow node's backlog only ever delays its own writer. The previous
/// engine paced every worker off one cluster-wide clock, which let a single
/// straggling upload push everyone's reservations out.
#[derive(Debug)]
pub struct NodeThrottles {
    lanes: Vec<Throttle>,
}

impl NodeThrottles {
    /// `total_bytes_per_sec == 0` disables pacing on every lane.
    pub fn new(total_bytes_per_sec: u64, nodes: usize) -> NodeThrottles {
        let n = nodes.max(1);
        let base = total_bytes_per_sec / n as u64;
        let rem = (total_bytes_per_sec % n as u64) as usize;
        NodeThrottles {
            lanes: (0..n)
                .map(|i| {
                    if total_bytes_per_sec == 0 {
                        Throttle::new(0)
                    } else {
                        // floor at 1 B/s: a lane whose split rounds to zero
                        // must stay *paced*, not flip to unlimited (a rate
                        // of 0 means "throttling disabled" to `Throttle`)
                        Throttle::new((base + u64::from(i < rem)).max(1))
                    }
                })
                .collect(),
        }
    }

    /// Reserve `bytes` on `node`'s local budget; returns the seconds slept.
    /// Unknown nodes (beyond the planned lane count) are unpaced rather
    /// than panicking — the write itself will fail on the plan check.
    pub fn consume(&self, node: usize, bytes: usize) -> f64 {
        match self.lanes.get(node) {
            Some(t) => t.consume(bytes),
            None => 0.0,
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// The bytes/sec budget of one lane (tests assert the split sums back
    /// to the cluster budget).
    pub fn rate_of(&self, node: usize) -> f64 {
        self.lanes.get(node).map_or(0.0, |t| t.bytes_per_sec)
    }
}

/// EWMA smoothing for the depth controller's per-job observations.
const DEPTH_EWMA_ALPHA: f64 = 0.4;

/// Live pipeline-depth controller: each committed-or-aborted job reports
/// how long its writers spent *waiting on SMP fetches* vs *uploading to
/// storage*, and EWMAs of the two pick how many jobs may overlap their
/// fetch/upload phase (1..=`persist.pipeline_jobs`).
///
/// The overlap a deeper pipeline buys is exactly "job N+1 fetches while job
/// N's uploads sit in storage RTT", so the classic latency/throughput
/// product applies: the ideal depth is `1 + round(upload / fetch)` — enough
/// jobs in flight that fetch work fills the upload window. Depth starts at
/// the configured maximum (optimistic: the static behaviour) and *shrinks*
/// when uploads turn out too cheap for the extra concurrency to pay, so the
/// adaptive engine is never slower than the static one while it learns.
/// With `adaptive` off the controller pins the static depth — the baseline.
#[derive(Debug)]
pub struct DepthController {
    adaptive: bool,
    max: usize,
    depth: AtomicUsize,
    /// (fetch_s, upload_s) EWMAs; None until the first observation
    ewma: Mutex<Option<(f64, f64)>>,
}

impl DepthController {
    pub fn new(adaptive: bool, max: usize) -> DepthController {
        let max = max.max(1);
        DepthController {
            adaptive,
            max,
            depth: AtomicUsize::new(max),
            ewma: Mutex::new(None),
        }
    }

    /// The number of jobs the dispatcher may currently keep in flight.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// One job's accumulated writer-side timings.
    pub fn observe(&self, fetch_s: f64, upload_s: f64) {
        if !self.adaptive || !(fetch_s.is_finite() && upload_s.is_finite()) {
            return;
        }
        let mut g = self.ewma.lock().unwrap();
        let (f, u) = match *g {
            Some((pf, pu)) => (
                DEPTH_EWMA_ALPHA * fetch_s + (1.0 - DEPTH_EWMA_ALPHA) * pf,
                DEPTH_EWMA_ALPHA * upload_s + (1.0 - DEPTH_EWMA_ALPHA) * pu,
            ),
            None => (fetch_s, upload_s),
        };
        *g = Some((f, u));
        let ideal = if f <= 0.0 {
            // instantaneous fetches: uploads are all there is, overlap away
            self.max
        } else {
            // rounded, not ceiled: an upload a fraction of the fetch time
            // collapses to depth 1, one several times the fetch asks for
            // that many extra jobs in flight
            1 + (u / f).round() as usize
        };
        self.depth.store(ideal.clamp(1, self.max), Ordering::Relaxed);
        drop(g);
    }
}

/// Counters the trainers fold into their run metrics and the tests assert.
#[derive(Debug, Clone, Default)]
pub struct PersistStats {
    pub jobs_enqueued: u64,
    pub manifests_committed: u64,
    /// jobs dropped without a manifest (dead SMP, version skew across
    /// nodes, no clean snapshot yet, storage error)
    pub jobs_aborted: u64,
    /// bytes shipped under committed manifests — the sum of the full and
    /// delta counters below (equal to the payload bytes whenever delta
    /// snapshots are off)
    pub persisted_bytes: u64,
    /// bytes shipped by full base rounds (whole shards)
    pub persisted_full_bytes: u64,
    /// bytes shipped by sparse delta rounds (changed extents only)
    pub persisted_delta_bytes: u64,
    /// multipart part-objects uploaded (committed and aborted jobs alike)
    pub parts_uploaded: u64,
    /// multipart part-objects found durable with a matching CRC and reused
    /// instead of re-uploaded (the crash-resume fast path)
    pub parts_reused: u64,
    pub gc_manifests_deleted: u64,
    pub gc_blobs_deleted: u64,
    /// cumulative seconds writer workers slept in their throttle lanes
    pub throttle_wait_s: f64,
    pub last_commit_step: Option<u64>,
    pub last_commit_version: Option<u64>,
    /// wall-clock of the most recent committed job (fetch → manifest + GC)
    pub last_job_secs: f64,
    pub last_error: Option<String>,
}

enum EngineMsg {
    Job {
        step: u64,
        sources: Vec<Option<Sender<SmpMsg>>>,
        /// recent snapshot-version → capture-step pairs, so the committed
        /// manifest can record the step its drained round actually
        /// contains (`snapshot_step`)
        version_steps: Vec<(u64, u64)>,
    },
    Flush(Sender<()>),
    Shutdown,
}

/// The commit turnstile: jobs run their fetch/upload phase concurrently but
/// take their manifest-commit (or abort) turn strictly in enqueue order, so
/// `latest` can never jump backwards and the per-commit GC never races a
/// sibling job's GC. Both operations are deliberately idempotent/monotonic
/// (`wait_turn` passes once predecessors are done, `advance` never moves
/// backwards), so the panic-recovery path in the job wrapper can re-issue
/// them without knowing where the unwind started.
struct CommitGate {
    done: Mutex<u64>,
    cv: Condvar,
}

impl CommitGate {
    fn new() -> CommitGate {
        CommitGate { done: Mutex::new(0), cv: Condvar::new() }
    }

    /// Block until every job enqueued before `seq` has taken its turn.
    fn wait_turn(&self, seq: u64) {
        let mut g = self.done.lock().unwrap();
        while *g < seq.saturating_sub(1) {
            g = self.cv.wait(g).unwrap();
        }
    }

    fn advance(&self, seq: u64) {
        let mut g = self.done.lock().unwrap();
        if *g < seq {
            *g = seq;
        }
        drop(g);
        self.cv.notify_all();
    }
}

/// The committed round the next delta job diffs against: its step, its
/// chain depth and the extent tables of every shard it landed, keyed by
/// `(stage, node)`. Only ever replaced inside the commit turnstile, so the
/// cache always describes the latest committed manifest.
#[derive(Clone)]
struct BaseRound {
    step: u64,
    /// delta links between this round and its full base (0 = this round IS
    /// a base); a delta on top of it would be `depth + 1` deep
    depth: u64,
    tables: BTreeMap<(usize, usize), ExtentTable>,
}

/// Everything a pipelined job needs, shared once behind an `Arc` instead of
/// cloned per job.
struct EngineShared {
    model: String,
    storage: Arc<dyn Storage>,
    plan: SnapshotPlan,
    cfg: PersistConfig,
    throttles: NodeThrottles,
    stats: Arc<Mutex<PersistStats>>,
    gate: CommitGate,
    depth: Arc<DepthController>,
    /// `None` until the first commit (or always, with delta snapshots off)
    delta: Mutex<Option<BaseRound>>,
}

/// Handle to the running engine thread. Dropping it drains the queue
/// (queued jobs still run their turns) and joins the dispatcher.
pub struct PersistEngine {
    tx: Sender<EngineMsg>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<Mutex<PersistStats>>,
    depth: Arc<DepthController>,
}

impl PersistEngine {
    pub fn start(
        model: impl Into<String>,
        storage: Arc<dyn Storage>,
        plan: SnapshotPlan,
        cfg: PersistConfig,
    ) -> PersistEngine {
        let model = model.into();
        let stats = Arc::new(Mutex::new(PersistStats::default()));
        let depth = Arc::new(DepthController::new(
            cfg.adaptive_depth,
            cfg.pipeline_jobs.max(1),
        ));
        let (tx, rx): (Sender<EngineMsg>, Receiver<EngineMsg>) = channel();
        let thread_stats = Arc::clone(&stats);
        let thread_depth = Arc::clone(&depth);
        let handle = std::thread::Builder::new()
            .name("persist-engine".into())
            .spawn(move || {
                let nodes = plan.nodes();
                let throttles = NodeThrottles::new(cfg.throttle_bytes_per_sec, nodes);
                let shared = Arc::new(EngineShared {
                    model,
                    storage,
                    plan,
                    cfg,
                    throttles,
                    stats: thread_stats,
                    gate: CommitGate::new(),
                    depth: thread_depth,
                    delta: Mutex::new(None),
                });
                let mut inflight: VecDeque<JoinHandle<()>> = VecDeque::new();
                let mut seq = 0u64;
                while let Ok(msg) = rx.recv() {
                    match msg {
                        EngineMsg::Job { step, sources, version_steps } => {
                            seq += 1;
                            // bound the pipeline depth: retire the oldest
                            // job before admitting a new one. Re-read per
                            // admission — the adaptive controller moves it
                            // between jobs.
                            while inflight.len() >= shared.depth.depth() {
                                if let Some(h) = inflight.pop_front() {
                                    let _ = h.join();
                                }
                            }
                            let sh = Arc::clone(&shared);
                            let my_seq = seq;
                            let h = std::thread::Builder::new()
                                .name(format!("persist-job-{step}"))
                                .spawn(move || {
                                    let unwound = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(|| {
                                            run_job(&sh, my_seq, step, sources, &version_steps)
                                        }),
                                    )
                                    .is_err();
                                    if unwound {
                                        // keep the turnstile moving: the gate
                                        // ops are idempotent, so this is safe
                                        // wherever the unwind started — a
                                        // wedged gate would deadlock flush()
                                        // and Drop for every later job
                                        sh.gate.wait_turn(my_seq);
                                        sh.gate.advance(my_seq);
                                        if let Ok(mut g) = sh.stats.lock() {
                                            g.jobs_aborted += 1;
                                            g.last_error = Some(format!(
                                                "persist job for step {step} panicked"
                                            ));
                                        }
                                    }
                                })
                                .expect("spawning persist job thread");
                            inflight.push_back(h);
                        }
                        EngineMsg::Flush(ack) => {
                            // enqueue order means every earlier job was
                            // dispatched; joining them barriers on their
                            // (ordered) commit turns too
                            while let Some(h) = inflight.pop_front() {
                                let _ = h.join();
                            }
                            let _ = ack.send(());
                        }
                        EngineMsg::Shutdown => break,
                    }
                }
                while let Some(h) = inflight.pop_front() {
                    let _ = h.join();
                }
            })
            .expect("spawning persistence engine thread");
        PersistEngine { tx, handle: Some(handle), stats, depth }
    }

    /// The pipeline depth the dispatcher currently admits (static depth
    /// unless `persist.adaptive_depth` is on).
    pub fn pipeline_depth(&self) -> usize {
        self.depth.depth()
    }

    /// Hand the engine a persist request and return immediately. The job
    /// drains whatever consistent clean snapshot round the SMPs serve at
    /// fetch time (with the async save path that can be one round behind
    /// the just-enqueued snapshot — still a complete, promoted round).
    /// `sources` are per-node SMP inbox handles (`None` = node offline),
    /// captured at enqueue time so elastic replacements are picked up.
    /// `version_steps` maps recent snapshot versions to their capture
    /// steps (may be empty — the manifest's `snapshot_step` then falls
    /// back to the enqueue step).
    pub fn enqueue(
        &self,
        step: u64,
        sources: Vec<Option<Sender<SmpMsg>>>,
        version_steps: Vec<(u64, u64)>,
    ) -> Result<()> {
        self.stats.lock().unwrap().jobs_enqueued += 1;
        obs::instant(obs::cat::PERSIST, "enqueue", step, 0);
        self.tx
            .send(EngineMsg::Job { step, sources, version_steps })
            .map_err(|_| anyhow::anyhow!("persistence engine is gone"))
    }

    /// Block until every job enqueued so far has committed or aborted. The
    /// shutdown barrier — the training loop never calls this mid-run.
    pub fn flush(&self) -> Result<()> {
        let (ack_tx, ack_rx) = channel();
        self.tx
            .send(EngineMsg::Flush(ack_tx))
            .map_err(|_| anyhow::anyhow!("persistence engine is gone"))?;
        ack_rx.recv().context("persistence engine died mid-flush")
    }

    pub fn stats(&self) -> PersistStats {
        self.stats.lock().unwrap().clone()
    }

    /// The two scalars the cadence scheduler needs — no `PersistStats`
    /// clone (and no `last_error` String allocation) on the training
    /// thread's per-step path.
    pub fn commit_meta(&self) -> (u64, f64) {
        let g = self.stats.lock().unwrap();
        (g.manifests_committed, g.last_job_secs)
    }
}

impl Drop for PersistEngine {
    fn drop(&mut self) {
        let _ = self.tx.send(EngineMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Upload accounting one writer worker accumulates — kept separate from
/// the fallible outcome so a job that aborts mid-shard still reports the
/// throttle waits and the parts it DID land (a later retry reuses them,
/// and the counters must add up across the crash).
#[derive(Default)]
struct UploadAcc {
    waited: f64,
    parts_uploaded: u64,
    parts_reused: u64,
    /// seconds this worker spent blocked on SMP fetches (GetClean recv)
    fetch_s: f64,
    /// seconds this worker spent in storage puts (throttle sleeps excluded
    /// via `waited` — pacing is policy, not storage RTT)
    upload_s: f64,
    /// the snapshot version the SMP served, recorded even when the upload
    /// later fails — the flight recorder ties a job's abort back to the
    /// round it actually drained
    seen_version: Option<u64>,
}

/// What one writer worker produced on success.
struct NodeOutcome {
    /// the snapshot version the SMP served
    version: u64,
    entries: Vec<ShardEntry>,
    /// bytes shipped as whole shards (base rounds / delta off)
    full_bytes: u64,
    /// bytes shipped as changed-extent delta blobs
    delta_bytes: u64,
    /// freshly hashed extent tables, `(stage, node)`-keyed — empty when
    /// delta snapshots are off
    tables: Vec<((usize, usize), ExtentTable)>,
}

/// What one writer worker produced: the fallible outcome plus the
/// always-present accounting.
struct NodeWrite {
    outcome: Result<NodeOutcome>,
    acc: UploadAcc,
}

/// Bounded-cadence sidecar writer. The previous engine rewrote the whole
/// multipart-progress sidecar after EVERY part put — O(parts²) metadata
/// write volume per shard. The flusher rewrites it only when the records
/// added since the last flush reach the records already flushed (doubling
/// cadence): O(log parts) sidecar puts and O(parts) total sidecar bytes,
/// while a crash loses at most the newer half of the records — a resumed
/// attempt re-uploads those parts, which is conservative, never corrupt.
/// Shared behind a `Mutex` by the parallel part workers; the encoded body
/// is returned to the caller so the RTT-paying sidecar put happens OUTSIDE
/// the lock (an older body overwriting a newer one is equally conservative).
struct SidecarFlusher {
    progress: PartProgress,
    /// records in the sidecar body as of the last flush
    flushed: usize,
    /// records added since the last flush
    unflushed: usize,
}

impl SidecarFlusher {
    fn new(progress: PartProgress) -> SidecarFlusher {
        let flushed = progress.len();
        SidecarFlusher { progress, flushed, unflushed: 0 }
    }

    /// The `(len, crc)` a prior attempt durably recorded for part `k`.
    fn get(&self, k: usize) -> Option<(u64, u32)> {
        self.progress.get(k)
    }

    /// Record a landed part; `Some(body)` when the cadence says flush.
    fn record(&mut self, k: usize, len: u64, crc: u32) -> Option<Vec<u8>> {
        self.progress.record(k, len, crc);
        self.unflushed += 1;
        if self.unflushed >= self.flushed.max(1) {
            self.flushed = self.progress.len();
            self.unflushed = 0;
            Some(self.progress.encode())
        } else {
            None
        }
    }
}

/// Land one `part-{k}` object. Reuse fast path: the sidecar's `(len, crc)`
/// record plus `exists()` plus ONE hash pass over the in-memory piece prove
/// the durable part holds exactly these bytes — no byte read-back. Upload
/// path: paced on the node's throttle lane, then a **fused**
/// `put_checksummed` (the CRC is computed inside the storage write loop,
/// not in a separate pass), then the sidecar record at the flusher's
/// bounded cadence. The sidecar put is best-effort — it is an optimization,
/// and a failed metadata put must not abort the job.
#[allow(clippy::too_many_arguments)]
fn upload_part(
    shared: &EngineShared,
    step: u64,
    stage: usize,
    node: usize,
    k: usize,
    piece: &[u8],
    flusher: &Mutex<SidecarFlusher>,
    meta_key: &str,
    acc: &mut UploadAcc,
) -> Result<PartEntry> {
    let cfg = &shared.cfg;
    let storage = shared.storage.as_ref();
    let pkey = part_key(&shared.model, step, stage, node, k);
    let recorded = flusher.lock().unwrap().get(k);
    if let Some((len, crc)) = recorded {
        // record first (written only AFTER a successful part put), cheap
        // exists() second, the hash pass over the in-memory piece last
        if len == piece.len() as u64
            && storage.exists(&pkey)
            && crc32fast::hash(piece) == crc
        {
            acc.parts_reused += 1;
            return Ok(PartEntry { key: pkey, len, crc32: crc });
        }
    }
    for sub in piece.chunks(cfg.chunk_bytes.max(1)) {
        acc.waited += shared.throttles.consume(node, sub.len());
    }
    let crc = storage
        .put_checksummed(&pkey, piece)
        .with_context(|| format!("uploading part `{pkey}`"))?;
    acc.parts_uploaded += 1;
    // a crash between the part put and the next sidecar flush just
    // re-uploads the unrecorded parts on resume (conservative)
    let body = flusher.lock().unwrap().record(k, piece.len() as u64, crc);
    if let Some(body) = body {
        let _ = storage.put(meta_key, &body);
    }
    Ok(PartEntry { key: pkey, len: piece.len() as u64, crc32: crc })
}

/// Land one blob under `key`: a single paced put below the multipart
/// threshold, else `part-{k}` objects with per-part CRCs, fanned across a
/// bounded in-node worker pool (`persist.multipart_streams`). A part that
/// is already durable with matching bytes (same CRC) is **reused**, not
/// re-uploaded — the crash-resume fast path a retried step hits.
///
/// Byte-touch budget: every byte is hashed inside the storage write loop
/// (`put_checksummed`) — never in a separate whole-blob pass. Returns the
/// whole-blob CRC (folded from the part CRCs with GF(2) `combine`, which
/// equals the CRC of the concatenated bytes exactly) and the part layout
/// (empty for a single blob).
fn upload_blob(
    shared: &EngineShared,
    step: u64,
    stage: usize,
    node: usize,
    key: &str,
    bytes: &[u8],
    acc: &mut UploadAcc,
) -> Result<(u32, Vec<PartEntry>)> {
    let cfg = &shared.cfg;
    let storage = shared.storage.as_ref();
    let part_bytes = cfg.multipart_part_bytes;
    if part_bytes == 0 || bytes.len() <= part_bytes {
        // single blob: pace chunk by chunk on this node's lane, then land
        // the blob in one atomic put (the PR-3 fast path, kept for small
        // shards where part bookkeeping would cost more than it saves);
        // the CRC is computed inside the put's write loop
        for piece in bytes.chunks(cfg.chunk_bytes.max(1)) {
            acc.waited += shared.throttles.consume(node, piece.len());
        }
        let crc = storage
            .put_checksummed(key, bytes)
            .with_context(|| format!("uploading `{key}`"))?;
        return Ok((crc, Vec::new()));
    }
    // O(parts)-metadata resume: ONE sidecar read recovers the (len, crc)
    // record of every part a crashed earlier attempt durably landed — no
    // per-part byte read-back (the pre-sidecar engine re-fetched and
    // re-hashed whole parts to prove them reusable)
    let meta_key = part_meta_key(&shared.model, step, stage, node);
    let flusher = Mutex::new(SidecarFlusher::new(PartProgress::load(storage, &meta_key)));
    let n_parts = bytes.len().div_ceil(part_bytes);
    let streams = cfg.multipart_streams.max(1).min(n_parts);
    let parts: Vec<PartEntry> = if streams <= 1 {
        // serial lane: deterministic part order — the crash-matrix tests
        // pin `multipart_streams: 1` to place fault injections exactly,
        // and the hotpath bench keeps it as the measured baseline
        let mut parts = Vec::with_capacity(n_parts);
        for (k, piece) in bytes.chunks(part_bytes).enumerate() {
            parts.push(upload_part(
                shared, step, stage, node, k, piece, &flusher, &meta_key, acc,
            )?);
        }
        parts
    } else {
        // bounded in-node worker pool: workers claim part indices from a
        // shared atomic, so `streams` part puts keep their storage RTTs in
        // flight concurrently. The node's throttle lane is a mutex-clocked
        // reservation queue, so concurrent workers still share exactly the
        // lane's bytes/sec budget — pacing semantics are unchanged.
        let chunks: Vec<(usize, &[u8])> = bytes.chunks(part_bytes).enumerate().collect();
        let next = AtomicUsize::new(0);
        let failed = AtomicBool::new(false);
        let mut outs: Vec<(UploadAcc, Result<Vec<(usize, PartEntry)>>)> =
            Vec::with_capacity(streams);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(streams);
            for _ in 0..streams {
                let chunks = &chunks;
                let flusher = &flusher;
                let meta_key = meta_key.as_str();
                let next = &next;
                let failed = &failed;
                handles.push(scope.spawn(move || {
                    let mut wacc = UploadAcc::default();
                    let mut got: Vec<(usize, PartEntry)> = Vec::new();
                    loop {
                        if failed.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        let Some(&(k, piece)) = chunks.get(i) else { break };
                        match upload_part(
                            shared, step, stage, node, k, piece, flusher, meta_key,
                            &mut wacc,
                        ) {
                            Ok(e) => got.push((k, e)),
                            Err(e) => {
                                // early-stop the siblings; the accounting
                                // for parts already landed is kept
                                failed.store(true, Ordering::Relaxed);
                                return (wacc, Err(e));
                            }
                        }
                    }
                    (wacc, Ok(got))
                }));
            }
            for h in handles {
                outs.push(h.join().unwrap_or_else(|_| {
                    (UploadAcc::default(), Err(anyhow::anyhow!("part upload worker panicked")))
                }));
            }
        });
        let mut slots: Vec<Option<PartEntry>> = Vec::new();
        slots.resize_with(n_parts, || None);
        let mut first_err: Option<anyhow::Error> = None;
        for (wacc, res) in outs {
            // merge accounting even from failed workers: the waits happened
            // and the parts that landed are reusable by a retry
            acc.waited += wacc.waited;
            acc.parts_uploaded += wacc.parts_uploaded;
            acc.parts_reused += wacc.parts_reused;
            match res {
                Ok(got) => {
                    for (k, e) in got {
                        slots[k] = Some(e);
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        // every index was claimed exactly once and none errored, so the
        // slots are complete — and in k-order by construction
        slots
            .into_iter()
            .map(|p| p.expect("part worker invariant: every index claimed once"))
            .collect()
    };
    // whole-blob CRC from the part CRCs via GF(2) combine — no extra pass
    let mut whole = crc32fast::Hasher::new();
    for p in &parts {
        whole.combine(&crc32fast::Hasher::new_with_initial_len(p.crc32, p.len));
    }
    Ok((whole.finalize(), parts))
}

/// Land one shard whole — the base-round (and delta-off) path.
fn upload_shard(
    shared: &EngineShared,
    step: u64,
    shard: &NodeShard,
    node: usize,
    bytes: &[u8],
    acc: &mut UploadAcc,
) -> Result<ShardEntry> {
    let key = shard_key(&shared.model, step, shard.stage, node);
    let (crc, parts) = upload_blob(shared, step, shard.stage, node, &key, bytes, acc)?;
    Ok(ShardEntry {
        key,
        stage: shard.stage,
        node,
        offset: shard.range.start,
        len: shard.len(),
        crc32: crc,
        extents: Vec::new(),
        parts,
    })
}

/// Land one shard as a sparse delta: the changed shard-local `ranges` are
/// concatenated into one blob (shipped through the same single/multipart
/// machinery, under the same shard key) and recorded as `extents`; the
/// entry's `crc32` is the CRC of the FULL reconstructed shard (the extent
/// table's GF(2) fold — no second hash pass), which is what chain
/// reconstruction verifies at restore. A shard with no changed extents
/// uploads nothing at all: the manifest entry alone says "keep the base
/// round's bytes".
#[allow(clippy::too_many_arguments)]
fn upload_delta_shard(
    shared: &EngineShared,
    step: u64,
    shard: &NodeShard,
    node: usize,
    bytes: &[u8],
    ranges: &[Range<u64>],
    whole_crc: u32,
    acc: &mut UploadAcc,
) -> Result<ShardEntry> {
    let key = shard_key(&shared.model, step, shard.stage, node);
    let mut entry = ShardEntry {
        key: key.clone(),
        stage: shard.stage,
        node,
        offset: shard.range.start,
        len: shard.len(),
        crc32: whole_crc,
        extents: ranges.iter().map(|r| (r.start, r.end - r.start)).collect(),
        parts: Vec::new(),
    };
    if ranges.is_empty() {
        return Ok(entry);
    }
    // a full-coverage delta (100% churn; the all-full collapse rewrites the
    // manifest entry as a base) uploads the shard bytes directly — the
    // concatenation copy would double the round's memory traffic for
    // nothing, and the 100%-churn path must track the full-capture path
    let built: Vec<u8>;
    let blob: &[u8] = if ranges.len() == 1 && ranges[0] == (0..shard.len()) {
        bytes
    } else {
        let delta_len: usize = ranges.iter().map(|r| (r.end - r.start) as usize).sum();
        let mut b = Vec::with_capacity(delta_len);
        for r in ranges {
            b.extend_from_slice(&bytes[r.start as usize..r.end as usize]);
        }
        built = b;
        &built
    };
    // the blob-level CRC is dropped on the single-blob path (the manifest
    // records the whole-shard CRC instead and restore verifies THAT); the
    // multipart path still records per-part CRCs for resumability
    let (_, parts) = upload_blob(shared, step, shard.stage, node, &key, blob, acc)?;
    entry.parts = parts;
    Ok(entry)
}

/// One writer worker: pull every clean shard this node owns from its SMP
/// and land it under the node's throttle lane. The next shard's fetch is
/// issued **before** the current one uploads, so the SMP's serialize+ship
/// overlaps this worker's storage I/O.
fn write_node(
    shared: &EngineShared,
    step: u64,
    node: usize,
    source: Option<Sender<SmpMsg>>,
    base: Option<&BTreeMap<(usize, usize), ExtentTable>>,
) -> NodeWrite {
    let mut acc = UploadAcc::default();
    let outcome = write_node_inner(shared, step, node, source, base, &mut acc);
    NodeWrite { outcome, acc }
}

fn write_node_inner(
    shared: &EngineShared,
    step: u64,
    node: usize,
    source: Option<Sender<SmpMsg>>,
    base: Option<&BTreeMap<(usize, usize), ExtentTable>>,
    acc: &mut UploadAcc,
) -> Result<NodeOutcome> {
    let source =
        source.with_context(|| format!("node {node} is offline — cannot persist"))?;
    let shards: Vec<&NodeShard> = shared.plan.shards_for_node(node).collect();
    let mut entries: Vec<ShardEntry> = Vec::with_capacity(shards.len());
    let mut full_bytes = 0u64;
    let mut delta_bytes = 0u64;
    let mut tables: Vec<((usize, usize), ExtentTable)> = Vec::new();
    let mut version: Option<u64> = None;
    let mut pending = match shards.first() {
        Some(sh) => Some(
            crate::smp::request_clean_via(&source, sh.stage)
                .map_err(|e| anyhow::anyhow!("node {node}: {e}"))?,
        ),
        None => None,
    };
    for (i, &shard) in shards.iter().enumerate() {
        let rx = pending.take().expect("prefetch invariant: one request per shard");
        // prefetch: issue the next shard's GetClean before draining this
        // reply, so the SMP works while we upload
        if let Some(next) = shards.get(i + 1) {
            pending = Some(
                crate::smp::request_clean_via(&source, next.stage)
                    .map_err(|e| anyhow::anyhow!("node {node}: {e}"))?,
            );
        }
        // Fig. 6 consistency: GetClean only ever serves promoted rounds, so
        // the durable copy can never observe a torn snapshot. The blocked
        // time feeds the adaptive depth controller's fetch-side EWMA.
        let t_fetch = Instant::now();
        let (v, bytes) = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("node {node}: SMP died mid-fetch"))?
            .with_context(|| {
                format!("no clean snapshot for stage {} on node {node} yet", shard.stage)
            })?;
        acc.fetch_s += t_fetch.elapsed().as_secs_f64();
        acc.seen_version = Some(v);
        obs::instant(obs::cat::PERSIST, "fetch", v, node as u64);
        anyhow::ensure!(
            bytes.len() as u64 == shard.len(),
            "clean shard on node {node} is {} bytes, plan says {}",
            bytes.len(),
            shard.len()
        );
        match version {
            Some(prev) => anyhow::ensure!(
                prev == v,
                "node {node} serves mixed clean versions {prev} / {v}"
            ),
            None => version = Some(v),
        }
        // one content-hash pass over the fetched bytes whenever delta
        // snapshots are on — even on base rounds, whose tables seed the
        // next round's diff
        let grain = shared.cfg.delta_extent_bytes;
        let table = (grain > 0).then(|| ExtentTable::build(&bytes, grain));
        let waited_before = acc.waited;
        let t_upload = Instant::now();
        let _upload_sp = obs::span_arg(obs::cat::PERSIST, "upload", v, node as u64);
        let entry = match (&table, base) {
            (Some(t), Some(base)) => {
                // delta round: every shard ships as an extent list. A shard
                // whose table is incomparable with the base's (elastic
                // resize, grain change) degrades to one full-coverage
                // extent — still a valid delta entry.
                let ranges = match base.get(&(shard.stage, node)).and_then(|b| t.diff(b)) {
                    Some(r) => r,
                    None if shard.len() == 0 => Vec::new(),
                    None => vec![0..shard.len()],
                };
                delta_bytes += ranges.iter().map(|r| r.end - r.start).sum::<u64>();
                upload_delta_shard(
                    shared, step, shard, node, &bytes, &ranges, t.whole_crc32(), acc,
                )?
            }
            _ => {
                full_bytes += bytes.len() as u64;
                upload_shard(shared, step, shard, node, &bytes, acc)?
            }
        };
        // storage time net of this shard's throttle sleeps: pacing is
        // policy, not RTT, and counting it would teach the controller to
        // out-deepen its own bandwidth budget
        acc.upload_s += (t_upload.elapsed().as_secs_f64() - (acc.waited - waited_before))
            .max(0.0);
        if let Some(t) = table {
            tables.push(((shard.stage, node), t));
        }
        entries.push(entry);
    }
    let version =
        version.with_context(|| format!("node {node} holds no planned shards"))?;
    Ok(NodeOutcome { version, entries, full_bytes, delta_bytes, tables })
}

fn run_job(
    shared: &EngineShared,
    seq: u64,
    step: u64,
    mut sources: Vec<Option<Sender<SmpMsg>>>,
    version_steps: &[(u64, u64)],
) {
    let t0 = Instant::now();
    let _job_sp = obs::span_arg(obs::cat::PERSIST, "job", step, seq);
    // the diff base, snapshotted ONCE per job so every writer diffs against
    // the same committed round; `None` ⇒ this job lands a full base (delta
    // off, nothing committed yet, or the chain hit its depth cap)
    let base: Option<BaseRound> = if shared.cfg.delta_extent_bytes > 0 {
        shared
            .delta
            .lock()
            .unwrap()
            .clone()
            .filter(|b| b.depth < shared.cfg.delta_chain_max)
    } else {
        None
    };
    let base_tables = base.as_ref().map(|b| &b.tables);
    // -- phase A: fetch + upload, concurrent with sibling jobs -------------
    let nodes: BTreeSet<usize> = shared.plan.shards.iter().map(|s| s.node).collect();
    let mut results: Vec<NodeWrite> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for &node in &nodes {
            let source = sources.get_mut(node).and_then(|s| s.take());
            handles.push(
                scope.spawn(move || write_node(shared, step, node, source, base_tables)),
            );
        }
        for h in handles {
            results.push(h.join().unwrap_or_else(|_| NodeWrite {
                outcome: Err(anyhow::anyhow!("writer worker panicked")),
                acc: UploadAcc::default(),
            }));
        }
    });

    // all-or-nothing: any worker failure or cross-node version skew drops
    // the job without a manifest — the previous manifest stays `latest` and
    // the partial blobs/parts wait for the GC sweep (or for a retried step
    // to reuse the durable parts). Accounting (waits, parts landed) is kept
    // from failed workers too: the bytes really moved.
    let mut entries = Vec::new();
    let mut versions: BTreeSet<u64> = BTreeSet::new();
    let mut full_bytes = 0u64;
    let mut delta_bytes = 0u64;
    let mut tables: BTreeMap<(usize, usize), ExtentTable> = BTreeMap::new();
    let mut wait_s = 0f64;
    let mut parts_uploaded = 0u64;
    let mut parts_reused = 0u64;
    let mut fetch_s = 0f64;
    let mut upload_s = 0f64;
    let mut seen_version: Option<u64> = None;
    let mut error: Option<String> = None;
    for w in results {
        wait_s += w.acc.waited;
        parts_uploaded += w.acc.parts_uploaded;
        parts_reused += w.acc.parts_reused;
        fetch_s += w.acc.fetch_s;
        upload_s += w.acc.upload_s;
        seen_version = seen_version.or(w.acc.seen_version);
        match w.outcome {
            Ok(o) => {
                versions.insert(o.version);
                full_bytes += o.full_bytes;
                delta_bytes += o.delta_bytes;
                tables.extend(o.tables);
                entries.extend(o.entries);
            }
            Err(e) => error = Some(format!("{e:#}")),
        }
    }
    if error.is_none() && versions.len() != 1 {
        error = Some(format!("snapshot version skew across nodes: {versions:?}"));
    }
    // feed the adaptive depth controller even from failing jobs: the bytes
    // and the RTTs were real, and a storage brown-out is exactly when the
    // upload EWMA should be learning
    if fetch_s > 0.0 || upload_s > 0.0 {
        shared.depth.observe(fetch_s, upload_s);
    }

    // -- phase B: the ordered commit turn ----------------------------------
    // time spent queued at the turnstile is pipeline scheduling, not save
    // cost: it must not inflate `last_job_secs`, which the cadence
    // scheduler treats as the per-job durable-save cost (t_persist)
    let t_gate = Instant::now();
    {
        let _gate_sp = obs::span_arg(obs::cat::PERSIST, "gate_wait", step, seq);
        shared.gate.wait_turn(seq);
    }
    let gate_wait = t_gate.elapsed();
    // cross-job monotonicity: overlapped jobs fetch in no particular order,
    // so a descheduled writer can hand an EARLIER step a NEWER promoted
    // round than the round a later step drained. Committing the later
    // step's older round would make `latest` resolve staler state than
    // what is already durable (and retention could then GC the newer
    // round's manifest). Checked inside the turn, where the predecessor's
    // `last_commit_version` is final.
    if error.is_none() {
        let v = versions.iter().next().copied().expect("exactly one version");
        let prev = shared.stats.lock().unwrap().last_commit_version;
        if let Some(p) = prev {
            if v < p {
                error = Some(format!(
                    "snapshot round regressed: job for step {step} drained round {v} \
                     but round {p} is already durable — dropping the job"
                ));
            }
        }
    }
    // a delta manifest links to the round it diffed against, and that base
    // must be the *immediately preceding* commit: if a sibling job committed
    // in between, its GC pass could not see this job's pending reference and
    // may already have made the base eligible for deletion. Dropping the job
    // here keeps every restore chain anchored; the next job simply diffs
    // against the sibling's (newer) cached tables.
    if error.is_none() {
        if let Some(bs) = base.as_ref().map(|b| b.step) {
            let last = shared.stats.lock().unwrap().last_commit_step;
            if last != Some(bs) {
                error = Some(format!(
                    "delta base step {bs} was superseded by a sibling commit \
                     (latest is {last:?}) — dropping the job"
                ));
            }
        }
    }
    if let Some(e) = error {
        obs::instant(obs::cat::PERSIST, "abort", seen_version.unwrap_or(0), step);
        let mut g = shared.stats.lock().unwrap();
        g.throttle_wait_s += wait_s;
        g.parts_uploaded += parts_uploaded;
        g.parts_reused += parts_reused;
        g.jobs_aborted += 1;
        g.last_error = Some(e);
        drop(g);
        shared.gate.advance(seq);
        return;
    }

    let version = versions.into_iter().next().expect("checked above");
    entries.sort_by(|a, b| (a.stage, a.offset).cmp(&(b.stage, b.offset)));
    // degenerate delta: every shard changed end to end, so the "delta"
    // carries exactly the bytes a base would — commit it AS a base (extents
    // stripped; the blobs and CRCs are already in base form) and keep the
    // restore chain from growing for nothing. Zero-length shards never
    // qualify (their delta entry skipped the blob upload a base needs).
    let mut base_step = base.as_ref().map(|b| b.step);
    if base_step.is_some()
        && !entries.is_empty()
        && entries.iter().all(|e| e.extents == [(0, e.len)])
    {
        for e in &mut entries {
            e.extents.clear();
        }
        base_step = None;
        full_bytes += delta_bytes;
        delta_bytes = 0;
    }
    // the step whose state the drained round actually contains: with async
    // snapshots the promoted round can be older than the enqueue step, and
    // recovery's cross-tier tie-break must not overstate it
    let snapshot_step = version_steps
        .iter()
        .rev()
        .find(|(v, _)| *v == version)
        .map(|&(_, s)| s)
        .unwrap_or(step);
    // base commits carry the parallelism-neutral atom index (reshape's
    // range-fetch map); deltas inherit their base's through the chain walk
    let atoms = if base_step.is_none() {
        crate::persist::manifest::derive_atoms(&shared.plan.stage_bytes, &entries)
            .unwrap_or_default()
    } else {
        vec![]
    };
    let manifest = PersistManifest {
        model: shared.model.clone(),
        step,
        version,
        snapshot_step,
        stage_bytes: shared.plan.stage_bytes.clone(),
        shards: entries,
        base_step,
        atoms,
    };
    let storage = shared.storage.as_ref();
    let committed = storage.put(&manifest_key(&shared.model, step), &manifest.encode());
    let gc = if committed.is_ok() {
        let policy = RetentionPolicy {
            keep_last: shared.cfg.keep_last,
            keep_every: shared.cfg.keep_every,
        };
        // `Some(step)`: sweep crashed-attempt part debris under the step we
        // just committed — the only step this engine can have resumed
        Some(run_gc(storage, &shared.model, &policy, Some(step)))
    } else {
        None
    };
    // the committed round becomes the diff base for the next job; replaced
    // inside the turn so siblings always observe a fully committed cache
    if committed.is_ok() && shared.cfg.delta_extent_bytes > 0 {
        let depth = match base_step {
            Some(_) => base.as_ref().map_or(0, |b| b.depth) + 1,
            None => 0,
        };
        *shared.delta.lock().unwrap() = Some(BaseRound { step, depth, tables });
    }

    let mut g = shared.stats.lock().unwrap();
    g.throttle_wait_s += wait_s;
    g.parts_uploaded += parts_uploaded;
    g.parts_reused += parts_reused;
    match committed {
        Ok(()) => {
            obs::instant(obs::cat::PERSIST, "commit", version, step);
            g.manifests_committed += 1;
            g.persisted_bytes += full_bytes + delta_bytes;
            g.persisted_full_bytes += full_bytes;
            g.persisted_delta_bytes += delta_bytes;
            g.last_commit_step = Some(step);
            g.last_commit_version = Some(version);
            g.last_job_secs =
                t0.elapsed().saturating_sub(gate_wait).as_secs_f64();
            match gc {
                Some(Ok(report)) => {
                    let swept = (report.manifests_deleted + report.blobs_deleted) as u64;
                    obs::instant(obs::cat::PERSIST, "gc_pass", version, swept);
                    g.gc_manifests_deleted += report.manifests_deleted as u64;
                    g.gc_blobs_deleted += report.blobs_deleted as u64;
                }
                Some(Err(e)) => g.last_error = Some(format!("gc: {e:#}")),
                None => {}
            }
        }
        Err(e) => {
            obs::instant(obs::cat::PERSIST, "abort", version, step);
            g.jobs_aborted += 1;
            g.last_error = Some(format!("manifest commit: {e:#}"));
        }
    }
    drop(g);
    shared.gate.advance(seq);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throttle_disabled_never_sleeps() {
        let t = Throttle::new(0);
        let t0 = Instant::now();
        assert_eq!(t.consume(1 << 30), 0.0);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn throttle_paces_to_the_budget() {
        // 1 MiB/s budget, 128 KiB transferred -> at least ~125 ms of pacing
        let t = Throttle::new(1 << 20);
        let t0 = Instant::now();
        let mut waited = 0.0;
        for _ in 0..4 {
            waited += t.consume(32 * 1024);
        }
        assert!(
            t0.elapsed() >= Duration::from_millis(100),
            "elapsed {:?}",
            t0.elapsed()
        );
        assert!(waited > 0.05, "waited {waited}");
    }

    #[test]
    fn node_throttles_preserve_the_cluster_budget() {
        // odd total: the remainder spreads over the first lanes
        let t = NodeThrottles::new(10, 3);
        assert_eq!(t.lanes(), 3);
        let sum: f64 = (0..3).map(|n| t.rate_of(n)).sum();
        assert!((sum - 10.0).abs() < 1e-9, "sum {sum}");
        // even split
        let t = NodeThrottles::new(6 << 20, 6);
        for n in 0..6 {
            assert!((t.rate_of(n) - (1 << 20) as f64).abs() < 1.0);
        }
        // disabled budget disables every lane
        let t = NodeThrottles::new(0, 4);
        assert_eq!(t.consume(2, 1 << 30), 0.0);
    }

    #[test]
    fn node_throttles_unknown_lane_is_unpaced() {
        let t = NodeThrottles::new(1 << 20, 2);
        assert_eq!(t.consume(99, 1 << 30), 0.0);
    }

    #[test]
    fn sidecar_flusher_doubling_cadence() {
        // fresh shard: flushes after parts 1, 2, 4, 8, ... so a 16-part
        // upload pays O(log parts) sidecar puts, not 16 (the old engine
        // rewrote the sidecar after every part — O(parts²) bytes)
        let mut f = SidecarFlusher::new(PartProgress::default());
        let mut flushes = 0;
        for k in 0..16usize {
            if f.record(k, 4096, k as u32).is_some() {
                flushes += 1;
            }
        }
        assert_eq!(flushes, 5, "16 fresh parts flush at 1, 2, 4, 8, 16");
        // every record is retained regardless of cadence
        assert_eq!(f.progress.len(), 16);
        // resumed attempt starting from 8 durable records: no flush until
        // 8 MORE records land
        let mut resumed = PartProgress::default();
        for k in 0..8usize {
            resumed.record(k, 1, 0);
        }
        let mut f = SidecarFlusher::new(resumed);
        let mut flushes = 0;
        for k in 8..16usize {
            if f.record(k, 1, 0).is_some() {
                flushes += 1;
            }
        }
        assert_eq!(flushes, 1, "one flush when the unflushed half catches up");
        assert_eq!(f.get(3), Some((1, 0)));
        assert_eq!(f.get(99), None);
    }

    #[test]
    fn depth_controller_pins_static_depth_when_disabled() {
        let c = DepthController::new(false, 3);
        assert_eq!(c.depth(), 3);
        c.observe(10.0, 0.001); // would shrink to 1 if adaptive
        assert_eq!(c.depth(), 3, "baseline behaviour must not move");
        // the configured depth floors at 1
        assert_eq!(DepthController::new(false, 0).depth(), 1);
    }

    #[test]
    fn depth_controller_adapts_in_both_directions() {
        let c = DepthController::new(true, 4);
        // optimistic start: the static maximum
        assert_eq!(c.depth(), 4);
        // uploads dwarfed by fetches: no overlap to win -> shrink to the
        // sequential engine as the EWMA settles
        for _ in 0..8 {
            c.observe(1.0, 0.001);
        }
        assert_eq!(c.depth(), 1, "cheap uploads need no deep pipeline");
        // storage RTT dominates: grow back toward the max (clamped)
        for _ in 0..8 {
            c.observe(0.01, 5.0);
        }
        assert_eq!(c.depth(), 4, "RTT-bound uploads refill the pipeline");
        // instantaneous fetches: the ratio degenerates -> max, not a panic
        let c = DepthController::new(true, 3);
        c.observe(0.0, 1.0);
        assert_eq!(c.depth(), 3);
        // non-finite observations are dropped
        c.observe(f64::NAN, 1.0);
        assert_eq!(c.depth(), 3);
    }
}
