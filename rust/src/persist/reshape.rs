//! Reshape-on-restore: the redistribution pass that regathers any
//! committed manifest into a **different** dp/tp/pp stage shape (the
//! *Universal Checkpointing* atom model — PAPERS.md, arxiv 2406.18820).
//!
//! The manifest's atom index ([`PersistManifest::atom_index`]) describes
//! the checkpoint as parallelism-neutral byte ranges of the **global
//! payload stream** (stage payloads concatenated in stage order). Given a
//! target shape, [`ReshapePlan::plan`] turns that index into the minimal
//! set of per-shard byte-range copies: which bytes of which shard blob
//! land at which offset of which *target* stage buffer. Execution
//! ([`reshape_restore`]) fetches each needed shard exactly once through
//! the fused-CRC leaf (`fetch_shard_into` — single-touch verify, multipart
//! combine included) and memcpys the planned ranges into place, so a
//! reshaped restore never fetches more bytes than the dense restore at the
//! source shape would.
//!
//! What "neutral" means depends on the payload layout, named by
//! [`StageCodec`]:
//!
//! * [`StageCodec::Opaque`] — the stage payloads are one flat byte stream
//!   with no per-stage framing (the soak/witness planes, raw tensors). Any
//!   target tiling of the same total is valid.
//! * [`StageCodec::StageState`] — the trainers' `StageState` layout: each
//!   stage payload is a 40-byte header (step + RNG lanes) followed by
//!   `params ‖ adam_m ‖ adam_v`, each `n × 4` bytes. Headers are **not**
//!   parallelism-neutral (they repeat per stage), so the pass re-tiles the
//!   three element streams independently — the params stream of the target
//!   split is carved out of the concatenated params stream of the source
//!   split, and likewise for the two Adam moments — and every target stage
//!   receives a copy of source stage 0's header (the step is
//!   cluster-uniform; the per-stage RNG lanes are re-anchored by the
//!   reshape, which is the documented semantic of an elastic restart).
//!
//! **Delta chains reshape over the *reshaped base* rule:** a delta
//! manifest's extents are source-shape-local, so the chain is first
//! reconstructed at the source shape through the existing bounded chain
//! walk (every CRC verified exactly as a dense restore would) and the
//! *result* is re-tiled in memory — no extra storage fetches beyond what
//! the dense chain load already pays.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::checkpoint::Storage;

use super::manifest::{
    self, fetch_shard_into, load_manifest_payload_bounded, manifest_key, persisted_steps,
    PersistManifest,
};

/// How a stage payload decomposes into parallelism-neutral byte streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageCodec {
    /// no per-stage framing: the concatenated payloads are one neutral
    /// stream, any same-total target tiling is valid
    Opaque,
    /// the trainers' `StageState` layout: `40-byte header ‖ params ‖
    /// adam_m ‖ adam_v` per stage — three neutral element streams plus a
    /// non-neutral header
    StageState,
}

/// Bytes of the `StageState` per-stage header: step (u64) + 4 RNG lanes.
pub const STAGE_STATE_HEADER_BYTES: u64 = 40;

/// Can a checkpoint at `src` stage sizes be reshaped into `dst`?
///
/// * `Opaque`: equal byte totals.
/// * `StageState`: every stage on both sides carries a whole number of
///   12-byte parameter records after its header, and the record totals
///   match (same model, different split).
pub fn reshape_compatible(codec: StageCodec, src: &[u64], dst: &[u64]) -> bool {
    if src.is_empty() || dst.is_empty() {
        return false;
    }
    match codec {
        StageCodec::Opaque => src.iter().sum::<u64>() == dst.iter().sum::<u64>(),
        StageCodec::StageState => {
            let body = |sb: &[u64]| -> Option<u64> {
                let mut total = 0u64;
                for &b in sb {
                    if b < STAGE_STATE_HEADER_BYTES
                        || (b - STAGE_STATE_HEADER_BYTES) % 12 != 0
                    {
                        return None;
                    }
                    total += b - STAGE_STATE_HEADER_BYTES;
                }
                Some(total)
            };
            matches!((body(src), body(dst)), (Some(a), Some(b)) if a == b)
        }
    }
}

/// One stage-to-stage copy in payload space: `len` bytes from
/// `(src_stage, src_off)` of the source split to `(dst_stage, dst_off)` of
/// the target split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct CopyOp {
    src_stage: usize,
    src_off: u64,
    dst_stage: usize,
    dst_off: u64,
    len: u64,
}

/// The per-stream segment lists of a shape under a codec: each stream is a
/// run of `(stage, stage-local offset, len)` pieces whose concatenation is
/// the neutral stream. Headers are not part of any stream.
fn streams(codec: StageCodec, stage_bytes: &[u64]) -> Result<Vec<Vec<(usize, u64, u64)>>> {
    match codec {
        StageCodec::Opaque => Ok(vec![stage_bytes
            .iter()
            .enumerate()
            .map(|(s, &b)| (s, 0u64, b))
            .collect()]),
        StageCodec::StageState => {
            let mut params = Vec::new();
            let mut adam_m = Vec::new();
            let mut adam_v = Vec::new();
            for (s, &b) in stage_bytes.iter().enumerate() {
                anyhow::ensure!(
                    b >= STAGE_STATE_HEADER_BYTES
                        && (b - STAGE_STATE_HEADER_BYTES) % 12 == 0,
                    "stage {s} payload of {b} bytes is not a StageState layout"
                );
                let third = (b - STAGE_STATE_HEADER_BYTES) / 3;
                let h = STAGE_STATE_HEADER_BYTES;
                params.push((s, h, third));
                adam_m.push((s, h + third, third));
                adam_v.push((s, h + 2 * third, third));
            }
            Ok(vec![params, adam_m, adam_v])
        }
    }
}

/// The full copy plan in payload space: zip-walk each neutral stream of the
/// source and target shapes, emitting maximal copies; for `StageState`,
/// every target stage additionally receives source stage 0's header.
fn copy_ops(codec: StageCodec, src: &[u64], dst: &[u64]) -> Result<Vec<CopyOp>> {
    anyhow::ensure!(
        reshape_compatible(codec, src, dst),
        "source shape {src:?} cannot be reshaped into {dst:?} under {codec:?}"
    );
    let src_streams = streams(codec, src)?;
    let dst_streams = streams(codec, dst)?;
    let mut ops = Vec::new();
    if codec == StageCodec::StageState {
        for t in 0..dst.len() {
            ops.push(CopyOp {
                src_stage: 0,
                src_off: 0,
                dst_stage: t,
                dst_off: 0,
                len: STAGE_STATE_HEADER_BYTES,
            });
        }
    }
    for (ss, ds) in src_streams.iter().zip(&dst_streams) {
        let (mut si, mut di) = (0usize, 0usize);
        let (mut s_used, mut d_used) = (0u64, 0u64);
        while si < ss.len() && di < ds.len() {
            let (s_stage, s_base, s_len) = ss[si];
            let (d_stage, d_base, d_len) = ds[di];
            let take = (s_len - s_used).min(d_len - d_used);
            if take > 0 {
                ops.push(CopyOp {
                    src_stage: s_stage,
                    src_off: s_base + s_used,
                    dst_stage: d_stage,
                    dst_off: d_base + d_used,
                    len: take,
                });
            }
            s_used += take;
            d_used += take;
            if s_used == s_len {
                si += 1;
                s_used = 0;
            }
            if d_used == d_len {
                di += 1;
                d_used = 0;
            }
        }
    }
    Ok(ops)
}

/// Pure in-memory re-tile: carve `src_stages` (at their own shape) into
/// the `target_stage_bytes` shape under `codec`. The leaf shared by the
/// delta path of [`reshape_restore`] and the tests' oracle comparisons.
pub fn retile_payload(
    codec: StageCodec,
    src_stages: &[Vec<u8>],
    target_stage_bytes: &[u64],
) -> Result<Vec<Vec<u8>>> {
    let src_sb: Vec<u64> = src_stages.iter().map(|s| s.len() as u64).collect();
    let ops = copy_ops(codec, &src_sb, target_stage_bytes)?;
    let mut out: Vec<Vec<u8>> =
        target_stage_bytes.iter().map(|&b| vec![0u8; b as usize]).collect();
    for op in &ops {
        let src = &src_stages[op.src_stage]
            [op.src_off as usize..(op.src_off + op.len) as usize];
        out[op.dst_stage][op.dst_off as usize..(op.dst_off + op.len) as usize]
            .copy_from_slice(src);
    }
    Ok(out)
}

/// One planned byte-range copy out of a shard blob: `len` bytes starting
/// `src_off` into shard `shard`'s payload land at `dst_off` of target
/// stage `dst_stage`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReshapePiece {
    /// index into the manifest's `shards`
    pub shard: usize,
    /// byte offset within that shard's payload
    pub src_off: u64,
    pub dst_stage: usize,
    pub dst_off: u64,
    pub len: u64,
}

/// The byte-range fetch plan of one reshaped restore: which shards are
/// needed at all, and where each of their byte ranges lands in the target
/// stage buffers.
#[derive(Debug, Clone)]
pub struct ReshapePlan {
    pub pieces: Vec<ReshapePiece>,
    /// unique indices of the shards the plan touches, ascending — shards a
    /// target shape doesn't need are never fetched
    pub needed: Vec<usize>,
    /// total bytes the plan fetches (the summed lengths of `needed`) —
    /// asserted ≤ the dense-restore byte count in `benches/hotpath.rs`
    pub fetched_bytes: u64,
    pub target_stage_bytes: Vec<u64>,
}

impl ReshapePlan {
    /// Plan the redistribution of full manifest `man` into
    /// `target_stage_bytes`: payload-space copy ops from the stream
    /// zip-walk, mapped through the atom index onto shard byte ranges.
    pub fn plan(
        man: &PersistManifest,
        codec: StageCodec,
        target_stage_bytes: &[u64],
    ) -> Result<ReshapePlan> {
        anyhow::ensure!(
            man.base_step.is_none(),
            "reshape plans target full manifests; reconstruct delta chains \
             at the source shape first (reshape_restore does)"
        );
        let atoms = man.atom_index()?;
        let mut prefix = vec![0u64; man.stage_bytes.len()];
        let mut acc = 0u64;
        for (i, &b) in man.stage_bytes.iter().enumerate() {
            prefix[i] = acc;
            acc += b;
        }
        let shard_of: BTreeMap<&str, usize> = man
            .shards
            .iter()
            .enumerate()
            .map(|(i, s)| (s.key.as_str(), i))
            .collect();
        let ops = copy_ops(codec, &man.stage_bytes, target_stage_bytes)?;
        let mut pieces = Vec::new();
        for op in &ops {
            // split this payload-space copy at atom boundaries and express
            // each fragment as a shard-local byte range
            let mut global = prefix[op.src_stage] + op.src_off;
            let mut dst_off = op.dst_off;
            let mut left = op.len;
            // atoms tile [0, total) ascending: find the one covering
            // `global`, then walk forward
            let mut ai = atoms.partition_point(|a| a.start + a.len <= global);
            while left > 0 {
                let a = atoms
                    .get(ai)
                    .with_context(|| format!("atom index ends before byte {global}"))?;
                let within = global - a.start;
                let take = left.min(a.len - within);
                let shard = *shard_of
                    .get(a.key.as_str())
                    .with_context(|| format!("atom names unknown shard `{}`", a.key))?;
                pieces.push(ReshapePiece {
                    shard,
                    src_off: within,
                    dst_stage: op.dst_stage,
                    dst_off,
                    len: take,
                });
                global += take;
                dst_off += take;
                left -= take;
                ai += 1;
            }
        }
        let mut needed: Vec<usize> = pieces.iter().map(|p| p.shard).collect();
        needed.sort_unstable();
        needed.dedup();
        let fetched_bytes = needed.iter().map(|&i| man.shards[i].len).sum();
        Ok(ReshapePlan {
            pieces,
            needed,
            fetched_bytes,
            target_stage_bytes: target_stage_bytes.to_vec(),
        })
    }

    /// Execute the plan: fetch every needed shard once through the
    /// fused-CRC leaf and memcpy the planned ranges into freshly allocated
    /// target stage buffers.
    pub fn execute(
        &self,
        storage: &dyn Storage,
        man: &PersistManifest,
    ) -> Result<Vec<Vec<u8>>> {
        let mut scratch: BTreeMap<usize, Vec<u8>> = BTreeMap::new();
        for &i in &self.needed {
            let s = &man.shards[i];
            let mut buf = vec![0u8; s.len as usize];
            fetch_shard_into(storage, s, &mut buf)
                .with_context(|| format!("reshape fetch of shard `{}`", s.key))?;
            scratch.insert(i, buf);
        }
        let mut out: Vec<Vec<u8>> = self
            .target_stage_bytes
            .iter()
            .map(|&b| vec![0u8; b as usize])
            .collect();
        for p in &self.pieces {
            let src =
                &scratch[&p.shard][p.src_off as usize..(p.src_off + p.len) as usize];
            out[p.dst_stage][p.dst_off as usize..(p.dst_off + p.len) as usize]
                .copy_from_slice(src);
        }
        Ok(out)
    }
}

/// Restore `man` into the `target_stage_bytes` shape. Full manifests go
/// through the planned range-fetch path (each needed shard fetched once,
/// CRC-fused); delta manifests reconstruct their chain at the **source**
/// shape first (bounded by `chain_budget` hops) and re-tile the result in
/// memory — the delta-over-reshaped-base rule. Returns the target stage
/// payloads and the number of shard bytes fetched.
pub fn reshape_restore(
    storage: &dyn Storage,
    man: &PersistManifest,
    codec: StageCodec,
    target_stage_bytes: &[u64],
    chain_budget: u64,
) -> Result<(Vec<Vec<u8>>, u64)> {
    if man.base_step.is_none() {
        let plan = ReshapePlan::plan(man, codec, target_stage_bytes)?;
        let out = plan.execute(storage, man)?;
        return Ok((out, plan.fetched_bytes));
    }
    let src = load_manifest_payload_bounded(storage, man, chain_budget)?;
    let fetched: u64 = man.stage_bytes.iter().sum();
    let out = retile_payload(codec, &src, target_stage_bytes)?;
    Ok((out, fetched))
}

/// The shape-tolerant sibling of [`super::resolve_for_recovery`]: walk the
/// committed manifests newest-first and serve the first that either
/// matches `target_stage_bytes` **exactly** (the dense path — byte-for-byte
/// the pre-reshape behavior) or is reshape-compatible under `codec` (the
/// redistribution path). The returned flag is `true` when the hit was
/// reshaped. Torn manifests are counted and traced on the way past; the
/// legacy tie-break compares steps numerically.
pub fn resolve_for_recovery_reshaped(
    storage: &dyn Storage,
    model: &str,
    codec: StageCodec,
    target_stage_bytes: &[u64],
    legacy_key: Option<&str>,
    chain_budget: u64,
) -> Option<(PersistManifest, Vec<Vec<u8>>, bool)> {
    let steps = persisted_steps(storage, model);
    for &step in steps.iter().rev() {
        let Ok(bytes) = storage.get(&manifest_key(model, step)) else {
            continue;
        };
        let Ok(man) = PersistManifest::decode(&bytes) else {
            manifest::note_torn_manifest(step);
            continue;
        };
        let hit = if man.stage_bytes == target_stage_bytes {
            load_manifest_payload_bounded(storage, &man, chain_budget)
                .ok()
                .map(|stages| (stages, false))
        } else if reshape_compatible(codec, &man.stage_bytes, target_stage_bytes) {
            reshape_restore(storage, &man, codec, target_stage_bytes, chain_budget)
                .ok()
                .map(|(stages, _)| (stages, true))
        } else {
            None
        };
        let Some((stages, reshaped)) = hit else {
            continue;
        };
        if let Some(k) = legacy_key {
            if manifest::legacy_is_newer(model, man.snapshot_step, k) {
                return None;
            }
        }
        if reshaped {
            crate::obs::instant(
                crate::obs::cat::PERSIST,
                "reshape_restore",
                man.step,
                target_stage_bytes.len() as u64,
            );
        }
        return Some((man, stages, reshaped));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::MemStorage;
    use crate::persist::manifest::{derive_atoms, shard_key, ShardEntry};

    /// A full manifest over `stage_bytes` with `shards_per_stage` even-ish
    /// shards per stage, blobs landed in `s`. Returns the manifest and the
    /// source stage payloads.
    fn synth_manifest(
        s: &MemStorage,
        model: &str,
        step: u64,
        stage_bytes: &[u64],
        shards_per_stage: usize,
        fill: impl Fn(u64) -> u8,
    ) -> (PersistManifest, Vec<Vec<u8>>) {
        let mut global = 0u64;
        let mut shards = Vec::new();
        let mut stages = Vec::new();
        for (stage, &sb) in stage_bytes.iter().enumerate() {
            let mut payload = Vec::with_capacity(sb as usize);
            for _ in 0..sb {
                payload.push(fill(global));
                global += 1;
            }
            let n = shards_per_stage.min(sb.max(1) as usize).max(1);
            let chunk = (sb as usize).div_ceil(n).max(1);
            let mut off = 0usize;
            let mut node = 0usize;
            while off < sb as usize || (sb == 0 && node == 0) {
                let end = (off + chunk).min(sb as usize);
                let body = &payload[off..end];
                let key = shard_key(model, step, stage, node);
                s.put(&key, body).unwrap();
                shards.push(ShardEntry {
                    key,
                    stage,
                    node,
                    offset: off as u64,
                    len: (end - off) as u64,
                    crc32: crc32fast::hash(body),
                    extents: vec![],
                    parts: vec![],
                });
                off = end;
                node += 1;
                if sb == 0 {
                    break;
                }
            }
            stages.push(payload);
        }
        let atoms = derive_atoms(stage_bytes, &shards).unwrap();
        let man = PersistManifest {
            model: model.into(),
            step,
            version: 1,
            snapshot_step: step,
            stage_bytes: stage_bytes.to_vec(),
            shards,
            base_step: None,
            atoms,
        };
        s.put(&manifest_key(model, step), &man.encode()).unwrap();
        (man, stages)
    }

    #[test]
    fn opaque_reshape_is_stream_identical() {
        let s = MemStorage::new();
        let (man, src) =
            synth_manifest(&s, "r", 10, &[100, 60, 40], 3, |g| (g % 251) as u8);
        for target in [vec![200u64], vec![50, 50, 50, 50], vec![100, 60, 40]] {
            let (out, fetched) =
                reshape_restore(&s, &man, StageCodec::Opaque, &target, 8).unwrap();
            let got: Vec<u8> = out.concat();
            let want: Vec<u8> = src.concat();
            assert_eq!(got, want, "stream identity at target {target:?}");
            assert!(fetched <= 200, "never fetch more than the dense restore");
            // the pure in-memory re-tile agrees with the planned-fetch path
            assert_eq!(retile_payload(StageCodec::Opaque, &src, &target).unwrap(), out);
        }
        // identity target is byte-for-byte per stage
        let (out, _) =
            reshape_restore(&s, &man, StageCodec::Opaque, &[100, 60, 40], 8).unwrap();
        assert_eq!(out, src);
    }

    #[test]
    fn partial_target_fetches_only_needed_shards() {
        let s = MemStorage::new();
        let (man, src) =
            synth_manifest(&s, "r", 10, &[120, 120], 4, |g| (g % 249) as u8);
        // a plan for ONLY the first 30 bytes-worth... not expressible as a
        // target (targets must cover the stream), but a collapse to one
        // stage still needs every shard exactly once
        let plan = ReshapePlan::plan(&man, StageCodec::Opaque, &[240]).unwrap();
        assert_eq!(plan.needed.len(), man.shards.len());
        assert_eq!(plan.fetched_bytes, 240);
        let out = plan.execute(&s, &man).unwrap();
        assert_eq!(out[0], src.concat());
    }

    #[test]
    fn stage_state_reshape_retiles_element_streams_and_reanchors_headers() {
        // 2 source stages of 3 and 2 params → one target stage of 5 params
        let n = [3u64, 2u64];
        let sb: Vec<u64> = n.iter().map(|&k| 40 + 12 * k).collect();
        let mut stages = Vec::new();
        let mut next = 0u8;
        for (i, &k) in n.iter().enumerate() {
            let mut p = Vec::new();
            p.extend_from_slice(&(77u64).to_le_bytes()); // step, uniform
            for lane in 0..4u64 {
                p.extend_from_slice(&(1000 * (i as u64) + lane).to_le_bytes());
            }
            for _ in 0..12 * k {
                p.push(next);
                next = next.wrapping_add(1);
            }
            stages.push(p);
        }
        let target = vec![40 + 12 * 5];
        let out = retile_payload(StageCodec::StageState, &stages, &target).unwrap();
        assert_eq!(out.len(), 1);
        // header: source stage 0's, verbatim
        assert_eq!(out[0][..40], stages[0][..40]);
        // params stream: stage0 params (12 bytes) then stage1 params (8)
        let params: Vec<u8> = [&stages[0][40..52], &stages[1][40..48]].concat();
        assert_eq!(out[0][40..60], params[..]);
        // adam_m stream follows the same carve
        let adam_m: Vec<u8> = [&stages[0][52..64], &stages[1][48..56]].concat();
        assert_eq!(out[0][60..80], adam_m[..]);
        // and the round trip back to the source shape restores the element
        // streams exactly (headers re-anchored to stage 0's)
        let back = retile_payload(StageCodec::StageState, &out, &sb).unwrap();
        assert_eq!(back[0][40..], stages[0][40..]);
        assert_eq!(back[1][40..], stages[1][40..]);
        assert_eq!(back[1][..40], stages[0][..40], "headers re-anchored");
    }

    #[test]
    fn incompatible_shapes_are_refused() {
        assert!(!reshape_compatible(StageCodec::Opaque, &[100], &[99]));
        assert!(!reshape_compatible(StageCodec::Opaque, &[], &[100]));
        assert!(reshape_compatible(StageCodec::Opaque, &[60, 40], &[100]));
        // StageState: totals match only after header accounting
        assert!(reshape_compatible(StageCodec::StageState, &[40 + 24, 40 + 12], &[40 + 36]));
        assert!(!reshape_compatible(StageCodec::StageState, &[40 + 24], &[40 + 25]));
        assert!(!reshape_compatible(StageCodec::StageState, &[39], &[39]));
        let src = vec![vec![0u8; 100]];
        assert!(retile_payload(StageCodec::Opaque, &src, &[99]).is_err());
    }

    #[test]
    fn reshaped_resolver_serves_dense_when_shapes_match() {
        let s = MemStorage::new();
        let (_, src) = synth_manifest(&s, "r", 10, &[64, 64], 2, |g| (g % 200) as u8);
        let (man, stages, reshaped) = resolve_for_recovery_reshaped(
            &s,
            "r",
            StageCodec::Opaque,
            &[64, 64],
            None,
            8,
        )
        .unwrap();
        assert!(!reshaped, "exact shape takes the dense path");
        assert_eq!(man.step, 10);
        assert_eq!(stages, src);
        // mismatched but compatible target takes the reshape path
        let (_, stages, reshaped) =
            resolve_for_recovery_reshaped(&s, "r", StageCodec::Opaque, &[128], None, 8)
                .unwrap();
        assert!(reshaped);
        assert_eq!(stages[0], src.concat());
        // incompatible target finds nothing
        assert!(resolve_for_recovery_reshaped(
            &s,
            "r",
            StageCodec::Opaque,
            &[127],
            None,
            8
        )
        .is_none());
    }

    #[test]
    fn delta_chain_replays_onto_the_reshaped_base() {
        // base at step 10, delta at step 14 patching bytes — reshape of the
        // delta head must equal the dense chain restore, re-tiled
        let s = MemStorage::new();
        let (base, src) = synth_manifest(&s, "r", 10, &[60, 40], 2, |g| (g % 97) as u8);
        let mut d = base.clone();
        d.step = 14;
        d.snapshot_step = 14;
        d.base_step = Some(10);
        d.atoms = vec![];
        for sh in &mut d.shards {
            sh.key = shard_key("r", 14, sh.stage, sh.node);
        }
        // patch 4 bytes at offset 2 of stage 0's first shard
        let mut patched = src.clone();
        for i in 2..6 {
            patched[0][i] ^= 0xA5;
        }
        d.shards[0].extents = vec![(2, 4)];
        d.shards[0].crc32 = crc32fast::hash(&patched[0][..d.shards[0].len as usize]);
        s.put(&d.shards[0].key, &patched[0][2..6]).unwrap();
        s.put(&manifest_key("r", 14), &d.encode()).unwrap();

        let (hit, stages, reshaped) =
            resolve_for_recovery_reshaped(&s, "r", StageCodec::Opaque, &[100], None, 8)
                .unwrap();
        assert!(reshaped);
        assert_eq!(hit.step, 14, "the delta head serves, not the base");
        assert_eq!(stages[0], patched.concat(), "extents land on the reshaped base");
    }
}
