//! Trainer-side persistence driver: one object owning the engine handle,
//! the optional live cadence scheduler, and the metric delta-sync, so both
//! trainers (`DpTrainer`, `PipelineTrainer`) share the exact same durable-
//! tier behaviour instead of duplicating it.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::checkpoint::Storage;
use crate::config::FtConfig;
use crate::metrics::{keys, Metrics};
use crate::smp::SmpMsg;
use crate::snapshot::SnapshotPlan;

use super::engine::{PersistEngine, PersistStats};
use super::scheduler::IntervalScheduler;

/// How many recent snapshot-version → capture-step pairs we remember for
/// honest manifest labeling (the drained round is at most a few versions
/// behind the enqueue).
const RECENT_VERSIONS: usize = 32;

pub struct PersistDriver {
    engine: PersistEngine,
    /// live Appendix-A cadence (None = static `persist_every` gating)
    sched: Option<IntervalScheduler>,
    /// engine counters already folded into the run metrics (delta sync)
    seen: PersistStats,
    /// recent (snapshot version, capture step) pairs from the trainer
    recent_versions: VecDeque<(u64, u64)>,
    /// commits already fed to the scheduler (skip re-derivation otherwise)
    observed_commits: u64,
    /// the run clock failure events are stamped against
    t0: Instant,
}

impl PersistDriver {
    /// Engine + optional scheduler for a REFT-Ckpt run with
    /// `ft.persist.enabled`. `sg_size` is the sharding-group size driving
    /// the Eq. 7 exceedance rate (callers pass the widest SG); the cluster
    /// size the empirical failure rate normalizes over comes from the plan.
    pub fn start(
        model: impl Into<String>,
        storage: Arc<dyn Storage>,
        plan: SnapshotPlan,
        ft: &FtConfig,
        sg_size: usize,
    ) -> PersistDriver {
        let nodes = plan.nodes();
        // the sparse-delta knobs live under `ft` (the snapshot layer reads
        // them first); mirror them into the engine config so one pair of
        // JSON knobs drives the whole changed-bytes path end to end
        let mut pcfg = ft.persist.clone();
        pcfg.delta_extent_bytes = ft.delta_extent_bytes;
        pcfg.delta_chain_max = ft.delta_chain_max;
        let engine = PersistEngine::start(model, storage, plan, pcfg);
        let sched = ft.persist.auto_interval.then(|| {
            IntervalScheduler::new(
                ft.persist.lambda_node,
                sg_size,
                nodes,
                (ft.persist_every * ft.snapshot_interval) as u64,
            )
        });
        PersistDriver {
            engine,
            sched,
            seen: PersistStats::default(),
            recent_versions: VecDeque::new(),
            observed_commits: 0,
            t0: Instant::now(),
        }
    }

    /// Record which trainer step a snapshot version captured, so the
    /// manifest the engine later commits can state the step its drained
    /// round actually contains.
    pub fn note_snapshot(&mut self, version: u64, step: u64) {
        self.recent_versions.push_back((version, step));
        while self.recent_versions.len() > RECENT_VERSIONS {
            self.recent_versions.pop_front();
        }
    }

    /// One observed node failure, stamped on the driver's run clock. Feeds
    /// the live cadence scheduler's rolling empirical λ (a no-op under the
    /// static cadence) — the trainers call this from their hardware-failure
    /// injection point, so the persist interval tracks the failure rate the
    /// run actually experiences instead of the `lambda_node` guess.
    ///
    /// The driver owns exactly ONE clock domain (wall seconds since start),
    /// which is why there is deliberately no driver-level hwsim-schedule
    /// ingest: a sim feed stamps events in *sim* time, and mixing the two
    /// bases in one rolling window would corrupt the rate (a huge phantom
    /// span → λ underestimated by orders of magnitude). Sim-driven harnesses
    /// own their `IntervalScheduler` directly and use
    /// [`IntervalScheduler::ingest_failure_schedule`] on the sim clock.
    pub fn note_failure(&mut self) {
        let at = self.t0.elapsed().as_secs_f64();
        if let Some(s) = self.sched.as_mut() {
            s.note_failure_event(at);
        }
    }

    /// A recovery restored training state: open a fresh λ-observation epoch
    /// on the driver's run clock. The failures counted so far described the
    /// regime (and often the very hardware) the restore just retired, so
    /// carrying them forward would keep the durable cadence pinned tight
    /// long after the cluster went quiet — the posterior returns to the
    /// knob-derived prior instead. A no-op under the static cadence.
    pub fn note_restore(&mut self) {
        let at = self.t0.elapsed().as_secs_f64();
        if let Some(s) = self.sched.as_mut() {
            s.reset_epoch(at);
        }
    }

    /// The live cadence scheduler, when enabled (tests + telemetry).
    pub fn scheduler(&self) -> Option<&IntervalScheduler> {
        self.sched.as_ref()
    }

    /// Cadence gate at a snapshot boundary: the scheduler when enabled,
    /// else the static interval (in steps).
    pub fn due(&mut self, step: u64, static_interval_steps: u64) -> bool {
        match self.sched.as_mut() {
            Some(s) => s.should_persist(step),
            None => static_interval_steps > 0 && step % static_interval_steps == 0,
        }
    }

    /// The trainer-thread persist hand-off: time the enqueue under
    /// `persist_stall` and fold the engine counters forward.
    pub fn enqueue(
        &mut self,
        step: u64,
        sources: Vec<Option<Sender<SmpMsg>>>,
        metrics: &Metrics,
    ) -> Result<()> {
        let version_steps: Vec<(u64, u64)> = self.recent_versions.iter().copied().collect();
        metrics.time_k(keys::PERSIST_STALL, || {
            self.engine.enqueue(step, sources, version_steps)
        })?;
        metrics.inc_k(keys::PERSIST_ENQUEUES, 1);
        self.sync(metrics);
        Ok(())
    }

    /// Per-step cadence re-derivation from measured costs. A no-op until
    /// the first job commits — before that `last_job_secs` is 0 and
    /// feeding it to the Eq. 11 math would clobber the static fallback
    /// cadence with a fabricated zero-cost measurement (pushing the
    /// *first* persist out indefinitely) — and between commits, since the
    /// measurement only changes when a new job lands. The steady-state
    /// per-step cost is one two-scalar mutex read.
    pub fn observe(&mut self, metrics: &Metrics) {
        let (commits, last_job_secs) = self.engine.commit_meta();
        if commits == 0 || commits == self.observed_commits {
            return;
        }
        self.observed_commits = commits;
        // depth telemetry moves only when jobs report, so the per-commit
        // cadence is exactly right for it — adaptive or not
        metrics.gauge("persist_pipeline_depth", self.engine.pipeline_depth() as f64);
        let Some(sched) = self.sched.as_mut() else {
            return;
        };
        let t_step = metrics.timer("step_wall").mean();
        let steps = sched.observe(last_job_secs, t_step);
        metrics.gauge("persist_interval_steps", steps as f64);
        metrics.gauge("persist_lambda_node", sched.lambda_node());
    }

    /// The engine's current pipeline depth (static unless
    /// `persist.adaptive_depth` is on).
    pub fn pipeline_depth(&self) -> usize {
        self.engine.pipeline_depth()
    }

    /// Shutdown barrier: block until every enqueued job committed or
    /// aborted, then sync counters. The only blocking persistence call.
    pub fn flush(&mut self, metrics: &Metrics) -> Result<()> {
        metrics.time_k(keys::PERSIST_FLUSH, || self.engine.flush())?;
        self.sync(metrics);
        Ok(())
    }

    pub fn stats(&self) -> PersistStats {
        self.engine.stats()
    }

    /// Fold the engine's (monotonic) counters into the run metrics as
    /// deltas, so `persisted_bytes` / `persist_commits` / `persist_aborts`
    /// / `persist_parts_*` read like every other counter.
    fn sync(&mut self, metrics: &Metrics) {
        let st = self.engine.stats();
        // one `persist_job` histogram sample per commit batch: the engine
        // only retains the latest job's wall-clock, so the distribution is
        // sampled at the sync cadence, not per job
        if st.manifests_committed > self.seen.manifests_committed && st.last_job_secs > 0.0 {
            metrics.record_secs_k(keys::PERSIST_JOB, st.last_job_secs);
        }
        metrics.inc_k(keys::PERSISTED_BYTES, st.persisted_bytes - self.seen.persisted_bytes);
        metrics.inc(
            "persisted_full_bytes",
            st.persisted_full_bytes - self.seen.persisted_full_bytes,
        );
        metrics.inc(
            "persisted_delta_bytes",
            st.persisted_delta_bytes - self.seen.persisted_delta_bytes,
        );
        metrics.inc(
            "persist_commits",
            st.manifests_committed - self.seen.manifests_committed,
        );
        metrics.inc_k(keys::PERSIST_ABORTS, st.jobs_aborted - self.seen.jobs_aborted);
        metrics.inc(
            "persist_parts_uploaded",
            st.parts_uploaded - self.seen.parts_uploaded,
        );
        metrics.inc(
            "persist_parts_reused",
            st.parts_reused - self.seen.parts_reused,
        );
        self.seen = st;
    }
}
