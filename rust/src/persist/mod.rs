//! Asynchronous tiered persistence: the durable tier below the in-memory
//! snapshot fabric (paper §6.1, REFT-Ckpt).
//!
//! The in-memory tier (SMPs + RAIM5) absorbs the common failures; this
//! subsystem drains *completed* snapshot rounds to the [`Storage`] tier in
//! the background so the rare protection-exceeded case has a durable
//! fallback — without the training thread ever paying for the upload.
//!
//! * [`engine`] — the background drain, a **multi-job pipeline**: up to
//!   `pipeline_jobs` jobs overlap their SMP fetches and uploads while a
//!   commit turnstile keeps manifests landing in enqueue order; per-node
//!   writer workers pull clean shards from the SMPs and stream them under
//!   **per-node** bytes/sec throttle lanes (cluster budget split, sum
//!   preserved); large shards land as **resumable multipart** part-objects
//!   with per-part CRCs. Trainer-side cost is one enqueue.
//! * [`driver`] — the trainer-side handle (engine + cadence + metric
//!   sync + live failure-event feed), shared by both trainers.
//! * [`manifest`] — the atomic commit unit: a cluster-wide manifest written
//!   only after every shard landed, so `latest` can never name a torn or
//!   partial checkpoint; loading is a parallel sharded gather (the serial
//!   loop is kept as the measured baseline/oracle).
//! * [`reshape`] — reshape-on-restore: the manifest's parallelism-neutral
//!   atom index turned into a byte-range fetch plan for a **different**
//!   dp/tp/pp split, so an elastic shrink/grow restores instead of
//!   aborting (Universal Checkpointing, arxiv 2406.18820).
//! * [`retention`] — keep-last-K + keep-every-Nth GC of superseded versions
//!   and orphaned shard blobs/part-objects.
//! * [`scheduler`] — the live Appendix-A cadences: measured save overhead
//!   and the failure rate — the shared [`LambdaTracker`]'s conjugate
//!   Gamma posterior over λ, anchored on the operator knob as the prior
//!   mean and sharpening continuously toward the empirical MLE as events
//!   and exposure accrue — pick the persist interval (Eq. 11,
//!   [`IntervalScheduler`]) and the in-memory snapshot interval (Eq. 9,
//!   [`SnapshotScheduler`], which holds the static interval until the
//!   first observed event). The engine's [`engine::DepthController`]
//!   closes the third loop: pipeline depth from the fetch-vs-upload EWMA.
//!
//! [`Storage`]: crate::checkpoint::Storage

pub mod driver;
pub mod engine;
pub mod manifest;
pub mod reshape;
pub mod retention;
pub mod scheduler;

pub use driver::PersistDriver;
pub use engine::{NodeThrottles, PersistEngine, PersistStats, Throttle};
pub use manifest::{
    derive_atoms, load_latest, load_manifest_payload, load_manifest_payload_bounded,
    load_manifest_payload_separate, load_manifest_payload_serial, manifest_key,
    manifest_prefix, manifest_torn_count, part_key, part_meta_key, persisted_steps,
    resolve_for_recovery, resolve_for_recovery_bounded, shard_key, step_of_key,
    sweep_orphan_shards, AtomEntry, PartEntry, PartProgress, PersistManifest, ShardEntry,
    DEFAULT_CHAIN_BUDGET,
};
pub use reshape::{
    reshape_compatible, reshape_restore, resolve_for_recovery_reshaped, retile_payload,
    ReshapePiece, ReshapePlan, StageCodec, STAGE_STATE_HEADER_BYTES,
};
pub use retention::{run_gc, GcReport, RetentionPolicy};
pub use scheduler::{IntervalScheduler, LambdaTracker, SnapshotScheduler, GAMMA_PRIOR_EVENTS};
