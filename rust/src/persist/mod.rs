//! Asynchronous tiered persistence: the durable tier below the in-memory
//! snapshot fabric (paper §6.1, REFT-Ckpt).
//!
//! The in-memory tier (SMPs + RAIM5) absorbs the common failures; this
//! subsystem drains *completed* snapshot rounds to the [`Storage`] tier in
//! the background so the rare protection-exceeded case has a durable
//! fallback — without the training thread ever paying for the upload.
//!
//! * [`engine`] — the background drain: per-node writer workers pull clean
//!   shards from the SMPs and stream them under a bytes/sec throttle;
//!   trainer-side cost is one enqueue.
//! * [`driver`] — the trainer-side handle (engine + cadence + metric
//!   sync), shared by both trainers.
//! * [`manifest`] — the atomic commit unit: a cluster-wide manifest written
//!   only after every shard landed, so `latest` can never name a torn or
//!   partial checkpoint.
//! * [`retention`] — keep-last-K + keep-every-Nth GC of superseded versions
//!   and orphaned shard blobs.
//! * [`scheduler`] — the live Appendix-A cadence: measured save overhead
//!   and the hwsim failure rate pick the persist interval instead of the
//!   static `persist_every` knob.
//!
//! [`Storage`]: crate::checkpoint::Storage

pub mod driver;
pub mod engine;
pub mod manifest;
pub mod retention;
pub mod scheduler;

pub use driver::PersistDriver;
pub use engine::{PersistEngine, PersistStats, Throttle};
pub use manifest::{
    load_latest, load_manifest_payload, manifest_key, manifest_prefix, persisted_steps,
    resolve_for_recovery, shard_key, sweep_orphan_shards, PersistManifest, ShardEntry,
};
pub use retention::{run_gc, GcReport, RetentionPolicy};
pub use scheduler::IntervalScheduler;
