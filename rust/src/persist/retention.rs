//! Retention policy + garbage collection for the durable tier.
//!
//! Two knobs compose (union of the two keep-sets):
//! * **keep-last-K** — the newest K manifests always survive (K floors at 1:
//!   the latest durable checkpoint is never collected);
//! * **keep-every-Nth** — any step divisible by N survives regardless of
//!   age, giving a sparse long-horizon history (0 disables).
//!
//! Deletion order is crash-consistent with the commit protocol: a dropped
//! version loses its *manifest first* (readers immediately stop resolving
//! it), then its shard blobs **and multipart part-objects**; a crash in
//! between just leaves orphans for the next sweep. The sweep also collects:
//! * shard-namespace keys of steps that never committed a manifest
//!   (aborted or crashed persist jobs), and
//! * keys under a *retained* step that its committed manifest does not
//!   reference — part debris of an earlier crashed attempt whose chunking
//!   differed from the attempt that finally committed.
//!
//! **Chain liveness rule** (sparse delta snapshots): a delta manifest is
//! only restorable while every link down to its full base survives, so a
//! retained delta transitively pins its whole `base_step` chain regardless
//! of the chain members' own age. Conversely a delta whose chain is already
//! broken (a base manifest missing) can never load again — keeping it would
//! only shadow older restorable rounds at recovery, so the sweep deletes
//! such orphaned deltas along with their blobs.

use std::collections::BTreeSet;

use anyhow::Result;

use crate::checkpoint::Storage;

use super::manifest::{self, PersistManifest};

#[derive(Debug, Clone, Copy)]
pub struct RetentionPolicy {
    /// always keep the newest K manifests (values below 1 are treated as 1)
    pub keep_last: usize,
    /// additionally keep every step divisible by N (0 disables)
    pub keep_every: u64,
}

impl RetentionPolicy {
    /// Which of `steps` (ascending) survive this policy.
    pub fn retained(&self, steps: &[u64]) -> BTreeSet<u64> {
        let mut keep: BTreeSet<u64> =
            steps.iter().rev().take(self.keep_last.max(1)).copied().collect();
        if self.keep_every > 0 {
            keep.extend(steps.iter().copied().filter(|s| s % self.keep_every == 0));
        }
        keep
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GcReport {
    pub manifests_deleted: usize,
    pub blobs_deleted: usize,
}

/// Apply the policy to `model`'s durable checkpoints and sweep orphaned
/// shard blobs/parts older than the newest committed manifest, plus
/// unreferenced part debris under `debris_step` (the engine passes the step
/// it just committed — the only step THIS engine instance can have resumed
/// with a different multipart chunking, so one manifest decode covers the
/// case without re-decoding every retained manifest on every pass; earlier
/// steps' debris was swept at their own commit). One listing snapshot
/// serves the whole pass — manifest enumeration, both sweeps — so the
/// per-commit GC costs a single full scan, not three. The pipelined engine
/// runs this inside the commit turnstile, so concurrent GC passes cannot
/// race each other, and any in-flight job's step is strictly newer than
/// `before_step` (commits are in enqueue order).
pub fn run_gc(
    storage: &dyn Storage,
    model: &str,
    policy: &RetentionPolicy,
    debris_step: Option<u64>,
) -> Result<GcReport> {
    let keys = storage.list();
    let prefix = manifest::manifest_prefix(model);
    let mut steps: Vec<u64> = keys
        .iter()
        .filter_map(|k| manifest::step_of_key(k, &prefix))
        .collect();
    steps.sort_unstable();
    steps.dedup();
    let Some(&newest) = steps.last() else {
        return Ok(GcReport::default());
    };
    let mut keep = policy.retained(&steps);
    let manifested: BTreeSet<u64> = steps.iter().copied().collect();
    // chain-aware expansion + orphan detection (module docs: the chain
    // liveness rule). Each kept manifest's `base_step` chain is walked to
    // its full base: live links join the keep-set, while a dangling link
    // (base manifest gone) marks every dependent above it as an orphan —
    // unrestorable forever, so it is retired below like any dropped step.
    let mut orphaned: BTreeSet<u64> = BTreeSet::new();
    for &step in steps.iter().rev() {
        if !keep.contains(&step) || orphaned.contains(&step) {
            continue;
        }
        let mut cur = step;
        let mut chain = vec![cur];
        let broken = loop {
            let link = storage
                .get(&manifest::manifest_key(model, cur))
                .ok()
                .and_then(|b| PersistManifest::decode(&b).ok())
                .map(|m| m.base_step);
            match link {
                // undecodable manifest: recovery skips it too, but deleting
                // on what may be a transient read error would be
                // destructive — leave it and just don't pin a chain for it
                None => break false,
                Some(None) => break false, // reached a full base
                Some(Some(base)) => {
                    // `base >= cur` cannot come from the engine (links
                    // strictly decrease); treat it as a broken chain rather
                    // than walking a corrupt cycle
                    if !manifested.contains(&base) || base >= cur {
                        break true;
                    }
                    cur = base;
                    chain.push(base);
                }
            }
        };
        if broken {
            orphaned.extend(chain);
        } else {
            keep.extend(chain);
        }
    }
    keep.retain(|s| !orphaned.contains(s));
    let mut report = GcReport::default();
    // shard-namespace keys the debris-swept manifest references, and the
    // steps whose manifest decoded cleanly (only those are safe to sweep
    // for unreferenced debris)
    let mut referenced: BTreeSet<String> = BTreeSet::new();
    let mut swept_steps: BTreeSet<u64> = BTreeSet::new();
    for &step in &steps {
        let key = manifest::manifest_key(model, step);
        if keep.contains(&step) {
            if debris_step == Some(step) {
                if let Some(m) = storage
                    .get(&key)
                    .ok()
                    .and_then(|b| PersistManifest::decode(&b).ok())
                {
                    for s in &m.shards {
                        referenced.extend(s.storage_keys());
                    }
                    swept_steps.insert(step);
                }
            }
            continue;
        }
        // read the shard list before unlinking the manifest, so the blobs
        // and parts can still be found once the version stops resolving
        let shard_keys: Vec<String> = storage
            .get(&key)
            .ok()
            .and_then(|b| PersistManifest::decode(&b).ok())
            .map(|m| m.shards.iter().flat_map(|s| s.storage_keys()).collect())
            .unwrap_or_default();
        storage.delete(&key)?;
        report.manifests_deleted += 1;
        for k in shard_keys {
            // deletes are idempotent: a multipart shard has no blob under
            // its single-blob key and vice versa
            if storage.exists(&k) {
                storage.delete(&k)?;
                report.blobs_deleted += 1;
            }
        }
    }
    // orphans = shard steps that never committed a manifest; steps whose
    // manifest was just retired above were handled through its shard list
    report.blobs_deleted +=
        manifest::sweep_orphans_in(storage, model, &manifested, newest, &keys);
    // multipart debris under the just-committed step: a crashed earlier
    // attempt may have left parts the committed manifest doesn't reference
    // (different chunking, or a whole-blob upload superseded by parts)
    let shard_prefix = manifest::shard_prefix(model);
    for key in &keys {
        if let Some(step) = manifest::step_of_key(key, &shard_prefix) {
            if swept_steps.contains(&step)
                && !referenced.contains(key)
                && storage.exists(key)
                && storage.delete(key).is_ok()
            {
                report.blobs_deleted += 1;
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::MemStorage;
    use crate::persist::manifest::{
        manifest_key, part_key, shard_key, PartEntry, PersistManifest, ShardEntry,
    };

    #[test]
    fn keep_last_floors_at_one() {
        let p = RetentionPolicy { keep_last: 0, keep_every: 0 };
        let kept = p.retained(&[5, 10, 15]);
        assert_eq!(kept.into_iter().collect::<Vec<_>>(), vec![15]);
    }

    #[test]
    fn keep_last_takes_newest() {
        let p = RetentionPolicy { keep_last: 2, keep_every: 0 };
        let kept = p.retained(&[5, 10, 15, 20]);
        assert_eq!(kept.into_iter().collect::<Vec<_>>(), vec![15, 20]);
    }

    #[test]
    fn keep_every_unions_with_keep_last() {
        let p = RetentionPolicy { keep_last: 2, keep_every: 10 };
        let kept = p.retained(&[5, 10, 15, 20, 25]);
        // newest two (20, 25) plus every multiple of 10 (10, 20)
        assert_eq!(kept.into_iter().collect::<Vec<_>>(), vec![10, 20, 25]);
    }

    #[test]
    fn fewer_steps_than_keep_last_keeps_all() {
        let p = RetentionPolicy { keep_last: 8, keep_every: 0 };
        let kept = p.retained(&[3, 6]);
        assert_eq!(kept.into_iter().collect::<Vec<_>>(), vec![3, 6]);
    }

    /// A retired multipart version loses its part-objects, and part debris
    /// of a crashed earlier attempt under the *retained* step is swept
    /// while every referenced key survives.
    #[test]
    fn gc_sweeps_parts_of_dropped_versions_and_unreferenced_debris() {
        let s = MemStorage::new();
        let mk_manifest = |step: u64, parts: usize| -> PersistManifest {
            let body = vec![step as u8; 8];
            let part_len = 8 / parts;
            let entries: Vec<PartEntry> = (0..parts)
                .map(|k| {
                    let chunk = &body[k * part_len..(k + 1) * part_len];
                    PartEntry {
                        key: part_key("m", step, 0, 0, k),
                        len: part_len as u64,
                        crc32: crc32fast::hash(chunk),
                    }
                })
                .collect();
            for (k, p) in entries.iter().enumerate() {
                s.put(&p.key, &body[k * part_len..(k + 1) * part_len]).unwrap();
            }
            PersistManifest {
                model: "m".into(),
                step,
                version: step,
                snapshot_step: step,
                stage_bytes: vec![8],
                shards: vec![ShardEntry {
                    key: shard_key("m", step, 0, 0),
                    stage: 0,
                    node: 0,
                    offset: 0,
                    len: 8,
                    crc32: crc32fast::hash(&body),
                    extents: vec![],
                    parts: entries,
                }],
                base_step: None,
                atoms: vec![],
            }
        };
        let old = mk_manifest(10, 2);
        s.put(&manifest_key("m", 10), &old.encode()).unwrap();
        let new = mk_manifest(20, 2);
        s.put(&manifest_key("m", 20), &new.encode()).unwrap();
        // debris under the retained step 20: parts 2..4 of a crashed
        // earlier attempt with a finer chunking
        s.put(&part_key("m", 20, 0, 0, 2), &[9; 2]).unwrap();
        s.put(&part_key("m", 20, 0, 0, 3), &[9; 2]).unwrap();

        let policy = RetentionPolicy { keep_last: 1, keep_every: 0 };
        // the engine passes the step it just committed (20): only that
        // step's debris is swept — a pass for an unrelated step must leave
        // the stray parts alone (they are under a manifested step, so the
        // orphan sweep ignores them too)
        let report = run_gc(&s, "m", &policy, None).unwrap();
        assert_eq!(report.manifests_deleted, 1);
        assert_eq!(report.blobs_deleted, 2, "only step 10's dropped parts");
        assert!(s.exists(&part_key("m", 20, 0, 0, 2)), "debris untouched without debris_step");
        let report = run_gc(&s, "m", &policy, Some(20)).unwrap();
        // dropped manifests already gone; now the 2 stray parts of step 20
        assert_eq!(report.manifests_deleted, 0);
        assert_eq!(report.blobs_deleted, 2);
        assert!(!s.exists(&old.shards[0].parts[0].key), "dropped parts gone");
        assert!(!s.exists(&part_key("m", 20, 0, 0, 2)), "debris swept");
        assert!(s.exists(&new.shards[0].parts[0].key), "referenced parts kept");
        // the retained version still loads end to end
        let (man, stages) = crate::persist::load_latest(&s, "m").unwrap().unwrap();
        assert_eq!(man.step, 20);
        assert_eq!(stages[0], vec![20u8; 8]);
    }

    /// One single-blob full manifest at `step` holding `body`.
    fn put_full(s: &MemStorage, step: u64, body: &[u8]) -> PersistManifest {
        let key = shard_key("m", step, 0, 0);
        s.put(&key, body).unwrap();
        let m = PersistManifest {
            model: "m".into(),
            step,
            version: step,
            snapshot_step: step,
            stage_bytes: vec![body.len() as u64],
            shards: vec![ShardEntry {
                key,
                stage: 0,
                node: 0,
                offset: 0,
                len: body.len() as u64,
                crc32: crc32fast::hash(body),
                extents: vec![],
                parts: vec![],
            }],
            base_step: None,
            atoms: vec![],
        };
        s.put(&manifest_key("m", step), &m.encode()).unwrap();
        m
    }

    /// One delta manifest at `step` linking to `base`, whose reconstructed
    /// shard is `body` with only the `(start, len)` extent shipped.
    fn put_delta(
        s: &MemStorage,
        step: u64,
        base: u64,
        body: &[u8],
        ext: (u64, u64),
    ) -> PersistManifest {
        let key = shard_key("m", step, 0, 0);
        s.put(&key, &body[ext.0 as usize..(ext.0 + ext.1) as usize]).unwrap();
        let m = PersistManifest {
            model: "m".into(),
            step,
            version: step,
            snapshot_step: step,
            stage_bytes: vec![body.len() as u64],
            shards: vec![ShardEntry {
                key,
                stage: 0,
                node: 0,
                offset: 0,
                len: body.len() as u64,
                crc32: crc32fast::hash(body),
                extents: vec![ext],
                parts: vec![],
            }],
            base_step: Some(base),
            atoms: vec![],
        };
        s.put(&manifest_key("m", step), &m.encode()).unwrap();
        m
    }

    /// A retained delta pins its whole chain: the base (and mid-chain
    /// links) survive keep-last-1 even though they are older, and the
    /// newest round still reconstructs after the sweep.
    #[test]
    fn gc_keeps_the_chain_of_a_retained_delta() {
        let s = MemStorage::new();
        put_full(&s, 10, &[1u8; 8]);
        put_delta(&s, 20, 10, &[1, 1, 9, 9, 1, 1, 1, 1], (2, 2));
        put_delta(&s, 30, 20, &[1, 1, 9, 9, 1, 1, 7, 7], (6, 2));
        // an unrelated old full round IS collected — chain pinning must not
        // degenerate into keep-everything
        put_full(&s, 5, &[5u8; 8]);
        let policy = RetentionPolicy { keep_last: 1, keep_every: 0 };
        let report = run_gc(&s, "m", &policy, None).unwrap();
        assert_eq!(report.manifests_deleted, 1, "only step 5 retired");
        assert!(s.exists(&manifest_key("m", 10)), "base pinned by the chain");
        assert!(s.exists(&manifest_key("m", 20)), "mid-chain link pinned");
        let (man, stages) = crate::persist::load_latest(&s, "m").unwrap().unwrap();
        assert_eq!(man.step, 30);
        assert_eq!(stages[0], vec![1, 1, 9, 9, 1, 1, 7, 7]);
    }

    /// A delta whose base manifest is gone can never load again: the sweep
    /// retires the whole broken chain (manifests and blobs) and recovery
    /// falls back to the newest restorable round.
    #[test]
    fn gc_sweeps_orphaned_delta_chains() {
        let s = MemStorage::new();
        put_full(&s, 10, &[1u8; 8]);
        // chain 40 -> 30 -> 15, but 15 never existed (or was lost): both
        // deltas are unrestorable
        let d30 = put_delta(&s, 30, 15, &[1, 1, 9, 9, 1, 1, 1, 1], (2, 2));
        let d40 = put_delta(&s, 40, 30, &[1, 1, 9, 9, 1, 1, 7, 7], (6, 2));
        let policy = RetentionPolicy { keep_last: 3, keep_every: 0 };
        let report = run_gc(&s, "m", &policy, None).unwrap();
        assert_eq!(report.manifests_deleted, 2, "both orphaned deltas retired");
        assert!(!s.exists(&manifest_key("m", 30)));
        assert!(!s.exists(&manifest_key("m", 40)));
        assert!(!s.exists(&d30.shards[0].key), "orphan blobs swept");
        assert!(!s.exists(&d40.shards[0].key));
        let (man, stages) = crate::persist::load_latest(&s, "m").unwrap().unwrap();
        assert_eq!(man.step, 10, "recovery lands on the surviving base");
        assert_eq!(stages[0], vec![1u8; 8]);
    }
}
