//! Retention policy + garbage collection for the durable tier.
//!
//! Two knobs compose (union of the two keep-sets):
//! * **keep-last-K** — the newest K manifests always survive (K floors at 1:
//!   the latest durable checkpoint is never collected);
//! * **keep-every-Nth** — any step divisible by N survives regardless of
//!   age, giving a sparse long-horizon history (0 disables).
//!
//! Deletion order is crash-consistent with the commit protocol: a dropped
//! version loses its *manifest first* (readers immediately stop resolving
//! it), then its shard blobs; a crash in between just leaves orphans for the
//! next sweep. The sweep also collects shard blobs of steps that never
//! committed a manifest (aborted or crashed persist jobs).

use std::collections::BTreeSet;

use anyhow::Result;

use crate::checkpoint::Storage;

use super::manifest::{self, PersistManifest};

#[derive(Debug, Clone, Copy)]
pub struct RetentionPolicy {
    /// always keep the newest K manifests (values below 1 are treated as 1)
    pub keep_last: usize,
    /// additionally keep every step divisible by N (0 disables)
    pub keep_every: u64,
}

impl RetentionPolicy {
    /// Which of `steps` (ascending) survive this policy.
    pub fn retained(&self, steps: &[u64]) -> BTreeSet<u64> {
        let mut keep: BTreeSet<u64> =
            steps.iter().rev().take(self.keep_last.max(1)).copied().collect();
        if self.keep_every > 0 {
            keep.extend(steps.iter().copied().filter(|s| s % self.keep_every == 0));
        }
        keep
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GcReport {
    pub manifests_deleted: usize,
    pub blobs_deleted: usize,
}

/// Apply the policy to `model`'s durable checkpoints and sweep orphaned
/// shard blobs older than the newest committed manifest. One listing
/// snapshot serves the whole pass — manifest enumeration and the orphan
/// sweep — so the per-commit GC costs a single full scan, not three.
pub fn run_gc(
    storage: &dyn Storage,
    model: &str,
    policy: &RetentionPolicy,
) -> Result<GcReport> {
    let keys = storage.list();
    let prefix = manifest::manifest_prefix(model);
    let mut steps: Vec<u64> = keys
        .iter()
        .filter_map(|k| manifest::step_of_key(k, &prefix))
        .collect();
    steps.sort_unstable();
    steps.dedup();
    let Some(&newest) = steps.last() else {
        return Ok(GcReport::default());
    };
    let keep = policy.retained(&steps);
    let mut report = GcReport::default();
    for &step in &steps {
        if keep.contains(&step) {
            continue;
        }
        let key = manifest::manifest_key(model, step);
        // read the shard list before unlinking the manifest, so the blobs
        // can still be found once the version is no longer resolvable
        let shard_keys: Vec<String> = storage
            .get(&key)
            .ok()
            .and_then(|b| PersistManifest::decode(&b).ok())
            .map(|m| m.shards.into_iter().map(|s| s.key).collect())
            .unwrap_or_default();
        storage.delete(&key)?;
        report.manifests_deleted += 1;
        for k in shard_keys {
            storage.delete(&k)?;
            report.blobs_deleted += 1;
        }
    }
    // orphans = shard steps that never committed a manifest; steps whose
    // manifest was just retired above were handled through its shard list
    let manifested: BTreeSet<u64> = steps.iter().copied().collect();
    report.blobs_deleted +=
        manifest::sweep_orphans_in(storage, model, &manifested, newest, &keys);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keep_last_floors_at_one() {
        let p = RetentionPolicy { keep_last: 0, keep_every: 0 };
        let kept = p.retained(&[5, 10, 15]);
        assert_eq!(kept.into_iter().collect::<Vec<_>>(), vec![15]);
    }

    #[test]
    fn keep_last_takes_newest() {
        let p = RetentionPolicy { keep_last: 2, keep_every: 0 };
        let kept = p.retained(&[5, 10, 15, 20]);
        assert_eq!(kept.into_iter().collect::<Vec<_>>(), vec![15, 20]);
    }

    #[test]
    fn keep_every_unions_with_keep_last() {
        let p = RetentionPolicy { keep_last: 2, keep_every: 10 };
        let kept = p.retained(&[5, 10, 15, 20, 25]);
        // newest two (20, 25) plus every multiple of 10 (10, 20)
        assert_eq!(kept.into_iter().collect::<Vec<_>>(), vec![10, 20, 25]);
    }

    #[test]
    fn fewer_steps_than_keep_last_keeps_all() {
        let p = RetentionPolicy { keep_last: 8, keep_every: 0 };
        let kept = p.retained(&[3, 6]);
        assert_eq!(kept.into_iter().collect::<Vec<_>>(), vec![3, 6]);
    }
}
