//! Reliability-driven persist cadence (paper Appendix A, live): instead of
//! the static `persist_every` knob, feed the *measured* durable-save cost
//! and per-iteration compute into the Eq. 9–11 interval math and let the
//! trainer re-derive its cadence as the run's costs drift.
//!
//! With an SG of n >= 2 the REFT form applies
//! ([`reft_ckpt_interval`], Eq. 11): the expensive durable save amortizes
//! against the *exceedance* rate (>= 2 nodes lost in the SG, Eq. 7), which
//! is why the cadence stretches by orders of magnitude once in-memory
//! protection exists. A single-node SG has no RAIM5 peers — any node loss
//! needs the durable tier — so the plain Young interval
//! ([`optimal_interval`], Eq. 5) against the raw node rate applies instead.
//!
//! **Live failure rate.** The per-node rate λ_node starts as the static
//! `lambda_node` knob, but the scheduler also ingests *observed* failure
//! events — from the trainers' failure injection or straight from a
//! pre-drawn hwsim Weibull schedule
//! ([`IntervalScheduler::ingest_failure_schedule`]; feed ONE clock domain
//! per scheduler — wall or sim, never both). Once enough events accrue, the rolling
//! empirical rate (exponential-interarrival MLE over the event window,
//! normalized per node) replaces the knob, so the cadence tracks the
//! cluster the run actually sees rather than the rate the operator guessed.

use std::collections::VecDeque;
use std::time::Instant;

use crate::hwsim::failure::FailureSchedule;
use crate::reliability::intervals::{
    optimal_interval, reft_ckpt_interval, reft_sn_interval, save_overhead,
};

/// Minimum observed failure events before the rolling empirical rate
/// replaces the static `lambda_node` knob.
pub const MIN_EMPIRICAL_EVENTS: usize = 4;

/// Rolling window of remembered event times (cluster-wide). Old events age
/// out, so a burst years of sim-time ago cannot dominate the rate forever.
const EMPIRICAL_WINDOW: usize = 64;

/// The rolling empirical per-node failure rate, shared by every cadence
/// scheduler in the control plane: a knob until enough observed events
/// accrue, then the exponential-interarrival MLE over the event window.
/// Feed ONE clock domain per tracker — wall or sim, never both.
#[derive(Debug, Clone)]
pub struct LambdaTracker {
    /// static per-node failure rate (per second) — the operator's knob,
    /// used until enough live events accrue
    knob: f64,
    /// cluster size the empirical rate normalizes over
    nodes: usize,
    /// observed failure-event times (seconds on the feeding clock),
    /// ascending, capped at [`EMPIRICAL_WINDOW`]
    events: VecDeque<f64>,
}

impl LambdaTracker {
    pub fn new(knob: f64, nodes: usize) -> LambdaTracker {
        LambdaTracker { knob, nodes: nodes.max(1), events: VecDeque::new() }
    }

    /// One observed failure event at `at_secs` on the feeding clock (any
    /// node; the rate is normalized by the cluster size). Slightly
    /// out-of-order deliveries are tolerated — the window is re-sorted so
    /// the span math stays honest.
    pub fn note_event(&mut self, at_secs: f64) {
        if !at_secs.is_finite() {
            return;
        }
        let out_of_order =
            self.events.back().is_some_and(|&last| last > at_secs);
        self.events.push_back(at_secs);
        if out_of_order {
            let mut v: Vec<f64> = self.events.drain(..).collect();
            v.sort_by(f64::total_cmp);
            self.events = v.into();
        }
        while self.events.len() > EMPIRICAL_WINDOW {
            self.events.pop_front();
        }
    }

    /// Bulk-feed a pre-drawn hwsim Weibull schedule: every event in
    /// `(since, upto]` is ingested.
    pub fn ingest_schedule(&mut self, schedule: &FailureSchedule, since: f64, upto: f64) {
        for e in schedule.in_window(since, upto) {
            self.note_event(e.at);
        }
    }

    /// How many live failure events the rolling window currently holds.
    pub fn events(&self) -> usize {
        self.events.len()
    }

    /// The rolling empirical rate, available only once
    /// [`MIN_EMPIRICAL_EVENTS`] events accrued (k events spanning `t`
    /// seconds across `nodes` nodes → the exponential-interarrival MLE
    /// `(k-1) / (t * nodes)`).
    pub fn empirical(&self) -> Option<f64> {
        let k = self.events.len();
        if k >= MIN_EMPIRICAL_EVENTS {
            let span = self.events.back().unwrap() - self.events.front().unwrap();
            if span > 0.0 {
                return Some((k - 1) as f64 / (span * self.nodes as f64));
            }
        }
        None
    }

    /// The rate driving interval math: the empirical rate when available,
    /// else the knob.
    pub fn lambda(&self) -> f64 {
        self.empirical().unwrap_or(self.knob)
    }
}

/// Live persist-cadence controller. Owned by the trainer; all methods run
/// on the training thread and are O(1) (event ingestion amortized).
#[derive(Debug, Clone)]
pub struct IntervalScheduler {
    lambda: LambdaTracker,
    /// sharding-group size n (Eq. 7 exceedance input)
    sg_size: usize,
    /// clamp bounds on the derived cadence, in steps
    min_steps: u64,
    max_steps: u64,
    interval_steps: u64,
    last_persist_step: u64,
}

impl IntervalScheduler {
    /// `fallback_steps` seeds the cadence until the first measurement
    /// arrives (the trainers pass the static
    /// `persist_every * snapshot_interval` product). `nodes` is the
    /// cluster size the empirical failure rate normalizes over.
    pub fn new(
        lambda_node: f64,
        sg_size: usize,
        nodes: usize,
        fallback_steps: u64,
    ) -> IntervalScheduler {
        IntervalScheduler {
            lambda: LambdaTracker::new(lambda_node, nodes),
            sg_size,
            min_steps: 1,
            max_steps: 1_000_000,
            interval_steps: fallback_steps.max(1),
            last_persist_step: 0,
        }
    }

    /// Current cadence in steps.
    pub fn interval_steps(&self) -> u64 {
        self.interval_steps
    }

    /// One observed failure event (see [`LambdaTracker::note_event`]).
    pub fn note_failure_event(&mut self, at_secs: f64) {
        self.lambda.note_event(at_secs);
    }

    /// Bulk-feed a pre-drawn hwsim Weibull schedule: every event in
    /// `(since, upto]` is ingested. Callers advancing a sim clock pass the
    /// previous and current time so each event is fed exactly once.
    pub fn ingest_failure_schedule(
        &mut self,
        schedule: &FailureSchedule,
        since: f64,
        upto: f64,
    ) {
        self.lambda.ingest_schedule(schedule, since, upto);
    }

    /// How many live failure events the rolling window currently holds.
    pub fn empirical_events(&self) -> usize {
        self.lambda.events()
    }

    /// The per-node failure rate driving the interval math: the rolling
    /// empirical rate once enough events accrued, else the static knob.
    pub fn lambda_node(&self) -> f64 {
        self.lambda.lambda()
    }

    /// Re-derive the cadence from measurements: `t_persist` is the wall
    /// cost of one durable save (with the background engine this is the
    /// *job* duration — the Eq. 8 overlap term absorbs everything the
    /// training thread doesn't see), `t_step` one training iteration.
    /// Returns the new interval in steps.
    pub fn observe(&mut self, t_persist: f64, t_step: f64) -> u64 {
        let lambda = self.lambda_node();
        if t_step > 0.0 && t_persist >= 0.0 && lambda > 0.0 {
            let t_secs = if self.sg_size >= 2 {
                reft_ckpt_interval(t_persist, t_step, lambda, self.sg_size)
            } else {
                // no RAIM5 peers: any node loss already needs the durable
                // tier, so the raw node rate drives the plain Eq. 5 form
                optimal_interval(
                    save_overhead(t_persist, t_step).max(1e-6),
                    lambda,
                )
            };
            self.interval_steps = if t_secs.is_finite() {
                ((t_secs / t_step).ceil() as u64).clamp(self.min_steps, self.max_steps)
            } else {
                self.max_steps
            };
        }
        self.interval_steps
    }

    /// Cadence gate, called every step on the training thread. Marks the
    /// step as persisted when it fires. Self-healing under step rollback:
    /// a recovery that restores an older checkpoint re-runs steps the gate
    /// already marked, so a `last` ahead of the current step is clamped
    /// back — otherwise the durable tier would go silent for the whole
    /// re-done window plus one interval, exactly when a second failure is
    /// most costly.
    pub fn should_persist(&mut self, step: u64) -> bool {
        if self.last_persist_step > step {
            self.last_persist_step = step;
        }
        if step.saturating_sub(self.last_persist_step) >= self.interval_steps {
            self.last_persist_step = step;
            true
        } else {
            false
        }
    }
}

/// Live *snapshot*-cadence controller (Eq. 9): the in-memory save interval
/// derived from the measured snapshot cost and the rolling empirical λ —
/// the second leg of the adaptive control plane, next to the persist-side
/// [`IntervalScheduler`] (Eq. 11).
///
/// Deliberately more conservative than the persist scheduler about its
/// failure-rate input: below the empirical event floor it holds the
/// operator's **static snapshot interval** rather than deriving a cadence
/// from the `lambda_node` knob — that knob was tuned for the durable tier's
/// once-in-a-run exceedance math, and silently repurposing it here could
/// swing the snapshot frequency by orders of magnitude on a guess. Only
/// once the run has *observed* enough failures does Eq. 9 take over.
#[derive(Debug, Clone)]
pub struct SnapshotScheduler {
    lambda: LambdaTracker,
    /// the operator's `snapshot_interval` knob, held below the event floor
    static_steps: u64,
    min_steps: u64,
    max_steps: u64,
    interval_steps: u64,
    last_snapshot_step: u64,
    /// the wall clock [`SnapshotScheduler::note_failure`] stamps against
    /// (sim-driven harnesses feed [`SnapshotScheduler::note_failure_event`]
    /// directly instead — one clock domain per scheduler)
    t0: Instant,
}

impl SnapshotScheduler {
    pub fn new(lambda_node: f64, nodes: usize, static_steps: u64) -> SnapshotScheduler {
        SnapshotScheduler {
            lambda: LambdaTracker::new(lambda_node, nodes),
            static_steps: static_steps.max(1),
            min_steps: 1,
            max_steps: 1_000_000,
            interval_steps: static_steps.max(1),
            last_snapshot_step: 0,
            t0: Instant::now(),
        }
    }

    /// Current cadence in steps (never zero).
    pub fn interval_steps(&self) -> u64 {
        self.interval_steps
    }

    /// One observed node failure, stamped on this scheduler's wall clock.
    pub fn note_failure(&mut self) {
        let at = self.t0.elapsed().as_secs_f64();
        self.lambda.note_event(at);
    }

    /// One observed failure event on an external (e.g. sim) clock.
    pub fn note_failure_event(&mut self, at_secs: f64) {
        self.lambda.note_event(at_secs);
    }

    /// Bulk-feed a pre-drawn hwsim Weibull schedule (sim clock).
    pub fn ingest_failure_schedule(
        &mut self,
        schedule: &FailureSchedule,
        since: f64,
        upto: f64,
    ) {
        self.lambda.ingest_schedule(schedule, since, upto);
    }

    pub fn empirical_events(&self) -> usize {
        self.lambda.events()
    }

    pub fn lambda_node(&self) -> f64 {
        self.lambda.lambda()
    }

    /// Re-derive the snapshot cadence from measurements: `t_snapshot` is
    /// the per-round snapshot cost the training thread actually pays
    /// (blocking round duration, or enqueue + amortized drain-tick time on
    /// the async path), `t_step` one training iteration. Below the
    /// empirical event floor this degrades to the static interval; above
    /// it, Eq. 9 against the observed node rate. Never returns zero.
    pub fn observe(&mut self, t_snapshot: f64, t_step: f64) -> u64 {
        match self.lambda.empirical() {
            Some(lam) if t_step > 0.0 && t_snapshot >= 0.0 && lam > 0.0 => {
                let t_secs = reft_sn_interval(t_snapshot, t_step, lam);
                self.interval_steps = if t_secs.is_finite() {
                    ((t_secs / t_step).ceil() as u64).clamp(self.min_steps, self.max_steps)
                } else {
                    self.max_steps
                };
            }
            _ => self.interval_steps = self.static_steps,
        }
        self.interval_steps
    }

    /// Cadence gate, called every step on the training thread. Marks the
    /// step as snapshotted when it fires. Clamped under step rollback like
    /// [`IntervalScheduler::should_persist`]: a recovery that rewinds the
    /// step must not leave the fabric unprotected for the re-done window.
    pub fn due(&mut self, step: u64) -> bool {
        if self.last_snapshot_step > step {
            self.last_snapshot_step = step;
        }
        if step.saturating_sub(self.last_snapshot_step) >= self.interval_steps {
            self.last_snapshot_step = step;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::failure::{FailureKind, FailureModel};
    use crate::util::rng::Rng;

    #[test]
    fn fallback_cadence_until_first_measurement() {
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 20);
        assert_eq!(s.interval_steps(), 20);
        assert!(!s.should_persist(10));
        assert!(s.should_persist(20));
        assert!(!s.should_persist(25));
        assert!(s.should_persist(40));
    }

    #[test]
    fn costlier_saves_stretch_the_interval() {
        let mut cheap = IntervalScheduler::new(1e-4, 6, 6, 10);
        let mut dear = IntervalScheduler::new(1e-4, 6, 6, 10);
        let a = cheap.observe(2.0, 1.0);
        let b = dear.observe(20.0, 1.0);
        assert!(b > a, "amortize expensive saves over longer intervals: {a} vs {b}");
    }

    #[test]
    fn reft_exceedance_stretches_vs_single_node_sg() {
        // same costs, same node rate: the SG-of-6 cadence must be far
        // sparser than the unprotected single-node one (Eq. 7 quadratic)
        let mut protected = IntervalScheduler::new(1e-4, 6, 6, 10);
        let mut bare = IntervalScheduler::new(1e-4, 1, 6, 10);
        let p = protected.observe(5.0, 1.0);
        let b = bare.observe(5.0, 1.0);
        assert!(p > b * 10, "protected {p} vs bare {b}");
    }

    #[test]
    fn fully_overlapped_save_caps_at_max() {
        // background engine: trainer-visible cost ~ 0 -> overhead clamps to
        // epsilon and the interval hits the ceiling rather than NaN/0
        let mut s = IntervalScheduler::new(1e-6, 6, 6, 10);
        let steps = s.observe(0.0, 1.0);
        assert!(steps >= 10, "{steps}");
        assert!(steps <= 1_000_000);
    }

    #[test]
    fn zero_step_time_keeps_previous_cadence() {
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 15);
        assert_eq!(s.observe(1.0, 0.0), 15);
    }

    #[test]
    fn cadence_tracks_interval_after_observe() {
        let mut s = IntervalScheduler::new(1e-1, 2, 6, 100);
        // high failure rate + expensive save -> short finite interval
        let steps = s.observe(50.0, 1.0);
        assert!(steps >= 1);
        assert!(s.should_persist(steps));
        assert!(!s.should_persist(steps + 1));
        assert!(s.should_persist(steps * 2));
    }

    #[test]
    fn knob_rate_until_enough_events_accrue() {
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 10);
        assert_eq!(s.lambda_node(), 1e-4);
        // three events: still below MIN_EMPIRICAL_EVENTS
        for t in [100.0, 200.0, 300.0] {
            s.note_failure_event(t);
        }
        assert_eq!(s.empirical_events(), 3);
        assert_eq!(s.lambda_node(), 1e-4, "knob holds below the event floor");
        // the fourth event flips to the empirical rate:
        // 3 renewals over 300 s across 6 nodes = 3 / 1800
        s.note_failure_event(400.0);
        let lam = s.lambda_node();
        assert!((lam - 3.0 / (300.0 * 6.0)).abs() < 1e-12, "{lam}");
    }

    #[test]
    fn hotter_observed_cluster_shortens_the_cadence() {
        // identical knobs; one scheduler observes a failure storm the knob
        // never predicted -> its derived interval must come in shorter
        let mut calm = IntervalScheduler::new(1e-6, 6, 6, 10);
        let mut hot = IntervalScheduler::new(1e-6, 6, 6, 10);
        for k in 0..16 {
            hot.note_failure_event(10.0 * k as f64); // one failure / 10 s
        }
        let calm_steps = calm.observe(5.0, 1.0);
        let hot_steps = hot.observe(5.0, 1.0);
        assert!(
            hot_steps < calm_steps,
            "live rate must shorten the cadence: {hot_steps} vs {calm_steps}"
        );
    }

    #[test]
    fn out_of_order_events_are_resorted() {
        let mut s = IntervalScheduler::new(1e-4, 6, 2, 10);
        for t in [50.0, 10.0, 30.0, 20.0] {
            s.note_failure_event(t);
        }
        // 3 renewals over the [10, 50] span across 2 nodes
        assert!((s.lambda_node() - 3.0 / (40.0 * 2.0)).abs() < 1e-12);
        // non-finite feeds are dropped, not poisoning the window
        s.note_failure_event(f64::NAN);
        assert_eq!(s.empirical_events(), 4);
    }

    #[test]
    fn snapshot_cadence_holds_static_below_event_floor() {
        let mut s = SnapshotScheduler::new(1e-3, 6, 5);
        assert_eq!(s.interval_steps(), 5);
        // a cost measurement with no observed failures must NOT repurpose
        // the lambda knob — the static interval holds
        assert_eq!(s.observe(0.5, 1.0), 5);
        for t in [10.0, 20.0, 30.0] {
            s.note_failure_event(t);
        }
        assert_eq!(s.observe(0.5, 1.0), 5, "3 events: still below the floor");
        // the fourth event crosses the floor: Eq. 9 takes over
        s.note_failure_event(40.0);
        let derived = s.observe(5.0, 1.0);
        assert!(derived >= 1);
        // 3 renewals / (30 s * 6 nodes) = 1/60 per node-second;
        // o = 4 s -> sqrt(2*4*60) ~ 21.9 s -> 22 steps at 1 s/step
        assert_eq!(derived, 22, "Eq. 9 from the empirical rate");
    }

    #[test]
    fn snapshot_cadence_gate_and_clamps() {
        let mut s = SnapshotScheduler::new(1e-3, 4, 3);
        assert!(!s.due(2));
        assert!(s.due(3));
        assert!(!s.due(4));
        assert!(s.due(6));
        // fully overlapped snapshot above the floor: epsilon overhead, the
        // derived interval still floors at 1, never 0
        for t in [1.0, 2.0, 3.0, 4.0] {
            s.note_failure_event(t);
        }
        let steps = s.observe(0.0, 1.0);
        assert!(steps >= 1, "{steps}");
    }

    #[test]
    fn cadence_gates_self_heal_after_step_rollback() {
        // recovery restored an old checkpoint: the trainer's step rewinds
        // below the gate's high-water mark. The gate must clamp and keep
        // its periodic cadence through the re-done window, not go silent
        // for (rollback distance + interval) steps.
        let mut p = IntervalScheduler::new(1e-4, 6, 6, 10);
        assert!(p.should_persist(100));
        assert!(!p.should_persist(21), "clamped to 21, interval not yet elapsed");
        assert!(p.should_persist(31), "cadence resumes from the rolled-back step");
        let mut s = SnapshotScheduler::new(1e-3, 6, 5);
        assert!(s.due(50));
        assert!(!s.due(8));
        assert!(s.due(13), "snapshot cadence resumes inside the re-done window");
    }

    #[test]
    fn snapshot_cadence_shortens_under_observed_failure_storm() {
        // identical schedulers; one sees a storm -> its Eq. 9 interval must
        // come in at or below the calm one's static fallback
        let mut calm = SnapshotScheduler::new(1e-6, 6, 50);
        let mut hot = SnapshotScheduler::new(1e-6, 6, 50);
        for k in 0..16 {
            hot.note_failure_event(5.0 * k as f64);
        }
        let calm_steps = calm.observe(2.0, 1.0); // static: below floor
        let hot_steps = hot.observe(2.0, 1.0);
        assert_eq!(calm_steps, 50);
        assert!(hot_steps < calm_steps, "{hot_steps} vs {calm_steps}");
    }

    #[test]
    fn ingests_hwsim_weibull_schedule_incrementally() {
        let model = FailureModel::new(0.01, 0.0, 1.0);
        let mut rng = Rng::seed_from(7);
        let sched = model.schedule(&mut rng, 8, 2000.0);
        assert!(sched.events.iter().all(|e| e.kind == FailureKind::Hardware));
        let mut s = IntervalScheduler::new(1e-9, 6, 8, 10);
        // two half-open windows feed each event exactly once
        s.ingest_failure_schedule(&sched, f64::NEG_INFINITY, 1000.0);
        let first = s.empirical_events();
        s.ingest_failure_schedule(&sched, 1000.0, 2000.0);
        let total = s.empirical_events();
        assert!(total >= first);
        let in_horizon = sched.events.len().min(64);
        assert_eq!(total, in_horizon, "window cap or exact count");
        // with ~0.01/node/unit observed, the empirical rate is near the
        // generating rate and far above the 1e-9 knob
        let lam = s.lambda_node();
        assert!(lam > 1e-3 && lam < 1e-1, "{lam}");
    }
}
