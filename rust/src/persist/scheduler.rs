//! Reliability-driven persist cadence (paper Appendix A, live): instead of
//! the static `persist_every` knob, feed the *measured* durable-save cost
//! and per-iteration compute into the Eq. 9–11 interval math and let the
//! trainer re-derive its cadence as the run's costs drift.
//!
//! With an SG of n >= 2 the REFT form applies
//! ([`reft_ckpt_interval`], Eq. 11): the expensive durable save amortizes
//! against the *exceedance* rate (>= 2 nodes lost in the SG, Eq. 7), which
//! is why the cadence stretches by orders of magnitude once in-memory
//! protection exists. A single-node SG has no RAIM5 peers — any node loss
//! needs the durable tier — so the plain Young interval
//! ([`optimal_interval`], Eq. 5) against the raw node rate applies instead.

use crate::reliability::intervals::{optimal_interval, reft_ckpt_interval, save_overhead};

/// Live persist-cadence controller. Owned by the trainer; all methods run
/// on the training thread and are O(1).
#[derive(Debug, Clone)]
pub struct IntervalScheduler {
    /// per-node failure rate (per second — the hwsim λ_node)
    lambda_node: f64,
    /// sharding-group size n (Eq. 7 exceedance input)
    sg_size: usize,
    /// clamp bounds on the derived cadence, in steps
    min_steps: u64,
    max_steps: u64,
    interval_steps: u64,
    last_persist_step: u64,
}

impl IntervalScheduler {
    /// `fallback_steps` seeds the cadence until the first measurement
    /// arrives (the trainers pass the static
    /// `persist_every * snapshot_interval` product).
    pub fn new(lambda_node: f64, sg_size: usize, fallback_steps: u64) -> IntervalScheduler {
        IntervalScheduler {
            lambda_node,
            sg_size,
            min_steps: 1,
            max_steps: 1_000_000,
            interval_steps: fallback_steps.max(1),
            last_persist_step: 0,
        }
    }

    /// Current cadence in steps.
    pub fn interval_steps(&self) -> u64 {
        self.interval_steps
    }

    /// Re-derive the cadence from measurements: `t_persist` is the wall
    /// cost of one durable save (with the background engine this is the
    /// *job* duration — the Eq. 8 overlap term absorbs everything the
    /// training thread doesn't see), `t_step` one training iteration.
    /// Returns the new interval in steps.
    pub fn observe(&mut self, t_persist: f64, t_step: f64) -> u64 {
        if t_step > 0.0 && t_persist >= 0.0 && self.lambda_node > 0.0 {
            let t_secs = if self.sg_size >= 2 {
                reft_ckpt_interval(t_persist, t_step, self.lambda_node, self.sg_size)
            } else {
                // no RAIM5 peers: any node loss already needs the durable
                // tier, so the raw node rate drives the plain Eq. 5 form
                optimal_interval(
                    save_overhead(t_persist, t_step).max(1e-6),
                    self.lambda_node,
                )
            };
            self.interval_steps = if t_secs.is_finite() {
                ((t_secs / t_step).ceil() as u64).clamp(self.min_steps, self.max_steps)
            } else {
                self.max_steps
            };
        }
        self.interval_steps
    }

    /// Cadence gate, called at each snapshot boundary on the training
    /// thread. Marks the step as persisted when it fires.
    pub fn should_persist(&mut self, step: u64) -> bool {
        if step.saturating_sub(self.last_persist_step) >= self.interval_steps {
            self.last_persist_step = step;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_cadence_until_first_measurement() {
        let mut s = IntervalScheduler::new(1e-4, 6, 20);
        assert_eq!(s.interval_steps(), 20);
        assert!(!s.should_persist(10));
        assert!(s.should_persist(20));
        assert!(!s.should_persist(25));
        assert!(s.should_persist(40));
    }

    #[test]
    fn costlier_saves_stretch_the_interval() {
        let mut cheap = IntervalScheduler::new(1e-4, 6, 10);
        let mut dear = IntervalScheduler::new(1e-4, 6, 10);
        let a = cheap.observe(2.0, 1.0);
        let b = dear.observe(20.0, 1.0);
        assert!(b > a, "amortize expensive saves over longer intervals: {a} vs {b}");
    }

    #[test]
    fn reft_exceedance_stretches_vs_single_node_sg() {
        // same costs, same node rate: the SG-of-6 cadence must be far
        // sparser than the unprotected single-node one (Eq. 7 quadratic)
        let mut protected = IntervalScheduler::new(1e-4, 6, 10);
        let mut bare = IntervalScheduler::new(1e-4, 1, 10);
        let p = protected.observe(5.0, 1.0);
        let b = bare.observe(5.0, 1.0);
        assert!(p > b * 10, "protected {p} vs bare {b}");
    }

    #[test]
    fn fully_overlapped_save_caps_at_max() {
        // background engine: trainer-visible cost ~ 0 -> overhead clamps to
        // epsilon and the interval hits the ceiling rather than NaN/0
        let mut s = IntervalScheduler::new(1e-6, 6, 10);
        let steps = s.observe(0.0, 1.0);
        assert!(steps >= 10, "{steps}");
        assert!(steps <= 1_000_000);
    }

    #[test]
    fn zero_step_time_keeps_previous_cadence() {
        let mut s = IntervalScheduler::new(1e-4, 6, 15);
        assert_eq!(s.observe(1.0, 0.0), 15);
    }

    #[test]
    fn cadence_tracks_interval_after_observe() {
        let mut s = IntervalScheduler::new(1e-1, 2, 100);
        // high failure rate + expensive save -> short finite interval
        let steps = s.observe(50.0, 1.0);
        assert!(steps >= 1);
        assert!(s.should_persist(steps));
        assert!(!s.should_persist(steps + 1));
        assert!(s.should_persist(steps * 2));
    }
}
