//! Reliability-driven persist cadence (paper Appendix A, live): instead of
//! the static `persist_every` knob, feed the *measured* durable-save cost
//! and per-iteration compute into the Eq. 9–11 interval math and let the
//! trainer re-derive its cadence as the run's costs drift.
//!
//! With an SG of n >= 2 the REFT form applies
//! ([`reft_ckpt_interval`], Eq. 11): the expensive durable save amortizes
//! against the *exceedance* rate (>= 2 nodes lost in the SG, Eq. 7), which
//! is why the cadence stretches by orders of magnitude once in-memory
//! protection exists. A single-node SG has no RAIM5 peers — any node loss
//! needs the durable tier — so the plain Young interval
//! ([`optimal_interval`], Eq. 5) against the raw node rate applies instead.
//!
//! **Live failure rate.** The per-node rate λ_node starts as the static
//! `lambda_node` knob, but the scheduler also ingests *observed* failure
//! events — from the trainers' failure injection or straight from a
//! pre-drawn hwsim Weibull schedule
//! ([`IntervalScheduler::ingest_failure_schedule`]; feed ONE clock domain
//! per scheduler — wall or sim, never both). Once enough events accrue, the rolling
//! empirical rate (exponential-interarrival MLE over the event window,
//! normalized per node) replaces the knob, so the cadence tracks the
//! cluster the run actually sees rather than the rate the operator guessed.

use std::collections::VecDeque;

use crate::hwsim::failure::FailureSchedule;
use crate::reliability::intervals::{optimal_interval, reft_ckpt_interval, save_overhead};

/// Minimum observed failure events before the rolling empirical rate
/// replaces the static `lambda_node` knob.
pub const MIN_EMPIRICAL_EVENTS: usize = 4;

/// Rolling window of remembered event times (cluster-wide). Old events age
/// out, so a burst years of sim-time ago cannot dominate the rate forever.
const EMPIRICAL_WINDOW: usize = 64;

/// Live persist-cadence controller. Owned by the trainer; all methods run
/// on the training thread and are O(1) (event ingestion amortized).
#[derive(Debug, Clone)]
pub struct IntervalScheduler {
    /// static per-node failure rate (per second) — the operator's knob,
    /// used until enough live events accrue
    lambda_knob: f64,
    /// sharding-group size n (Eq. 7 exceedance input)
    sg_size: usize,
    /// cluster size the empirical rate normalizes over
    nodes: usize,
    /// observed failure-event times (seconds on the feeding clock),
    /// ascending, capped at [`EMPIRICAL_WINDOW`]
    events: VecDeque<f64>,
    /// clamp bounds on the derived cadence, in steps
    min_steps: u64,
    max_steps: u64,
    interval_steps: u64,
    last_persist_step: u64,
}

impl IntervalScheduler {
    /// `fallback_steps` seeds the cadence until the first measurement
    /// arrives (the trainers pass the static
    /// `persist_every * snapshot_interval` product). `nodes` is the
    /// cluster size the empirical failure rate normalizes over.
    pub fn new(
        lambda_node: f64,
        sg_size: usize,
        nodes: usize,
        fallback_steps: u64,
    ) -> IntervalScheduler {
        IntervalScheduler {
            lambda_knob: lambda_node,
            sg_size,
            nodes: nodes.max(1),
            events: VecDeque::new(),
            min_steps: 1,
            max_steps: 1_000_000,
            interval_steps: fallback_steps.max(1),
            last_persist_step: 0,
        }
    }

    /// Current cadence in steps.
    pub fn interval_steps(&self) -> u64 {
        self.interval_steps
    }

    /// One observed failure event at `at_secs` on the feeding clock (any
    /// node; the rate is normalized by the cluster size). Slightly
    /// out-of-order deliveries are tolerated — the window is re-sorted so
    /// the span math stays honest.
    pub fn note_failure_event(&mut self, at_secs: f64) {
        if !at_secs.is_finite() {
            return;
        }
        let out_of_order =
            self.events.back().is_some_and(|&last| last > at_secs);
        self.events.push_back(at_secs);
        if out_of_order {
            let mut v: Vec<f64> = self.events.drain(..).collect();
            v.sort_by(f64::total_cmp);
            self.events = v.into();
        }
        while self.events.len() > EMPIRICAL_WINDOW {
            self.events.pop_front();
        }
    }

    /// Bulk-feed a pre-drawn hwsim Weibull schedule: every event in
    /// `(since, upto]` is ingested. Callers advancing a sim clock pass the
    /// previous and current time so each event is fed exactly once.
    pub fn ingest_failure_schedule(
        &mut self,
        schedule: &FailureSchedule,
        since: f64,
        upto: f64,
    ) {
        for e in schedule.in_window(since, upto) {
            self.note_failure_event(e.at);
        }
    }

    /// How many live failure events the rolling window currently holds.
    pub fn empirical_events(&self) -> usize {
        self.events.len()
    }

    /// The per-node failure rate driving the interval math: the rolling
    /// empirical rate once [`MIN_EMPIRICAL_EVENTS`] events accrued
    /// (k events spanning `t` seconds across `nodes` nodes → the
    /// exponential-interarrival MLE `(k-1) / (t * nodes)`), else the
    /// static knob.
    pub fn lambda_node(&self) -> f64 {
        let k = self.events.len();
        if k >= MIN_EMPIRICAL_EVENTS {
            let span = self.events.back().unwrap() - self.events.front().unwrap();
            if span > 0.0 {
                return (k - 1) as f64 / (span * self.nodes as f64);
            }
        }
        self.lambda_knob
    }

    /// Re-derive the cadence from measurements: `t_persist` is the wall
    /// cost of one durable save (with the background engine this is the
    /// *job* duration — the Eq. 8 overlap term absorbs everything the
    /// training thread doesn't see), `t_step` one training iteration.
    /// Returns the new interval in steps.
    pub fn observe(&mut self, t_persist: f64, t_step: f64) -> u64 {
        let lambda = self.lambda_node();
        if t_step > 0.0 && t_persist >= 0.0 && lambda > 0.0 {
            let t_secs = if self.sg_size >= 2 {
                reft_ckpt_interval(t_persist, t_step, lambda, self.sg_size)
            } else {
                // no RAIM5 peers: any node loss already needs the durable
                // tier, so the raw node rate drives the plain Eq. 5 form
                optimal_interval(
                    save_overhead(t_persist, t_step).max(1e-6),
                    lambda,
                )
            };
            self.interval_steps = if t_secs.is_finite() {
                ((t_secs / t_step).ceil() as u64).clamp(self.min_steps, self.max_steps)
            } else {
                self.max_steps
            };
        }
        self.interval_steps
    }

    /// Cadence gate, called at each snapshot boundary on the training
    /// thread. Marks the step as persisted when it fires.
    pub fn should_persist(&mut self, step: u64) -> bool {
        if step.saturating_sub(self.last_persist_step) >= self.interval_steps {
            self.last_persist_step = step;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::failure::{FailureKind, FailureModel};
    use crate::util::rng::Rng;

    #[test]
    fn fallback_cadence_until_first_measurement() {
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 20);
        assert_eq!(s.interval_steps(), 20);
        assert!(!s.should_persist(10));
        assert!(s.should_persist(20));
        assert!(!s.should_persist(25));
        assert!(s.should_persist(40));
    }

    #[test]
    fn costlier_saves_stretch_the_interval() {
        let mut cheap = IntervalScheduler::new(1e-4, 6, 6, 10);
        let mut dear = IntervalScheduler::new(1e-4, 6, 6, 10);
        let a = cheap.observe(2.0, 1.0);
        let b = dear.observe(20.0, 1.0);
        assert!(b > a, "amortize expensive saves over longer intervals: {a} vs {b}");
    }

    #[test]
    fn reft_exceedance_stretches_vs_single_node_sg() {
        // same costs, same node rate: the SG-of-6 cadence must be far
        // sparser than the unprotected single-node one (Eq. 7 quadratic)
        let mut protected = IntervalScheduler::new(1e-4, 6, 6, 10);
        let mut bare = IntervalScheduler::new(1e-4, 1, 6, 10);
        let p = protected.observe(5.0, 1.0);
        let b = bare.observe(5.0, 1.0);
        assert!(p > b * 10, "protected {p} vs bare {b}");
    }

    #[test]
    fn fully_overlapped_save_caps_at_max() {
        // background engine: trainer-visible cost ~ 0 -> overhead clamps to
        // epsilon and the interval hits the ceiling rather than NaN/0
        let mut s = IntervalScheduler::new(1e-6, 6, 6, 10);
        let steps = s.observe(0.0, 1.0);
        assert!(steps >= 10, "{steps}");
        assert!(steps <= 1_000_000);
    }

    #[test]
    fn zero_step_time_keeps_previous_cadence() {
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 15);
        assert_eq!(s.observe(1.0, 0.0), 15);
    }

    #[test]
    fn cadence_tracks_interval_after_observe() {
        let mut s = IntervalScheduler::new(1e-1, 2, 6, 100);
        // high failure rate + expensive save -> short finite interval
        let steps = s.observe(50.0, 1.0);
        assert!(steps >= 1);
        assert!(s.should_persist(steps));
        assert!(!s.should_persist(steps + 1));
        assert!(s.should_persist(steps * 2));
    }

    #[test]
    fn knob_rate_until_enough_events_accrue() {
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 10);
        assert_eq!(s.lambda_node(), 1e-4);
        // three events: still below MIN_EMPIRICAL_EVENTS
        for t in [100.0, 200.0, 300.0] {
            s.note_failure_event(t);
        }
        assert_eq!(s.empirical_events(), 3);
        assert_eq!(s.lambda_node(), 1e-4, "knob holds below the event floor");
        // the fourth event flips to the empirical rate:
        // 3 renewals over 300 s across 6 nodes = 3 / 1800
        s.note_failure_event(400.0);
        let lam = s.lambda_node();
        assert!((lam - 3.0 / (300.0 * 6.0)).abs() < 1e-12, "{lam}");
    }

    #[test]
    fn hotter_observed_cluster_shortens_the_cadence() {
        // identical knobs; one scheduler observes a failure storm the knob
        // never predicted -> its derived interval must come in shorter
        let mut calm = IntervalScheduler::new(1e-6, 6, 6, 10);
        let mut hot = IntervalScheduler::new(1e-6, 6, 6, 10);
        for k in 0..16 {
            hot.note_failure_event(10.0 * k as f64); // one failure / 10 s
        }
        let calm_steps = calm.observe(5.0, 1.0);
        let hot_steps = hot.observe(5.0, 1.0);
        assert!(
            hot_steps < calm_steps,
            "live rate must shorten the cadence: {hot_steps} vs {calm_steps}"
        );
    }

    #[test]
    fn out_of_order_events_are_resorted() {
        let mut s = IntervalScheduler::new(1e-4, 6, 2, 10);
        for t in [50.0, 10.0, 30.0, 20.0] {
            s.note_failure_event(t);
        }
        // 3 renewals over the [10, 50] span across 2 nodes
        assert!((s.lambda_node() - 3.0 / (40.0 * 2.0)).abs() < 1e-12);
        // non-finite feeds are dropped, not poisoning the window
        s.note_failure_event(f64::NAN);
        assert_eq!(s.empirical_events(), 4);
    }

    #[test]
    fn ingests_hwsim_weibull_schedule_incrementally() {
        let model = FailureModel::new(0.01, 0.0, 1.0);
        let mut rng = Rng::seed_from(7);
        let sched = model.schedule(&mut rng, 8, 2000.0);
        assert!(sched.events.iter().all(|e| e.kind == FailureKind::Hardware));
        let mut s = IntervalScheduler::new(1e-9, 6, 8, 10);
        // two half-open windows feed each event exactly once
        s.ingest_failure_schedule(&sched, f64::NEG_INFINITY, 1000.0);
        let first = s.empirical_events();
        s.ingest_failure_schedule(&sched, 1000.0, 2000.0);
        let total = s.empirical_events();
        assert!(total >= first);
        let in_horizon = sched.events.len().min(64);
        assert_eq!(total, in_horizon, "window cap or exact count");
        // with ~0.01/node/unit observed, the empirical rate is near the
        // generating rate and far above the 1e-9 knob
        let lam = s.lambda_node();
        assert!(lam > 1e-3 && lam < 1e-1, "{lam}");
    }
}
