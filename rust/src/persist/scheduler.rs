//! Reliability-driven persist cadence (paper Appendix A, live): instead of
//! the static `persist_every` knob, feed the *measured* durable-save cost
//! and per-iteration compute into the Eq. 9–11 interval math and let the
//! trainer re-derive its cadence as the run's costs drift.
//!
//! With an SG of n >= 2 the REFT form applies
//! ([`reft_ckpt_interval`], Eq. 11): the expensive durable save amortizes
//! against the *exceedance* rate (>= 2 nodes lost in the SG, Eq. 7), which
//! is why the cadence stretches by orders of magnitude once in-memory
//! protection exists. A single-node SG has no RAIM5 peers — any node loss
//! needs the durable tier — so the plain Young interval
//! ([`optimal_interval`], Eq. 5) against the raw node rate applies instead.
//!
//! **Live failure rate.** The per-node rate λ_node is a conjugate
//! Gamma-prior posterior over the observed failure process, seeded from the
//! static `lambda_node` knob. The knob becomes a Gamma(α₀, β₀) prior with
//! mean α₀/β₀ = knob (α₀ = [`GAMMA_PRIOR_EVENTS`] pseudo-events of mass);
//! observing k failure events over E node-seconds of exposure yields the
//! posterior Gamma(α₀ + k, β₀ + E), whose mean
//!
//! ```text
//!   λ̂ = (α₀ + k) / (β₀ + E)
//! ```
//!
//! is what every cadence consumer reads. At zero events and zero exposure
//! this is *exactly* the knob (no behavior change on the no-failure path);
//! from the first observed event it shades smoothly toward the empirical
//! rate, and as k → ∞ it converges to the exposure MLE k/E — no hard
//! event-count floor. Events arrive from the trainers' failure injection or
//! straight from a pre-drawn hwsim Weibull schedule
//! ([`IntervalScheduler::ingest_failure_schedule`]; feed ONE clock domain
//! per scheduler — wall or sim, never both).
//!
//! **Horizon awareness.** The observation window is `(origin, horizon]` on
//! the feeding clock. Quiet time advanced past the last event (via
//! [`LambdaTracker::advance`], or the `upto` edge of an ingested schedule
//! window) grows the exposure and decays the posterior — a burst long ago
//! cannot inflate λ forever. A recovery that rewinds training state calls
//! [`LambdaTracker::reset_epoch`]: pre-recovery events belong to a
//! different regime (often the very hardware that was just replaced), so
//! the window is cleared and the posterior returns to the prior.

use std::time::Instant;

use crate::hwsim::failure::FailureSchedule;
use crate::reliability::intervals::{
    optimal_interval, reft_ckpt_interval, reft_sn_interval, save_overhead,
};

/// Pseudo-event mass of the knob-derived Gamma prior: the knob carries the
/// weight of this many observed events (and the matching `α₀ / knob`
/// node-seconds of pseudo-exposure), so the first real event already moves
/// the posterior while a handful of events still cannot swing it to an
/// extreme on a fluke.
pub const GAMMA_PRIOR_EVENTS: f64 = 1.0;

/// The live per-node failure-rate estimate shared by every cadence
/// scheduler in the control plane: a conjugate Gamma posterior whose prior
/// mean is the operator's `lambda_node` knob (module docs have the math).
/// Feed ONE clock domain per tracker — wall or sim, never both; the
/// observation window opens at 0 on that clock (tracker creation time).
#[derive(Debug, Clone)]
pub struct LambdaTracker {
    /// static per-node failure rate (per second) — the operator's knob,
    /// i.e. the prior mean
    knob: f64,
    /// cluster size the exposure normalizes over
    nodes: usize,
    /// Gamma prior shape (pseudo-events) — 0 when the knob is non-positive
    /// (an uninformative prior: the posterior mean is then the pure MLE)
    prior_alpha: f64,
    /// Gamma prior rate (pseudo node-seconds of exposure), α₀ / knob
    prior_beta: f64,
    /// observed failure events in the current epoch (cluster-wide count —
    /// the Poisson likelihood needs only the count and the exposure, so no
    /// per-event memory is kept and the evidence is never capped)
    count: u64,
    /// left edge of the observation window: tracker birth (0 on the
    /// feeding clock) or the last epoch reset
    origin: f64,
    /// right edge of the observation window: the latest event or
    /// explicitly advanced quiet time
    horizon: f64,
}

impl LambdaTracker {
    pub fn new(knob: f64, nodes: usize) -> LambdaTracker {
        let (prior_alpha, prior_beta) = if knob > 0.0 {
            (GAMMA_PRIOR_EVENTS, GAMMA_PRIOR_EVENTS / knob)
        } else {
            (0.0, 0.0)
        };
        LambdaTracker {
            knob,
            nodes: nodes.max(1),
            prior_alpha,
            prior_beta,
            count: 0,
            origin: 0.0,
            horizon: 0.0,
        }
    }

    /// One observed failure event at `at_secs` on the feeding clock (any
    /// node; the exposure is normalized by the cluster size). Out-of-order
    /// deliveries are fine — only the count and the window's right edge
    /// matter. Events stamped before the window's origin (stale deliveries
    /// from a pre-reset epoch) are dropped.
    pub fn note_event(&mut self, at_secs: f64) {
        if !at_secs.is_finite() || at_secs < self.origin {
            return;
        }
        self.count += 1;
        self.horizon = self.horizon.max(at_secs);
    }

    /// Advance the window's right edge to `now_secs` without an event:
    /// quiet time is evidence too, and grows the exposure the posterior
    /// divides by. Never moves the edge backward.
    pub fn advance(&mut self, now_secs: f64) {
        if now_secs.is_finite() {
            self.horizon = self.horizon.max(now_secs);
        }
    }

    /// Open a fresh observation epoch at `now_secs`: the event window is
    /// cleared and the posterior returns to the knob-derived prior.
    /// Recovery calls this — pre-recovery events described hardware that
    /// was just replaced and a regime the restored run no longer sees, so
    /// letting them keep inflating λ after a long quiet stretch would hold
    /// every cadence too tight forever.
    pub fn reset_epoch(&mut self, now_secs: f64) {
        if !now_secs.is_finite() {
            return;
        }
        self.count = 0;
        self.origin = now_secs;
        self.horizon = now_secs;
    }

    /// Bulk-feed a pre-drawn hwsim Weibull schedule: every event in
    /// `(since, upto]` is ingested, and the window's right edge advances to
    /// `upto` — an event-free window is ingested as pure exposure.
    pub fn ingest_schedule(&mut self, schedule: &FailureSchedule, since: f64, upto: f64) {
        for e in schedule.in_window(since, upto) {
            self.note_event(e.at);
        }
        self.advance(upto);
    }

    /// How many live failure events the current epoch has observed.
    pub fn events(&self) -> usize {
        self.count as usize
    }

    /// Whether at least one live event informs the posterior — the
    /// criterion [`SnapshotScheduler`] uses to let Eq. 9 take over from the
    /// operator's static snapshot interval.
    pub fn informed(&self) -> bool {
        self.count > 0
    }

    /// Exposure of the current observation window, in node-seconds.
    fn exposure(&self) -> f64 {
        (self.horizon - self.origin).max(0.0) * self.nodes as f64
    }

    /// The window's pure exposure MLE `k / E` (k events over E
    /// node-seconds), available once any event accrued with positive
    /// exposure — the limit the posterior mean converges to, exposed for
    /// diagnostics and tests.
    pub fn empirical(&self) -> Option<f64> {
        let e = self.exposure();
        if self.count >= 1 && e > 0.0 {
            return Some(self.count as f64 / e);
        }
        None
    }

    /// The rate driving interval math: the Gamma-posterior mean
    /// `(α₀ + k) / (β₀ + E)`. Exactly the knob at zero events and zero
    /// exposure; the MLE in the many-events limit.
    pub fn lambda(&self) -> f64 {
        let num = self.prior_alpha + self.count as f64;
        let den = self.prior_beta + self.exposure();
        if den > 0.0 {
            num / den
        } else {
            // knob <= 0 and no exposure yet: degrade to the knob's floor
            self.knob.max(0.0)
        }
    }
}

/// Live persist-cadence controller. Owned by the trainer; all methods run
/// on the training thread and are O(1) (event ingestion amortized).
#[derive(Debug, Clone)]
pub struct IntervalScheduler {
    lambda: LambdaTracker,
    /// sharding-group size n (Eq. 7 exceedance input)
    sg_size: usize,
    /// clamp bounds on the derived cadence, in steps
    min_steps: u64,
    max_steps: u64,
    interval_steps: u64,
    last_persist_step: u64,
}

impl IntervalScheduler {
    /// `fallback_steps` seeds the cadence until the first measurement
    /// arrives (the trainers pass the static
    /// `persist_every * snapshot_interval` product). `nodes` is the
    /// cluster size the empirical failure rate normalizes over.
    pub fn new(
        lambda_node: f64,
        sg_size: usize,
        nodes: usize,
        fallback_steps: u64,
    ) -> IntervalScheduler {
        IntervalScheduler {
            lambda: LambdaTracker::new(lambda_node, nodes),
            sg_size,
            min_steps: 1,
            max_steps: 1_000_000,
            interval_steps: fallback_steps.max(1),
            last_persist_step: 0,
        }
    }

    /// Current cadence in steps.
    pub fn interval_steps(&self) -> u64 {
        self.interval_steps
    }

    /// One observed failure event (see [`LambdaTracker::note_event`]).
    pub fn note_failure_event(&mut self, at_secs: f64) {
        self.lambda.note_event(at_secs);
    }

    /// Bulk-feed a pre-drawn hwsim Weibull schedule: every event in
    /// `(since, upto]` is ingested. Callers advancing a sim clock pass the
    /// previous and current time so each event is fed exactly once.
    pub fn ingest_failure_schedule(
        &mut self,
        schedule: &FailureSchedule,
        since: f64,
        upto: f64,
    ) {
        self.lambda.ingest_schedule(schedule, since, upto);
    }

    /// How many live failure events the rolling window currently holds.
    pub fn empirical_events(&self) -> usize {
        self.lambda.events()
    }

    /// Advance the tracker's quiet-time exposure (see
    /// [`LambdaTracker::advance`]). Sim harnesses call this each tick so a
    /// long failure-free stretch decays the posterior.
    pub fn advance(&mut self, now_secs: f64) {
        self.lambda.advance(now_secs);
    }

    /// Open a fresh observation epoch (see [`LambdaTracker::reset_epoch`]).
    /// Called after a recovery restores training state.
    pub fn reset_epoch(&mut self, now_secs: f64) {
        self.lambda.reset_epoch(now_secs);
    }

    /// The per-node failure rate driving the interval math: the
    /// Gamma-posterior mean — exactly the `lambda_node` knob until the
    /// first event or exposure accrues, shading toward the empirical rate
    /// from the first observed event.
    pub fn lambda_node(&self) -> f64 {
        self.lambda.lambda()
    }

    /// Re-derive the cadence from measurements: `t_persist` is the wall
    /// cost of one durable save (with the background engine this is the
    /// *job* duration — the Eq. 8 overlap term absorbs everything the
    /// training thread doesn't see), `t_step` one training iteration.
    /// Returns the new interval in steps.
    pub fn observe(&mut self, t_persist: f64, t_step: f64) -> u64 {
        let lambda = self.lambda_node();
        if t_step > 0.0 && t_persist >= 0.0 && lambda > 0.0 {
            let t_secs = if self.sg_size >= 2 {
                reft_ckpt_interval(t_persist, t_step, lambda, self.sg_size)
            } else {
                // no RAIM5 peers: any node loss already needs the durable
                // tier, so the raw node rate drives the plain Eq. 5 form
                optimal_interval(
                    save_overhead(t_persist, t_step).max(1e-6),
                    lambda,
                )
            };
            self.interval_steps = if t_secs.is_finite() {
                ((t_secs / t_step).ceil() as u64).clamp(self.min_steps, self.max_steps)
            } else {
                self.max_steps
            };
        }
        self.interval_steps
    }

    /// Cadence gate, called every step on the training thread. Marks the
    /// step as persisted when it fires. Self-healing under step rollback:
    /// a recovery that restores an older checkpoint re-runs steps the gate
    /// already marked, so a `last` ahead of the current step is clamped
    /// back — otherwise the durable tier would go silent for the whole
    /// re-done window plus one interval, exactly when a second failure is
    /// most costly.
    pub fn should_persist(&mut self, step: u64) -> bool {
        if self.last_persist_step > step {
            self.last_persist_step = step;
        }
        if step.saturating_sub(self.last_persist_step) >= self.interval_steps {
            self.last_persist_step = step;
            true
        } else {
            false
        }
    }
}

/// Live *snapshot*-cadence controller (Eq. 9): the in-memory save interval
/// derived from the measured snapshot cost and the rolling empirical λ —
/// the second leg of the adaptive control plane, next to the persist-side
/// [`IntervalScheduler`] (Eq. 11).
///
/// Deliberately more conservative than the persist scheduler about its
/// failure-rate input: with no observed failures it holds the operator's
/// **static snapshot interval** rather than deriving a cadence from the
/// `lambda_node` knob — that knob was tuned for the durable tier's
/// once-in-a-run exceedance math, and silently repurposing it here could
/// swing the snapshot frequency by orders of magnitude on a guess. From
/// the first *observed* failure Eq. 9 takes over, fed the Gamma-posterior
/// mean, so the cadence shades smoothly from the operator's setting toward
/// the empirical rate instead of jumping at a hard event-count floor.
#[derive(Debug, Clone)]
pub struct SnapshotScheduler {
    lambda: LambdaTracker,
    /// the operator's `snapshot_interval` knob, held below the event floor
    static_steps: u64,
    min_steps: u64,
    max_steps: u64,
    interval_steps: u64,
    last_snapshot_step: u64,
    /// the wall clock [`SnapshotScheduler::note_failure`] stamps against
    /// (sim-driven harnesses feed [`SnapshotScheduler::note_failure_event`]
    /// directly instead — one clock domain per scheduler)
    t0: Instant,
}

impl SnapshotScheduler {
    pub fn new(lambda_node: f64, nodes: usize, static_steps: u64) -> SnapshotScheduler {
        SnapshotScheduler {
            lambda: LambdaTracker::new(lambda_node, nodes),
            static_steps: static_steps.max(1),
            min_steps: 1,
            max_steps: 1_000_000,
            interval_steps: static_steps.max(1),
            last_snapshot_step: 0,
            t0: Instant::now(),
        }
    }

    /// Current cadence in steps (never zero).
    pub fn interval_steps(&self) -> u64 {
        self.interval_steps
    }

    /// One observed node failure, stamped on this scheduler's wall clock.
    pub fn note_failure(&mut self) {
        let at = self.t0.elapsed().as_secs_f64();
        self.lambda.note_event(at);
    }

    /// A recovery restored training state: open a fresh observation epoch
    /// on this scheduler's wall clock, dropping pre-recovery events (see
    /// [`LambdaTracker::reset_epoch`]).
    pub fn note_restore(&mut self) {
        let at = self.t0.elapsed().as_secs_f64();
        self.lambda.reset_epoch(at);
    }

    /// Epoch reset on an external (e.g. sim) clock.
    pub fn reset_epoch(&mut self, at_secs: f64) {
        self.lambda.reset_epoch(at_secs);
    }

    /// Advance quiet-time exposure on an external (e.g. sim) clock.
    pub fn advance(&mut self, now_secs: f64) {
        self.lambda.advance(now_secs);
    }

    /// One observed failure event on an external (e.g. sim) clock.
    pub fn note_failure_event(&mut self, at_secs: f64) {
        self.lambda.note_event(at_secs);
    }

    /// Bulk-feed a pre-drawn hwsim Weibull schedule (sim clock).
    pub fn ingest_failure_schedule(
        &mut self,
        schedule: &FailureSchedule,
        since: f64,
        upto: f64,
    ) {
        self.lambda.ingest_schedule(schedule, since, upto);
    }

    pub fn empirical_events(&self) -> usize {
        self.lambda.events()
    }

    pub fn lambda_node(&self) -> f64 {
        self.lambda.lambda()
    }

    /// Re-derive the snapshot cadence from measurements: `t_snapshot` is
    /// the per-round snapshot cost the training thread actually pays
    /// (blocking round duration, or enqueue + amortized drain-tick time on
    /// the async path), `t_step` one training iteration. With no observed
    /// failures this degrades to the static interval; from the first
    /// observed event, Eq. 9 against the Gamma-posterior node rate takes
    /// over. Never returns zero.
    pub fn observe(&mut self, t_snapshot: f64, t_step: f64) -> u64 {
        let lam = self.lambda.lambda();
        if self.lambda.informed() && t_step > 0.0 && t_snapshot >= 0.0 && lam > 0.0 {
            let t_secs = reft_sn_interval(t_snapshot, t_step, lam);
            self.interval_steps = if t_secs.is_finite() {
                ((t_secs / t_step).ceil() as u64).clamp(self.min_steps, self.max_steps)
            } else {
                self.max_steps
            };
        } else {
            self.interval_steps = self.static_steps;
        }
        self.interval_steps
    }

    /// Cadence gate, called every step on the training thread. Marks the
    /// step as snapshotted when it fires. Clamped under step rollback like
    /// [`IntervalScheduler::should_persist`]: a recovery that rewinds the
    /// step must not leave the fabric unprotected for the re-done window.
    pub fn due(&mut self, step: u64) -> bool {
        if self.last_snapshot_step > step {
            self.last_snapshot_step = step;
        }
        if step.saturating_sub(self.last_snapshot_step) >= self.interval_steps {
            self.last_snapshot_step = step;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::failure::{FailureKind, FailureModel};
    use crate::util::rng::Rng;

    #[test]
    fn fallback_cadence_until_first_measurement() {
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 20);
        assert_eq!(s.interval_steps(), 20);
        assert!(!s.should_persist(10));
        assert!(s.should_persist(20));
        assert!(!s.should_persist(25));
        assert!(s.should_persist(40));
    }

    #[test]
    fn costlier_saves_stretch_the_interval() {
        let mut cheap = IntervalScheduler::new(1e-4, 6, 6, 10);
        let mut dear = IntervalScheduler::new(1e-4, 6, 6, 10);
        let a = cheap.observe(2.0, 1.0);
        let b = dear.observe(20.0, 1.0);
        assert!(b > a, "amortize expensive saves over longer intervals: {a} vs {b}");
    }

    #[test]
    fn reft_exceedance_stretches_vs_single_node_sg() {
        // same costs, same node rate: the SG-of-6 cadence must be far
        // sparser than the unprotected single-node one (Eq. 7 quadratic)
        let mut protected = IntervalScheduler::new(1e-4, 6, 6, 10);
        let mut bare = IntervalScheduler::new(1e-4, 1, 6, 10);
        let p = protected.observe(5.0, 1.0);
        let b = bare.observe(5.0, 1.0);
        assert!(p > b * 10, "protected {p} vs bare {b}");
    }

    #[test]
    fn fully_overlapped_save_caps_at_max() {
        // background engine: trainer-visible cost ~ 0 -> overhead clamps to
        // epsilon and the interval hits the ceiling rather than NaN/0
        let mut s = IntervalScheduler::new(1e-6, 6, 6, 10);
        let steps = s.observe(0.0, 1.0);
        assert!(steps >= 10, "{steps}");
        assert!(steps <= 1_000_000);
    }

    #[test]
    fn zero_step_time_keeps_previous_cadence() {
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 15);
        assert_eq!(s.observe(1.0, 0.0), 15);
    }

    #[test]
    fn cadence_tracks_interval_after_observe() {
        let mut s = IntervalScheduler::new(1e-1, 2, 6, 100);
        // high failure rate + expensive save -> short finite interval
        let steps = s.observe(50.0, 1.0);
        assert!(steps >= 1);
        assert!(s.should_persist(steps));
        assert!(!s.should_persist(steps + 1));
        assert!(s.should_persist(steps * 2));
    }

    #[test]
    fn posterior_shades_from_knob_toward_empirical_rate() {
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 10);
        // zero events, zero exposure: EXACTLY the knob (no-failure path)
        assert_eq!(s.lambda_node(), 1e-4);
        // each event moves the posterior monotonically toward the (hotter)
        // empirical rate — no hard event-count floor
        let mut prev = s.lambda_node();
        for t in [100.0, 200.0, 300.0, 400.0] {
            s.note_failure_event(t);
            let lam = s.lambda_node();
            assert!(lam > prev, "event at {t}: {lam} vs {prev}");
            prev = lam;
        }
        assert_eq!(s.empirical_events(), 4);
        // pinned posterior mean: prior Gamma(1, 1/1e-4) + 4 events over
        // 400 s * 6 nodes of exposure -> (1 + 4) / (1e4 + 2400)
        let lam = s.lambda_node();
        assert!((lam - 5.0 / 12_400.0).abs() < 1e-12, "{lam}");
        // the posterior sits strictly between the knob and the window MLE
        let mle = 4.0 / 2400.0;
        assert!(lam > 1e-4 && lam < mle, "{lam} vs mle {mle}");
    }

    #[test]
    fn gamma_posterior_converges_to_mle() {
        // a long run at a steady observed rate: the knob's pseudo-exposure
        // washes out and the posterior mean approaches k / E
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 10);
        let mut t = 0.0;
        let mut last_gap = f64::INFINITY;
        for k in 1..=5000u64 {
            t += 10.0;
            s.note_failure_event(t);
            if k % 1000 == 0 {
                let mle = k as f64 / (t * 6.0);
                let gap = (s.lambda_node() / mle - 1.0).abs();
                assert!(gap < last_gap, "gap must shrink: {gap} vs {last_gap}");
                last_gap = gap;
            }
        }
        let mle = 5000.0 / (50_000.0 * 6.0);
        let lam = s.lambda_node();
        assert!((lam / mle - 1.0).abs() < 0.05, "{lam} vs {mle}");
    }

    #[test]
    fn quiet_exposure_decays_posterior_below_knob() {
        // horizon awareness: a long failure-free stretch is evidence of a
        // LOWER rate than the knob guessed — advancing the window without
        // events must decay the posterior, never hold it pinned
        let mut s = IntervalScheduler::new(1e-3, 6, 6, 10);
        assert_eq!(s.lambda_node(), 1e-3);
        s.advance(10_000.0);
        let lam = s.lambda_node();
        // Gamma(1, 1000) + 0 events over 60k node-s -> 1 / 61_000
        assert!((lam - 1.0 / 61_000.0).abs() < 1e-12, "{lam}");
        assert!(lam < 1e-3);
    }

    #[test]
    fn epoch_reset_on_restore_drops_stale_burst() {
        // regression (horizon-aware window): a pre-recovery burst must not
        // keep inflating λ after the restore opened a new regime
        let mut s = IntervalScheduler::new(1e-4, 6, 6, 10);
        for k in 0..32 {
            s.note_failure_event(10.0 * k as f64);
        }
        assert!(s.lambda_node() > 1e-3, "burst dominates before the reset");
        s.reset_epoch(320.0);
        assert_eq!(s.empirical_events(), 0);
        assert_eq!(s.lambda_node(), 1e-4, "posterior back to the knob prior");
        // stale deliveries stamped before the reset are dropped outright
        s.note_failure_event(200.0);
        assert_eq!(s.empirical_events(), 0);
        // fresh post-reset events count from the new origin
        s.note_failure_event(330.0);
        assert_eq!(s.empirical_events(), 1);
        // exposure is measured from the reset, not from t = 0
        let lam = s.lambda_node();
        assert!((lam - 2.0 / (1e4 + 60.0)).abs() < 1e-12, "{lam}");
    }

    #[test]
    fn hotter_observed_cluster_shortens_the_cadence() {
        // identical knobs; one scheduler observes a failure storm the knob
        // never predicted -> its derived interval must come in shorter
        let mut calm = IntervalScheduler::new(1e-6, 6, 6, 10);
        let mut hot = IntervalScheduler::new(1e-6, 6, 6, 10);
        for k in 0..16 {
            hot.note_failure_event(10.0 * k as f64); // one failure / 10 s
        }
        let calm_steps = calm.observe(5.0, 1.0);
        let hot_steps = hot.observe(5.0, 1.0);
        assert!(
            hot_steps < calm_steps,
            "live rate must shorten the cadence: {hot_steps} vs {calm_steps}"
        );
    }

    #[test]
    fn out_of_order_events_count_once_each() {
        let mut s = IntervalScheduler::new(1e-4, 6, 2, 10);
        for t in [50.0, 10.0, 30.0, 20.0] {
            s.note_failure_event(t);
        }
        // 4 events over the (0, 50] window across 2 nodes: the exposure MLE
        // only needs the count and the window's right edge
        let mle = 4.0 / (50.0 * 2.0);
        let lam = s.lambda_node();
        assert!(lam > 1e-4 && lam < mle, "{lam} between knob and {mle}");
        assert!((lam - 5.0 / (1e4 + 100.0)).abs() < 1e-12, "{lam}");
        // non-finite feeds are dropped, not poisoning the window
        s.note_failure_event(f64::NAN);
        assert_eq!(s.empirical_events(), 4);
    }

    #[test]
    fn snapshot_cadence_holds_static_until_first_event() {
        let mut s = SnapshotScheduler::new(1e-3, 6, 5);
        assert_eq!(s.interval_steps(), 5);
        // a cost measurement with no observed failures must NOT repurpose
        // the lambda knob — the static interval holds (no-failure path)
        assert_eq!(s.observe(0.5, 1.0), 5);
        // the FIRST event hands Eq. 9 the posterior mean: prior
        // Gamma(1, 1000) + 1 event over 10 s * 6 nodes -> 2/1060;
        // o = 4 s -> sqrt(2*4*1060/2) = 65.1 s -> 66 steps at 1 s/step
        s.note_failure_event(10.0);
        assert_eq!(s.observe(5.0, 1.0), 66, "Eq. 9 from the posterior mean");
        // more events at the same pace shade the cadence tighter
        for t in [20.0, 30.0, 40.0] {
            s.note_failure_event(t);
        }
        let derived = s.observe(5.0, 1.0);
        assert!(derived < 66, "{derived}");
        // (1 + 4) / (1000 + 240) -> sqrt(2*4*1240/5) = 44.5 s -> 45 steps
        assert_eq!(derived, 45);
    }

    #[test]
    fn snapshot_cadence_gate_and_clamps() {
        let mut s = SnapshotScheduler::new(1e-3, 4, 3);
        assert!(!s.due(2));
        assert!(s.due(3));
        assert!(!s.due(4));
        assert!(s.due(6));
        // fully overlapped snapshot above the floor: epsilon overhead, the
        // derived interval still floors at 1, never 0
        for t in [1.0, 2.0, 3.0, 4.0] {
            s.note_failure_event(t);
        }
        let steps = s.observe(0.0, 1.0);
        assert!(steps >= 1, "{steps}");
    }

    #[test]
    fn cadence_gates_self_heal_after_step_rollback() {
        // recovery restored an old checkpoint: the trainer's step rewinds
        // below the gate's high-water mark. The gate must clamp and keep
        // its periodic cadence through the re-done window, not go silent
        // for (rollback distance + interval) steps.
        let mut p = IntervalScheduler::new(1e-4, 6, 6, 10);
        assert!(p.should_persist(100));
        assert!(!p.should_persist(21), "clamped to 21, interval not yet elapsed");
        assert!(p.should_persist(31), "cadence resumes from the rolled-back step");
        let mut s = SnapshotScheduler::new(1e-3, 6, 5);
        assert!(s.due(50));
        assert!(!s.due(8));
        assert!(s.due(13), "snapshot cadence resumes inside the re-done window");
    }

    #[test]
    fn snapshot_cadence_shortens_under_observed_failure_storm() {
        // identical schedulers; one sees a storm -> its Eq. 9 interval must
        // come in below the calm one's static fallback
        let mut calm = SnapshotScheduler::new(1e-3, 6, 50);
        let mut hot = SnapshotScheduler::new(1e-3, 6, 50);
        for k in 0..16 {
            hot.note_failure_event(5.0 * (k as f64 + 1.0));
        }
        let calm_steps = calm.observe(2.0, 1.0); // no events: static holds
        let hot_steps = hot.observe(2.0, 1.0);
        assert_eq!(calm_steps, 50);
        assert!(hot_steps < calm_steps, "{hot_steps} vs {calm_steps}");
        // a restore opens a new epoch: the storm's evidence is dropped and
        // the cadence returns to the operator's static setting
        hot.note_restore();
        assert_eq!(hot.observe(2.0, 1.0), 50);
    }

    #[test]
    fn ingests_hwsim_weibull_schedule_incrementally() {
        let model = FailureModel::new(0.01, 0.0, 1.0);
        let mut rng = Rng::seed_from(7);
        let sched = model.schedule(&mut rng, 8, 2000.0);
        assert!(sched.events.iter().all(|e| e.kind == FailureKind::Hardware));
        let mut s = IntervalScheduler::new(1e-4, 6, 8, 10);
        // two half-open windows feed each event exactly once
        s.ingest_failure_schedule(&sched, f64::NEG_INFINITY, 1000.0);
        let first = s.empirical_events();
        s.ingest_failure_schedule(&sched, 1000.0, 2000.0);
        let total = s.empirical_events();
        assert!(total > first);
        assert_eq!(total, sched.events.len(), "each event fed exactly once");
        // the window MLE recovers the generating rate (0.01/node/unit over
        // the full 2000-unit horizon the ingest advanced the window to)...
        let k = sched.events.len() as f64;
        let mle = k / (2000.0 * 8.0);
        assert!((mle / 0.01 - 1.0).abs() < 0.3, "{mle}");
        // ...and the posterior mean sits between the stale knob and the MLE
        let lam = s.lambda_node();
        assert!(lam > 1e-4 && lam < mle, "{lam} vs {mle}");
        assert!(lam > 1e-3, "evidence dominates the knob at this volume: {lam}");
    }
}
