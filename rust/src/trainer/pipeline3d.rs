//! 3D (DP × TP × PP) trainer: drives the per-stage PJRT artifacts through a
//! 1F1B/GPipe microbatch schedule with activation hand-off, gradient
//! accumulation, per-stage Adam, and REFT snapshotting of every stage across
//! its sharding group.
//!
//! Execution model: ranks are simulated on one process, ops run in a
//! dependency-resolving order identical to the distributed schedule (the
//! schedule itself is validated in [`crate::pipeline`]); numerics are
//! bit-equal to the distributed run because synchronous PP has no
//! scheduling-dependent arithmetic. TP partitions parameter *ownership*
//! (snapshot/EC data paths) but executes the stage computation unsharded —
//! see DESIGN.md §Substitutions.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::checkpoint::{storage::step_key, CheckpointFile, SectionKind, Storage};
use crate::config::{FtMethod, RunConfig};
use crate::elastic::{DurableTier, RecoveryPath, RecoveryPlan, ReftCluster};
use crate::metrics::{keys, Metrics};
use crate::model::{StageState, SyntheticCorpus};
use crate::obs;
use crate::persist::{self, PersistDriver, PersistStats, SnapshotScheduler};
use crate::pipeline::{self, Op, Schedule};
use crate::runtime::{self, Engine, In, Manifest};
use crate::snapshot::SharedPayload;
use crate::topology::Topology;

pub struct PipelineTrainer {
    pub cfg: RunConfig,
    pub topo: Topology,
    engine: Engine,
    manifest: Manifest,
    /// canonical per-stage states (identical across DP paths)
    pub stages: Vec<StageState>,
    reft: Option<ReftCluster>,
    storage: Arc<dyn Storage>,
    corpus: SyntheticCorpus,
    pub schedule: Schedule,
    pub metrics: Arc<Metrics>,
    pub losses: Vec<f32>,
    /// durable-tier driver: background drain engine + cadence + metric
    /// sync (REFT-Ckpt with `ft.persist.enabled`)
    persist: Option<PersistDriver>,
    /// live Eq. 9 snapshot cadence (None = static `snapshot_interval`)
    snap_sched: Option<SnapshotScheduler>,
}

impl PipelineTrainer {
    pub fn new(cfg: RunConfig, storage: Arc<dyn Storage>, schedule: Schedule) -> Result<Self> {
        let topo = Topology::build(cfg.plan, cfg.nodes, cfg.gpus_per_node)?;
        let manifest = Manifest::load(&cfg.artifacts_dir, &cfg.model)?;
        anyhow::ensure!(
            manifest.n_stages == cfg.plan.pp,
            "artifacts exported for {} stages but plan has pp={}",
            manifest.n_stages,
            cfg.plan.pp
        );
        let engine = Engine::cpu(&cfg.artifacts_dir)?;
        let stages: Vec<StageState> = manifest
            .stages
            .iter()
            .map(|m| StageState::init(m, cfg.seed))
            .collect::<Result<_>>()?;
        let payload_bytes: Vec<u64> = stages
            .iter()
            .map(|s| s.payload_bytes() as u64)
            .collect();
        let reft = match cfg.ft.method {
            FtMethod::ReftSn | FtMethod::ReftCkpt => Some(ReftCluster::start(
                topo.clone(),
                &payload_bytes,
                cfg.ft.clone(),
            )?),
            _ => None,
        };
        let corpus = SyntheticCorpus::new(manifest.hyper.vocab, cfg.seed ^ 0xC0FFEE);
        // durable tier: REFT-Ckpt with the engine enabled persists via the
        // background drain instead of inline trainer-thread puts. The
        // widest SG drives the exceedance rate conservatively.
        let widest_sg = (0..cfg.plan.pp)
            .map(|s| topo.sharding_group(s).len())
            .max()
            .unwrap_or(1);
        let persist = match (&reft, cfg.ft.method, cfg.ft.persist.enabled) {
            (Some(r), FtMethod::ReftCkpt, true) => Some(PersistDriver::start(
                cfg.model.clone(),
                Arc::clone(&storage),
                r.plan.clone(),
                &cfg.ft,
                widest_sg,
            )),
            _ => None,
        };
        // adaptive snapshot cadence (Eq. 9): live only for REFT methods —
        // the baselines' checkpoint interval stays the static knob
        let snap_sched = (reft.is_some() && cfg.ft.auto_snapshot_interval).then(|| {
            SnapshotScheduler::new(
                cfg.ft.persist.lambda_node,
                cfg.nodes,
                cfg.ft.snapshot_interval as u64,
            )
        });
        Ok(PipelineTrainer {
            cfg,
            topo,
            engine,
            manifest,
            stages,
            reft,
            storage,
            corpus,
            schedule,
            metrics: Arc::new(Metrics::new()),
            losses: Vec::new(),
            persist,
            snap_sched,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// One full iteration: `microbatches` through the pipe per DP path,
    /// gradient accumulation + DP all-reduce, per-stage fused Adam.
    pub fn step(&mut self) -> Result<f32> {
        let t_step0 = Instant::now();
        let pp = self.cfg.plan.pp;
        let dp = self.cfg.plan.dp;
        let n_micro = self.cfg.microbatches;
        let (b, t) = (self.manifest.hyper.batch, self.manifest.hyper.seq);
        let d = self.manifest.hyper.d_model;

        // per-DP-path accumulated grads, per stage
        let mut grad_acc: Vec<Vec<Vec<f32>>> = Vec::with_capacity(dp);
        let mut loss_total = 0f32;

        for _path in 0..dp {
            let mut acc: Vec<Vec<f32>> = self
                .stages
                .iter()
                .map(|s| vec![0f32; s.n_params()])
                .collect();
            // microbatch data for this path
            let batches: Vec<(Vec<i32>, Vec<i32>)> =
                (0..n_micro).map(|_| self.corpus.next_batch(b, t)).collect();

            // dependency-driven execution of the validated schedule
            let sched = pipeline::build(self.schedule, pp, n_micro);
            pipeline::validate(&sched, n_micro).map_err(|e| anyhow::anyhow!(e))?;

            // stage activations: input of (stage, micro) saved for bwd
            let mut act_in: HashMap<(usize, usize), Vec<f32>> = HashMap::new();
            let mut dx_from: HashMap<(usize, usize), Vec<f32>> = HashMap::new();
            let mut done_f = vec![vec![false; n_micro]; pp];
            let mut done_b = vec![vec![false; n_micro]; pp];
            let mut cursor = vec![0usize; pp];
            let total_ops: usize = sched.iter().map(Vec::len).sum();
            let mut executed = 0usize;

            while executed < total_ops {
                let mut progressed = false;
                for s in 0..pp {
                    while cursor[s] < sched[s].len() {
                        let op = sched[s][cursor[s]];
                        let ready = match op {
                            Op::Fwd(i) => s == 0 || done_f[s - 1][i],
                            Op::Bwd(i) => {
                                done_f[s][i] && (s == pp - 1 || done_b[s + 1][i])
                            }
                        };
                        if !ready {
                            break;
                        }
                        match op {
                            Op::Fwd(i) => {
                                let loss = self.exec_fwd(
                                    s, i, &batches[i], &mut act_in, &mut dx_from, &mut acc, b, t, d,
                                )?;
                                if let Some(l) = loss {
                                    loss_total += l;
                                }
                                done_f[s][i] = true;
                            }
                            Op::Bwd(i) => {
                                self.exec_bwd(
                                    s, i, &batches[i], &mut act_in, &mut dx_from, &mut acc, b, t, d,
                                )?;
                                done_b[s][i] = true;
                            }
                        }
                        cursor[s] += 1;
                        executed += 1;
                        progressed = true;
                    }
                }
                anyhow::ensure!(progressed, "schedule deadlocked at runtime");
            }
            grad_acc.push(acc);
        }

        // DP all-reduce per stage, then mean over microbatches
        for s in 0..pp {
            let mut per_path: Vec<Vec<f32>> = grad_acc.iter().map(|g| g[s].clone()).collect();
            crate::collective::allreduce_mean(&mut per_path);
            let inv = 1.0 / n_micro as f32;
            let grads: Vec<f32> = per_path[0].iter().map(|g| g * inv).collect();
            self.adam_stage(s, &grads)?;
        }
        for st in &mut self.stages {
            st.step += 1;
            st.rng_state[2] = st.rng_state[2].wrapping_add(1);
        }

        let loss = loss_total / (dp * n_micro) as f32;
        self.losses.push(loss);
        self.metrics.inc_k(keys::STEPS, 1);

        // iteration-boundary drain of any in-flight snapshot backlog (§4.1
        // L2): a bounded bucket budget per node, never O(payload)
        self.tick_snapshot_backlog()?;

        // fault tolerance. Snapshot cadence: the Eq. 9 scheduler when
        // enabled (live cost x observed λ), else the static interval.
        let step = self.stages[0].step;
        let snap_due = match self.snap_sched.as_mut() {
            Some(s) => s.due(step),
            None => step % self.cfg.ft.snapshot_interval as u64 == 0,
        };
        if snap_due {
            match self.cfg.ft.method {
                FtMethod::ReftSn | FtMethod::ReftCkpt => {
                    self.snapshot()?;
                }
                FtMethod::CheckFreq | FtMethod::TorchSnapshot => {
                    self.checkpoint()?;
                }
                FtMethod::None => {}
            }
        }
        // Durable-persist cadence, evaluated EVERY step (see
        // `DpTrainer::step`): Eq. 9 snapshot steps are not multiples of
        // `snapshot_interval`, so the static persist product must not hide
        // inside the snapshot branch. The engine drains the latest promoted
        // round, so this only needs one snapshot to have ever completed.
        if self.cfg.ft.method == FtMethod::ReftCkpt
            && self.metrics.counter("snapshots") > 0
        {
            let persist =
                self.cfg.ft.persist_every as u64 * self.cfg.ft.snapshot_interval as u64;
            // cadence: the driver's live Appendix-A scheduler when
            // enabled, else the static persist_every product
            let due = match self.persist.as_mut() {
                Some(d) => d.due(step, persist),
                None => step % persist == 0,
            };
            if due {
                self.persist_now()?;
            }
        }

        // live cadence re-derivation from this run's measured costs
        self.metrics.record_secs_k(keys::STEP_WALL, t_step0.elapsed().as_secs_f64());
        let metrics = Arc::clone(&self.metrics);
        if let Some(d) = self.persist.as_mut() {
            d.observe(&metrics);
        }
        self.observe_snapshot_cadence(&metrics);
        self.sync_delta_gauges();
        Ok(loss)
    }

    /// Feed the Eq. 9 snapshot scheduler the cost the training thread
    /// actually pays per round (see `DpTrainer::observe_snapshot_cadence`).
    fn observe_snapshot_cadence(&mut self, metrics: &Metrics) {
        let Some(sched) = self.snap_sched.as_mut() else {
            return;
        };
        let snap = metrics.timer("snapshot");
        if snap.count == 0 {
            return;
        }
        let tick = metrics.timer("snapshot_tick");
        let t_sn = snap.mean() + tick.total / snap.count as f64;
        let steps = sched.observe(t_sn, metrics.timer("step_wall").mean());
        metrics.gauge("snapshot_interval_steps", steps as f64);
        metrics.gauge("snapshot_lambda_node", sched.lambda_node());
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_fwd(
        &mut self,
        s: usize,
        micro: usize,
        batch: &(Vec<i32>, Vec<i32>),
        act_in: &mut HashMap<(usize, usize), Vec<f32>>,
        dx_from: &mut HashMap<(usize, usize), Vec<f32>>,
        acc: &mut [Vec<f32>],
        b: usize,
        t: usize,
        d: usize,
    ) -> Result<Option<f32>> {
        let pp = self.cfg.plan.pp;
        let meta = &self.manifest.stages[s];
        let n = self.stages[s].n_params();
        let (tokens, targets) = batch;
        if s == 0 && pp == 1 {
            // single-stage: fused fwd_bwd artifact
            let path = meta.artifacts.get("fwd_bwd")?.to_string();
            let outs = self.metrics.time_k(keys::STAGE_FWD, || {
                self.engine.run_inputs(
                    &path,
                    &[
                        In::f32(&self.stages[s].params, &[n]),
                        In::i32(tokens, &[b, t]),
                        In::i32(targets, &[b, t]),
                    ],
                )
            })?;
            let loss = runtime::scalar_f32(&outs[0])?;
            let grads = runtime::vec_f32(&outs[1])?;
            for (a, g) in acc[s].iter_mut().zip(&grads) {
                *a += g;
            }
            return Ok(Some(loss));
        }
        if s == 0 {
            let path = meta.artifacts.get("fwd")?.to_string();
            let outs = self.metrics.time_k(keys::STAGE_FWD, || {
                self.engine.run_inputs(
                    &path,
                    &[In::f32(&self.stages[s].params, &[n]), In::i32(tokens, &[b, t])],
                )
            })?;
            let y = runtime::vec_f32(&outs[0])?;
            act_in.insert((s + 1, micro), y);
            return Ok(None);
        }
        let x = act_in
            .get(&(s, micro))
            .with_context(|| format!("missing activation for stage {s} micro {micro}"))?
            .clone();
        if s == pp - 1 {
            // last stage: fused fwd+bwd (loss, dx, grads)
            let path = meta.artifacts.get("fwdbwd")?.to_string();
            let outs = self.metrics.time_k(keys::STAGE_FWDBWD, || {
                self.engine.run_inputs(
                    &path,
                    &[
                        In::f32(&self.stages[s].params, &[n]),
                        In::f32(&x, &[b, t, d]),
                        In::i32(targets, &[b, t]),
                    ],
                )
            })?;
            let loss = runtime::scalar_f32(&outs[0])?;
            let dx = runtime::vec_f32(&outs[1])?;
            let grads = runtime::vec_f32(&outs[2])?;
            for (a, g) in acc[s].iter_mut().zip(&grads) {
                *a += g;
            }
            dx_from.insert((s, micro), dx);
            return Ok(Some(loss));
        }
        // middle stage
        let path = meta.artifacts.get("fwd")?.to_string();
        let outs = self.metrics.time_k(keys::STAGE_FWD, || {
            self.engine.run_inputs(
                &path,
                &[In::f32(&self.stages[s].params, &[n]), In::f32(&x, &[b, t, d])],
            )
        })?;
        let y = runtime::vec_f32(&outs[0])?;
        act_in.insert((s + 1, micro), y);
        Ok(None)
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_bwd(
        &mut self,
        s: usize,
        micro: usize,
        batch: &(Vec<i32>, Vec<i32>),
        act_in: &mut HashMap<(usize, usize), Vec<f32>>,
        dx_from: &mut HashMap<(usize, usize), Vec<f32>>,
        acc: &mut [Vec<f32>],
        b: usize,
        t: usize,
        d: usize,
    ) -> Result<()> {
        let pp = self.cfg.plan.pp;
        if pp == 1 || s == pp - 1 {
            // single-stage fwd_bwd / last-stage fwdbwd already accumulated
            return Ok(());
        }
        let meta = &self.manifest.stages[s];
        let n = self.stages[s].n_params();
        let dy = dx_from
            .remove(&(s + 1, micro))
            .with_context(|| format!("missing upstream grad for stage {s} micro {micro}"))?;
        let (tokens, _) = batch;
        if s == 0 {
            let path = meta.artifacts.get("bwd")?.to_string();
            let outs = self.metrics.time_k(keys::STAGE_BWD, || {
                self.engine.run_inputs(
                    &path,
                    &[
                        In::f32(&self.stages[s].params, &[n]),
                        In::i32(tokens, &[b, t]),
                        In::f32(&dy, &[b, t, d]),
                    ],
                )
            })?;
            let grads = runtime::vec_f32(&outs[0])?;
            for (a, g) in acc[s].iter_mut().zip(&grads) {
                *a += g;
            }
        } else {
            let x = act_in
                .remove(&(s, micro))
                .with_context(|| format!("missing activation for bwd stage {s} micro {micro}"))?;
            let path = meta.artifacts.get("bwd")?.to_string();
            let outs = self.metrics.time_k(keys::STAGE_BWD, || {
                self.engine.run_inputs(
                    &path,
                    &[
                        In::f32(&self.stages[s].params, &[n]),
                        In::f32(&x, &[b, t, d]),
                        In::f32(&dy, &[b, t, d]),
                    ],
                )
            })?;
            let dx = runtime::vec_f32(&outs[0])?;
            let grads = runtime::vec_f32(&outs[1])?;
            for (a, g) in acc[s].iter_mut().zip(&grads) {
                *a += g;
            }
            dx_from.insert((s, micro), dx);
        }
        Ok(())
    }

    fn adam_stage(&mut self, s: usize, grads: &[f32]) -> Result<()> {
        let meta = &self.manifest.stages[s];
        let n = self.stages[s].n_params();
        let path = meta.artifacts.get("adam")?.to_string();
        let step = self.stages[s].step + 1;
        let step_in = [step as f32];
        let outs = self.metrics.time_k(keys::ADAM, || {
            self.engine.run_inputs(
                &path,
                &[
                    In::f32(&self.stages[s].params, &[n]),
                    In::f32(&self.stages[s].adam_m, &[n]),
                    In::f32(&self.stages[s].adam_v, &[n]),
                    In::f32(grads, &[n]),
                    In::f32(&step_in, &[1]),
                ],
            )
        })?;
        self.stages[s].params = runtime::vec_f32(&outs[0])?;
        self.stages[s].adam_m = runtime::vec_f32(&outs[1])?;
        self.stages[s].adam_v = runtime::vec_f32(&outs[2])?;
        Ok(())
    }

    /// Sparse-snapshot accounting: mirror the delta planner's counters into
    /// run gauges (see `DpTrainer::sync_delta_gauges`). A no-op when the
    /// delta layer is off.
    fn sync_delta_gauges(&self) {
        let Some(ds) = self.reft.as_ref().and_then(|r| r.delta_stats()) else {
            return;
        };
        self.metrics.gauge("delta_full_rounds", ds.full_rounds as f64);
        self.metrics.gauge("delta_sparse_rounds", ds.sparse_rounds as f64);
        self.metrics.gauge("delta_payload_bytes", ds.payload_bytes as f64);
        self.metrics.gauge("delta_shipped_bytes", ds.shipped_bytes as f64);
    }

    pub fn run(&mut self, steps: usize) -> Result<Vec<f32>> {
        (0..steps).map(|_| self.step()).collect()
    }

    /// Save the current state through REFT. With `async_snapshot` on, this
    /// is an L1 enqueue — it returns before any payload bucket moves, and
    /// [`Self::tick_snapshot_backlog`] drains the round across the next
    /// iterations. Otherwise the classic blocking round runs here.
    pub fn snapshot(&mut self) -> Result<u64> {
        // single capture per stage: serialize once, share Arc-backed views
        // downstream (zero further payload copies on the save path)
        let payloads: Vec<SharedPayload> = self
            .stages
            .iter()
            .map(|s| SharedPayload::new(s.to_payload()))
            .collect();
        let use_async = self.cfg.ft.async_snapshot;
        let reft = self.reft.as_mut().context("REFT not enabled")?;
        let v = if use_async {
            let superseded_before = reft.coordinator().stats().superseded;
            let v = self
                .metrics
                .time_k(keys::SNAPSHOT, || reft.request_snapshot(payloads))?;
            // chronic supersession = the interference budget never lets a
            // round finish; protection would silently be zero, so count it
            if reft.coordinator().stats().superseded > superseded_before {
                self.metrics.inc_k(keys::SNAPSHOTS_SUPERSEDED, 1);
            }
            v
        } else {
            self.metrics.time_k(keys::SNAPSHOT, || reft.snapshot_all(&payloads))?
        };
        // remember which step this version captured, so a later persist of
        // the round labels its manifest with the contained state honestly
        let step = self.stages[0].step;
        obs::instant(obs::cat::TRAINER, "snapshot", v, step);
        if let Some(d) = self.persist.as_mut() {
            d.note_snapshot(v, step);
        }
        self.metrics.inc_k(keys::SNAPSHOTS, 1);
        Ok(v)
    }

    /// One coordinator tick (iteration-boundary drain). No-op unless the
    /// asynchronous save path is enabled and a round is in flight.
    pub fn tick_snapshot_backlog(&mut self) -> Result<()> {
        if !self.cfg.ft.async_snapshot {
            return Ok(());
        }
        let Some(reft) = self.reft.as_mut() else {
            return Ok(());
        };
        let report = self.metrics.time_k(keys::SNAPSHOT_TICK, || reft.tick())?;
        if report.completed {
            self.metrics.inc_k(keys::SNAPSHOTS_COMPLETED, 1);
        }
        if report.aborted {
            self.metrics.inc_k(keys::SNAPSHOTS_ABORTED, 1);
        }
        Ok(())
    }

    /// Post-recovery re-protection: always blocking, so every SMP holds a
    /// clean copy of the restored state before training resumes (a
    /// half-drained asynchronous round protects nothing).
    fn snapshot_blocking_for_recovery(&mut self) -> Result<u64> {
        let payloads: Vec<SharedPayload> = self
            .stages
            .iter()
            .map(|s| SharedPayload::new(s.to_payload()))
            .collect();
        let reft = self.reft.as_mut().context("REFT not enabled")?;
        // distinct timer: this blocking round must not pollute the
        // "snapshot" stall measurement (enqueue cost on the async path)
        let v = self
            .metrics
            .time_k(keys::SNAPSHOT_RECOVERY, || reft.snapshot_all_blocking(&payloads))?;
        let step = self.stages[0].step;
        if let Some(d) = self.persist.as_mut() {
            d.note_snapshot(v, step);
        }
        self.metrics.inc_k(keys::SNAPSHOTS, 1);
        Ok(v)
    }

    pub fn checkpoint(&mut self) -> Result<String> {
        let step = self.stages[0].step;
        let mut file = CheckpointFile::new(&self.cfg.model, step);
        for (s, st) in self.stages.iter().enumerate() {
            file.add_section(SectionKind::StagePayload, s as u32, st.to_payload());
        }
        let key = step_key(&self.cfg.model, step);
        let bytes = self.metrics.time_k(keys::CKPT_ENCODE, || file.encode());
        self.metrics.time_k(keys::CKPT_PUT, || self.storage.put(&key, &bytes))?;
        self.metrics.inc_k(keys::CHECKPOINTS, 1);
        Ok(key)
    }

    /// Durable-tier hand-off at the persist cadence: with the engine
    /// enabled this is an enqueue — the SMP-driven background drain does
    /// the I/O and commits the manifest off the training thread — else the
    /// legacy inline checkpoint. Returns whether a blocking checkpoint ran.
    fn persist_now(&mut self) -> Result<bool> {
        if self.persist.is_none() {
            self.checkpoint()?;
            return Ok(true);
        }
        let sources = self
            .reft
            .as_ref()
            .context("persistence engine requires REFT")?
            .persist_sources();
        let step = self.stages[0].step;
        let metrics = Arc::clone(&self.metrics);
        self.persist.as_mut().unwrap().enqueue(step, sources, &metrics)?;
        Ok(false)
    }

    /// Shutdown barrier for the durable tier: block until every enqueued
    /// persist job committed (or aborted) and fold the engine counters into
    /// the run metrics. The only blocking persistence call in the system;
    /// a no-op when the engine is off.
    pub fn flush_persist(&mut self) -> Result<()> {
        let metrics = Arc::clone(&self.metrics);
        if let Some(d) = self.persist.as_mut() {
            d.flush(&metrics)?;
        }
        Ok(())
    }

    /// Engine introspection for drivers and tests.
    pub fn persist_stats(&self) -> Option<PersistStats> {
        self.persist.as_ref().map(PersistDriver::stats)
    }

    // -- failure injection + recovery ---------------------------------------

    pub fn inject_software_failure(&mut self) {
        for st in &mut self.stages {
            st.params.clear();
            st.adam_m.clear();
            st.adam_v.clear();
        }
        obs::instant(obs::cat::TRAINER, "sw_failure", 0, self.stages[0].step);
        self.metrics.inc_k(keys::FAILURES_SOFTWARE, 1);
    }

    /// Hardware failure: a node goes away entirely. The event also feeds
    /// the live persist-cadence scheduler's rolling empirical λ (see
    /// `DpTrainer::inject_node_failure`).
    pub fn inject_node_failure(&mut self, node: usize) {
        obs::instant(obs::cat::TRAINER, "hw_failure", 0, node as u64);
        if let Some(reft) = self.reft.as_mut() {
            reft.kill_node(node);
        }
        self.inject_software_failure();
        if let Some(d) = self.persist.as_mut() {
            d.note_failure();
        }
        // the same event feeds the Eq. 9 snapshot cadence's rolling λ
        if let Some(s) = self.snap_sched.as_mut() {
            s.note_failure();
        }
        self.metrics.inc_k(keys::FAILURES_HARDWARE, 1);
    }

    /// Recover from the failure described by `dead`, driven by the elastic
    /// decision tree **up front** (see `DpTrainer::recover` — same plan →
    /// predict → execute → predicted-vs-actual telemetry flow, over
    /// per-stage states here).
    pub fn recover(&mut self, dead: &[usize]) -> Result<u64> {
        let _sp = obs::span_arg(obs::cat::TRAINER, "recover", 0, dead.len() as u64);
        let sizes: Vec<usize> = self.manifest.stages.iter().map(|m| m.n_params).collect();
        let plan = match &self.reft {
            Some(_) => RecoveryPlan::probe_elastic(
                &self.topo,
                dead,
                self.cfg.ft.raim5,
                self.storage.as_ref(),
                &self.cfg.model,
                self.stages.len(),
                self.cfg.ft.reshape_on_restore,
            ),
            None => RecoveryPlan::durable_only(self.storage.as_ref(), &self.cfg.model),
        };
        plan.record_predicted(&self.metrics);
        let restore_inmem = |me: &mut Self| -> Result<()> {
            let payloads = me
                .reft
                .as_ref()
                .context("REFT not enabled")
                .and_then(|r| r.restore_all(dead))?;
            for (s, payload) in payloads.iter().enumerate() {
                me.stages[s] = StageState::from_payload(s, sizes[s], payload)?;
            }
            me.metrics.inc_k(keys::RECOVERIES_INMEMORY, 1);
            Ok(())
        };
        let actual = match plan.predicted() {
            Some(RecoveryPath::InMemory) => match restore_inmem(self) {
                Ok(()) => RecoveryPath::InMemory,
                // predicted in-memory, fabric refused: durable fallback,
                // counted as a misprediction
                Err(e) => self.recover_from_durable(&sizes, Some(&e))?,
            },
            Some(RecoveryPath::Durable(_)) => self.recover_from_durable(&sizes, None)?,
            None => match restore_inmem(self) {
                Ok(()) => RecoveryPath::InMemory,
                Err(e) => anyhow::bail!(
                    "protection exceeded and no durable checkpoint exists \
                     (plan: {:?}; in-memory: {e})",
                    plan.decision
                ),
            },
        };
        plan.record_actual(&self.metrics, actual);
        for &n in dead {
            if let Some(reft) = self.reft.as_mut() {
                let _ = reft.replace_node(n);
            }
        }
        if self.reft.is_some() {
            self.snapshot_blocking_for_recovery()?;
        }
        // the restore opened a new failure regime: both cadence trackers
        // drop their pre-recovery event windows (horizon-aware λ — an old
        // burst must not keep the cadence pinned tight forever)
        if let Some(d) = self.persist.as_mut() {
            d.note_restore();
        }
        if let Some(s) = self.snap_sched.as_mut() {
            s.note_restore();
        }
        Ok(self.stages[0].step)
    }

    /// The durable-tier restore (decision-tree case 3): the shared resolver
    /// picks the newest *complete* persist manifest with exactly this run's
    /// stage layout (atomic commit: partial uploads are invisible; a
    /// different-layout manifest degrades instead of aborting) unless the
    /// legacy inline checkpoint holds newer state. Manifest shards arrive
    /// through the fused fetch path — CRC verified in the same pass that
    /// fills the payload buffer, parts combined into the whole-shard check —
    /// so restore touches every byte once. Returns the tier that served.
    fn recover_from_durable(
        &mut self,
        sizes: &[usize],
        inmem_err: Option<&anyhow::Error>,
    ) -> Result<RecoveryPath> {
        let legacy_key = self.storage.latest_for(&self.cfg.model);
        // behind the knob, a manifest persisted at a different pipeline
        // shape is regathered into this run's stage layout through the
        // manifest's atom index (element streams re-tiled per stage)
        let resolved = if self.cfg.ft.reshape_on_restore {
            let target: Vec<u64> = sizes
                .iter()
                .map(|&n| n as u64 * 12 + persist::STAGE_STATE_HEADER_BYTES)
                .collect();
            persist::resolve_for_recovery_reshaped(
                self.storage.as_ref(),
                &self.cfg.model,
                persist::StageCodec::StageState,
                &target,
                legacy_key.as_deref(),
                self.cfg.ft.delta_chain_max,
            )
        } else {
            persist::resolve_for_recovery_bounded(
                self.storage.as_ref(),
                &self.cfg.model,
                self.stages.len(),
                legacy_key.as_deref(),
                self.cfg.ft.delta_chain_max,
            )
            .map(|(man, payloads)| (man, payloads, false))
        };
        if let Some((man, payloads, reshaped)) = resolved {
            for (s, payload) in payloads.iter().enumerate() {
                self.stages[s] = StageState::from_payload(s, sizes[s], payload)?;
            }
            self.metrics.inc_k(keys::RECOVERIES_CHECKPOINT, 1);
            self.metrics.inc_k(keys::RECOVERIES_MANIFEST, 1);
            if reshaped {
                self.metrics.inc("recoveries_reshaped", 1);
            }
            self.metrics
                .gauge("recovered_manifest_step", man.snapshot_step as f64);
            let restored: usize = payloads.iter().map(Vec::len).sum();
            self.metrics
                .gauge("restored_durable_bytes", restored as f64);
            return Ok(RecoveryPath::Durable(DurableTier::Manifest));
        }
        // legacy checkpoint of THIS model — a shared store may hold other
        // models' steps with alphabetically-later names
        let key = legacy_key.with_context(|| match inmem_err {
            Some(e) => format!("in-memory recovery failed ({e}) and no durable checkpoint exists"),
            None => "protection exceeded and no durable checkpoint exists".to_string(),
        })?;
        let file = CheckpointFile::decode(&self.storage.get(&key)?)?;
        for s in 0..self.stages.len() {
            let payload = file
                .stage_payload(s as u32)
                .with_context(|| format!("checkpoint missing stage {s}"))?;
            self.stages[s] = StageState::from_payload(s, sizes[s], payload)?;
        }
        self.metrics.inc_k(keys::RECOVERIES_CHECKPOINT, 1);
        self.metrics.inc_k(keys::RECOVERIES_LEGACY, 1);
        Ok(RecoveryPath::Durable(DurableTier::Legacy))
    }
}

#[cfg(test)]
mod tests {
    // Needs artifacts; exercised in rust/tests/trainer_integration.rs.
}
