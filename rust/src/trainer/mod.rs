//! Trainers: the live training loops that execute the PJRT artifacts under a
//! fault-tolerance policy — the composition point of the whole system.
//!
//! * [`dp::DpTrainer`] — synchronous data-parallel training: each DP path
//!   runs `fwd_bwd` on its own microbatch, gradients are mean-all-reduced
//!   (real math), Adam runs via the fused Pallas kernel artifact.
//! * [`pipeline3d::PipelineTrainer`] — 3D (DP × PP) training driven by a
//!   1F1B/GPipe schedule over the per-stage artifacts, with activation
//!   hand-off and gradient accumulation.
//!
//! Both plug into [`crate::elastic::ReftCluster`] for REFT snapshots and the
//! [`crate::checkpoint`] stack for durable checkpoints, and both expose
//! failure-injection entry points used by the recovery tests/examples.

pub mod dp;
pub mod pipeline3d;

pub use dp::DpTrainer;
pub use pipeline3d::PipelineTrainer;
